//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! Loads the build-time-trained switch8 bundle, builds a hash table for
//! one sentence (the hash-building thread's job), serves a short trace
//! through the SiDA pipeline, and prints predictions + stats.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use std::sync::Arc;

use sida_moe::coordinator::{HashBuilder, Pipeline, PipelineConfig};
use sida_moe::runtime::ModelBundle;
use sida_moe::workload::{ArrivalProcess, Profile, TraceGenerator};

fn main() -> anyhow::Result<()> {
    sida_moe::util::logging::init();
    let root = sida_moe::default_artifacts_root();
    if !root.join("switch8").join("model.json").is_file() {
        println!("artifacts not built — run `make artifacts` first");
        return Ok(());
    }

    // 1. load a model bundle: compiled HLO artifacts + weights + topology
    let bundle = Arc::new(ModelBundle::load_named(&root, "switch8")?);
    println!(
        "loaded {} ({} experts x {} MoE layers, PJRT platform: {})",
        bundle.topology.name,
        bundle.topology.num_experts,
        bundle.topology.num_moe_layers(),
        bundle.engine.platform(),
    );

    // 2. the data-aware half: predict expert activation for one sentence
    //    without running the model at all
    let mut gen = TraceGenerator::new(Profile::named("sst2")?, bundle.topology.vocab, 42);
    let (ids, n_tokens, topic) = gen.sentence();
    let builder = HashBuilder::new(&bundle, "sst2")?;
    let table = builder.build(0, &ids)?;
    let mask = sida_moe::workload::pad_mask(&ids);
    println!("\nsentence: {n_tokens} tokens (topic {topic})");
    for layer in 0..table.m {
        println!(
            "  MoE layer {layer}: predicted active experts {:?} (idle {:.0}%)",
            table.predicted_experts(layer, 1, &mask),
            100.0 * table.idle_ratio(layer, bundle.topology.num_experts, &mask),
        );
    }

    // 3. serve a small closed-loop trace through the full two-thread
    //    pipeline (hash-building thread + prefetch + inference thread)
    let requests = gen.trace(8, ArrivalProcess::ClosedLoop);
    let pipeline = Pipeline::new(
        bundle,
        "sst2",
        PipelineConfig { want_cls: true, ..Default::default() },
    )?;
    let outcome = pipeline.serve(&requests)?;
    let stats = outcome.stats;
    println!("\nserved {} requests in {:.3}s", stats.requests, stats.wall_secs);
    println!("  throughput      {:.1} req/s", stats.throughput());
    println!(
        "  latency p50/p95 {:.2} / {:.2} ms",
        stats.latency.p50() * 1e3,
        stats.latency.p95() * 1e3
    );
    println!(
        "  cache           {} hits / {} misses ({} blocking)",
        stats.cache_hits, stats.cache_misses, stats.blocking_misses
    );
    println!("  expert calls    {}", stats.phases.expert_invocations);
    for r in outcome.per_request.iter().take(3) {
        println!(
            "  request {} -> class {:?} in {:.2} ms",
            r.id,
            r.cls_pred,
            r.latency_secs * 1e3
        );
    }
    Ok(())
}
