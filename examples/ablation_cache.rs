//! Eviction-policy and prefetch ablation tool.
//!
//! The paper fixes FIFO (footnote 1) and folds prefetch into the
//! inference thread; this example lets you vary both knobs and watch the
//! hit rate / blocking-miss / throughput trade-off.
//!
//! Run: `cargo run --release --example ablation_cache -- --model switch64`

use std::sync::Arc;

use sida_moe::config::ServeConfig;
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::memory::CostModel;
use sida_moe::metrics::Table;
use sida_moe::runtime::ModelBundle;
use sida_moe::util::cli::Cli;
use sida_moe::workload::{ArrivalProcess, Profile, TraceGenerator};

fn main() -> anyhow::Result<()> {
    sida_moe::util::logging::init();
    let cli = Cli::new("ablation_cache", "eviction x prefetch ablation")
        .opt("model", "model config", "switch64")
        .opt("dataset", "dataset profile", "sst2")
        .opt("requests", "requests per cell", "10")
        .opt("layer-frac", "budget as a fraction of one MoE layer", "0.5");
    let args = cli.parse();
    let model = args.get_or("model", "switch64");
    let dataset = args.get_or("dataset", "sst2");
    let n = args.get_usize("requests", 10);
    let frac = args.get_f64("layer-frac", 0.5);

    let root = sida_moe::default_artifacts_root();
    if !root.join(&model).join("model.json").is_file() {
        println!("artifacts for {model} not built — run `make artifacts`");
        return Ok(());
    }
    let bundle = Arc::new(ModelBundle::load_named(&root, &model)?);
    let cost = CostModel::paper_scale(bundle.topology.expert_param_bytes);
    let layer_sim =
        cost.sim_bytes(bundle.topology.expert_param_bytes * bundle.topology.num_experts);
    let budget = (layer_sim as f64 * frac) as usize;

    let mut gen =
        TraceGenerator::new(Profile::named(&dataset)?, bundle.topology.vocab, 0);
    let requests = gen.trace(n, ArrivalProcess::ClosedLoop);

    let mut t = Table::new(
        "eviction x prefetch ablation",
        &[
            "policy", "prefetch", "hit %", "blocking misses", "evictions",
            "req/s",
        ],
    );
    for policy in ["fifo", "lru", "lfu", "clock"] {
        for prefetch in [true, false] {
            let cfg = PipelineConfig {
                k_used: ServeConfig::paper_k_for(&dataset),
                budget_sim_bytes: budget,
                policy: policy.into(),
                prefetch,
                real_sleep: true,
                ..Default::default()
            };
            let out = Pipeline::new(bundle.clone(), &dataset, cfg)?.serve(&requests)?;
            let s = &out.stats;
            let hit = sida_moe::metrics::report::fmt_rate(s.hit_rate());
            t.row(vec![
                policy.into(),
                prefetch.to_string(),
                hit,
                s.blocking_misses.to_string(),
                s.evictions.to_string(),
                format!("{:.2}", s.throughput()),
            ]);
        }
    }
    t.print();
    Ok(())
}
