//! End-to-end serving driver (the required validation example).
//!
//! Loads a build-time-trained Switch model, serves a mixed batched
//! request trace through the full SiDA stack — hash-building thread,
//! prefetch stage, inference thread, expert cache under a device-memory
//! budget with modeled PCIe transfer costs actually slept on the
//! critical path — and reports latency/throughput, hash-hit rate and
//! memory saving against the Standard baseline on the same trace.
//!
//! Run: `cargo run --release --example serve_trace -- --model switch64`
//! Results recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;

use sida_moe::baselines::{run_baseline, BaselineConfig, Method};
use sida_moe::config::ServeConfig;
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::memory::CostModel;
use sida_moe::metrics::report::{fmt_bytes, fmt_secs};
use sida_moe::metrics::Table;
use sida_moe::runtime::ModelBundle;
use sida_moe::util::cli::Cli;
use sida_moe::workload::{ArrivalProcess, Profile, TraceGenerator};

fn main() -> anyhow::Result<()> {
    sida_moe::util::logging::init();
    let cli = Cli::new("serve_trace", "end-to-end SiDA serving driver")
        .opt("model", "model config", "switch64")
        .opt("requests", "requests per dataset", "16")
        .opt("budget-gb", "device budget (sim GB)", "8")
        .opt("seed", "trace seed", "0");
    let args = cli.parse();
    let model = args.get_or("model", "switch64");
    let n = args.get_usize("requests", 16);
    let budget = (args.get_f64("budget-gb", 8.0) * 1e9) as usize;

    let root = sida_moe::default_artifacts_root();
    if !root.join(&model).join("model.json").is_file() {
        println!("artifacts for {model} not built — run `make artifacts`");
        return Ok(());
    }
    let bundle = Arc::new(ModelBundle::load_named(&root, &model)?);
    let cost = CostModel::paper_scale(bundle.topology.expert_param_bytes);
    let full_sim = cost.sim_bytes(bundle.topology.total_param_bytes);
    println!(
        "model {model}: {} experts/layer, full residency {} (simulated), budget {}",
        bundle.topology.num_experts,
        fmt_bytes(full_sim),
        fmt_bytes(budget),
    );

    let mut t = Table::new(
        "end-to-end serving (real-slept transfer model)",
        &[
            "dataset", "method", "req/s", "p50", "p95", "p99", "hash hit %",
            "peak device", "mem saved %",
        ],
    );
    let mut total_tokens = 0u64;
    for dataset in ["sst2", "mrpc", "multirc"] {
        let mut gen = TraceGenerator::new(
            Profile::named(dataset)?,
            bundle.topology.vocab,
            args.get_u64("seed", 0),
        );
        let requests = gen.trace(n, ArrivalProcess::ClosedLoop);
        total_tokens += requests.iter().map(|r| r.n_tokens as u64).sum::<u64>();

        // SiDA
        let pcfg = PipelineConfig {
            k_used: ServeConfig::paper_k_for(dataset),
            budget_sim_bytes: budget,
            real_sleep: true,
            want_cls: true,
            ..Default::default()
        };
        let sida = Pipeline::new(bundle.clone(), dataset, pcfg)?.serve(&requests)?;
        let s = sida.stats.clone();
        let dense_sim = cost
            .sim_bytes(bundle.topology.total_param_bytes - bundle.topology.moe_param_bytes);
        let sida_peak = dense_sim + s.peak_device_bytes;
        let hit = sida_moe::metrics::report::fmt_rate(s.hit_rate());
        t.row(vec![
            dataset.into(),
            "sida".into(),
            format!("{:.2}", s.throughput()),
            fmt_secs(s.latency.p50()),
            fmt_secs(s.latency.p95()),
            fmt_secs(s.latency.p99()),
            hit,
            fmt_bytes(sida_peak),
            format!(
                "{:.1}",
                100.0 * (full_sim.saturating_sub(sida_peak)) as f64 / full_sim as f64
            ),
        ]);

        // Standard baseline on the same trace
        let bcfg =
            BaselineConfig { real_sleep: true, want_cls: true, ..Default::default() };
        let std_out =
            run_baseline(bundle.clone(), dataset, Method::Standard, &requests, &bcfg)?;
        let s = std_out.stats.clone();
        t.row(vec![
            dataset.into(),
            "standard".into(),
            format!("{:.2}", s.throughput()),
            fmt_secs(s.latency.p50()),
            fmt_secs(s.latency.p95()),
            fmt_secs(s.latency.p99()),
            "-".into(),
            fmt_bytes(full_sim),
            "0.0".into(),
        ]);

        // classifier agreement (fidelity proxy)
        let mut a = sida.per_request.clone();
        a.sort_by_key(|r| r.id);
        let mut bb = std_out.per_request.clone();
        bb.sort_by_key(|r| r.id);
        let agree = a
            .iter()
            .zip(bb.iter())
            .filter(|(x, y)| x.cls_pred == y.cls_pred)
            .count();
        println!(
            "{dataset}: classifier agreement SiDA vs Standard {}/{}",
            agree,
            requests.len()
        );
    }
    t.print();
    println!("total real tokens served per method: {total_tokens}");
    t.save_csv("target/bench_results/serve_trace.csv")?;
    Ok(())
}
