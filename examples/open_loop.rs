//! Open-loop load test: timed arrivals against the SiDA coordinator.
//!
//! Where `serve_trace` measures capacity (closed loop), this example
//! measures client-visible latency under a target offered load —
//! queueing + hash build + inference — sweeping the arrival rate up to
//! saturation.  Arrivals can be Poisson, bursty (Markov-modulated
//! on/off), or diurnal (sinusoidal rate), and a fraction of requests
//! can be marked interactive with an SLO deadline to exercise
//! admission control and deadline shedding.
//!
//! Run: `cargo run --release --example open_loop -- --model switch64 --rates 20,50,100 --arrivals bursty --interactive-frac 0.5`

use std::sync::Arc;

use sida_moe::config::ServeConfig;
use sida_moe::coordinator::{replay_open_loop, Pipeline, PipelineConfig};
use sida_moe::metrics::report::fmt_secs;
use sida_moe::metrics::Table;
use sida_moe::runtime::ModelBundle;
use sida_moe::util::cli::Cli;
use sida_moe::workload::{ArrivalProcess, ClassMix, Profile, TraceGenerator};

fn main() -> anyhow::Result<()> {
    sida_moe::util::logging::init();
    let cli = Cli::new("open_loop", "open-loop load test against the SiDA coordinator")
        .opt("model", "model config", "switch64")
        .opt("dataset", "dataset profile", "sst2")
        .opt("requests", "requests per rate", "20")
        .opt("rates", "comma-separated arrival rates (req/s)", "20,50,100")
        .opt("arrivals", "arrival process (poisson|bursty|diurnal)", "poisson")
        .opt("interactive-frac", "fraction of requests with an SLO deadline", "0")
        .opt("slo-deadline", "interactive completion deadline (ms)", "100")
        .opt("queue-cap", "admission queue bound", "32")
        .opt("prefetch-depth", "MoE layers the warmer may stage ahead (1 = baseline)", "3")
        .opt("host-bw", "modeled host staging bandwidth (bytes/s, 0 = reference PCIe)", "0");
    let args = cli.parse();
    let model = args.get_or("model", "switch64");
    let dataset = args.get_or("dataset", "sst2");
    let n = args.get_usize("requests", 20);
    let mix = ClassMix {
        interactive_frac: args.get_f64("interactive-frac", 0.0).clamp(0.0, 1.0),
        deadline_secs: args.get_f64("slo-deadline", 100.0) / 1e3,
    };

    let root = sida_moe::default_artifacts_root();
    if !root.join(&model).join("model.json").is_file() {
        println!("artifacts for {model} not built — run `make artifacts`");
        return Ok(());
    }
    let bundle = Arc::new(ModelBundle::load_named(&root, &model)?);
    let cfg = PipelineConfig {
        k_used: ServeConfig::paper_k_for(&dataset),
        want_cls: true,
        // sweep prefetch depth against tail latency: deeper staging
        // hides SSD promotions but spends shared window bandwidth
        prefetch_depth: args.get_usize("prefetch-depth", 3).max(1),
        host_bw: args.get_f64("host-bw", 0.0).max(0.0),
        ..Default::default()
    };
    let pipeline = Pipeline::new(bundle.clone(), &dataset, cfg)?;

    // warm the executables + cache once
    let mut gen = TraceGenerator::new(Profile::named(&dataset)?, bundle.topology.vocab, 7);
    let warm = gen.trace(4, ArrivalProcess::ClosedLoop);
    let _ = pipeline.serve(&warm)?;

    let mut t = Table::new(
        "open-loop latency under offered load",
        &[
            "rate (req/s)", "served", "rejected", "slo-rej", "shed",
            "mean queueing", "p50", "p99", "p99.9", "slo",
        ],
    );
    for rate_str in args.get_or("rates", "20,50,100").split(',') {
        let rate: f64 = rate_str.trim().parse().unwrap_or(20.0);
        let arrivals =
            ArrivalProcess::parse(&args.get_or("arrivals", "poisson"), rate)?;
        let mut gen =
            TraceGenerator::new(Profile::named(&dataset)?, bundle.topology.vocab, 11);
        let trace = gen.trace_classed(n, arrivals, mix);
        let report = replay_open_loop(&pipeline, &trace, args.get_usize("queue-cap", 32))?;
        let mut s = report.outcome.stats;
        t.row(vec![
            format!("{rate:.0}"),
            s.requests.to_string(),
            report.rejected.to_string(),
            report.rejected_slo.to_string(),
            report.shed.to_string(),
            fmt_secs(report.mean_queueing_secs),
            fmt_secs(s.latency.p50()),
            fmt_secs(s.latency.p99()),
            fmt_secs(s.latency.p999()),
            s.slo_attainment()
                .map_or_else(|| "-".into(), |a| format!("{:.0}%", 100.0 * a)),
        ]);
    }
    t.print();
    Ok(())
}
