//! Memory-budget sweep (Fig 11 as an interactive tool).
//!
//! Sweeps the simulated device budget for one model/dataset and compares
//! SiDA, Reactive (no prediction) and Layerwise (model-parallel
//! streaming) — the constrained-memory scenario the paper's intro
//! motivates (commodity 24-48GB GPUs serving 27-54GB models).
//!
//! Run: `cargo run --release --example memory_budget -- --model switch128`

use std::sync::Arc;

use sida_moe::baselines::{run_baseline, BaselineConfig, Method};
use sida_moe::config::ServeConfig;
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::memory::CostModel;
use sida_moe::metrics::report::fmt_bytes;
use sida_moe::metrics::Table;
use sida_moe::runtime::ModelBundle;
use sida_moe::util::cli::Cli;
use sida_moe::workload::{ArrivalProcess, Profile, TraceGenerator};

fn main() -> anyhow::Result<()> {
    sida_moe::util::logging::init();
    let cli = Cli::new("memory_budget", "budget sweep: SiDA vs offloading baselines")
        .opt("model", "model config", "switch128")
        .opt("dataset", "dataset profile", "sst2")
        .opt("requests", "requests per cell", "8")
        .opt("fracs", "comma-separated budget fractions of one MoE layer", "0.25,0.5,1,2");
    let args = cli.parse();
    let model = args.get_or("model", "switch128");
    let dataset = args.get_or("dataset", "sst2");
    let n = args.get_usize("requests", 8);

    let root = sida_moe::default_artifacts_root();
    if !root.join(&model).join("model.json").is_file() {
        println!("artifacts for {model} not built — run `make artifacts`");
        return Ok(());
    }
    let bundle = Arc::new(ModelBundle::load_named(&root, &model)?);
    let cost = CostModel::paper_scale(bundle.topology.expert_param_bytes);
    let layer_sim =
        cost.sim_bytes(bundle.topology.expert_param_bytes * bundle.topology.num_experts);
    println!(
        "{model}: one MoE layer = {} simulated; sweeping budgets",
        fmt_bytes(layer_sim)
    );

    let mut gen =
        TraceGenerator::new(Profile::named(&dataset)?, bundle.topology.vocab, 0);
    let requests = gen.trace(n, ArrivalProcess::ClosedLoop);

    let mut t = Table::new(
        "throughput vs budget",
        &["budget", "layerwise req/s", "reactive req/s", "sida req/s", "sida hit %"],
    );
    for frac_str in args.get_or("fracs", "0.25,0.5,1,2").split(',') {
        let frac: f64 = frac_str.trim().parse().unwrap_or(1.0);
        let budget = (layer_sim as f64 * frac) as usize;
        let bcfg = BaselineConfig {
            budget_sim_bytes: budget,
            real_sleep: true,
            ..Default::default()
        };
        let lw = run_baseline(bundle.clone(), &dataset, Method::Layerwise, &requests, &bcfg)?;
        let re = run_baseline(bundle.clone(), &dataset, Method::Reactive, &requests, &bcfg)?;
        let pcfg = PipelineConfig {
            k_used: ServeConfig::paper_k_for(&dataset),
            budget_sim_bytes: budget,
            real_sleep: true,
            ..Default::default()
        };
        let sida = Pipeline::new(bundle.clone(), &dataset, pcfg)?.serve(&requests)?;
        let s = &sida.stats;
        let hit = sida_moe::metrics::report::fmt_rate(s.hit_rate());
        t.row(vec![
            fmt_bytes(budget),
            format!("{:.2}", lw.stats.throughput()),
            format!("{:.2}", re.stats.throughput()),
            format!("{:.2}", s.throughput()),
            hit,
        ]);
    }
    t.print();
    Ok(())
}
