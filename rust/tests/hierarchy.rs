//! Unified tier-aware residency: end-to-end hierarchy reporting over
//! the serving pipeline (hermetic, synthetic bundle).
//!
//! The contract under test (ISSUE 5):
//! * per-tier byte occupancy respects the device and RAM budgets, and
//!   tier sums are conserved across demote/promote (no bytes leak from
//!   the ladder);
//! * the ladder-seconds attribution equals the cache's modeled transfer
//!   total — ONE timeline, no parallel promote accounting;
//! * `ServeStats` ladder seconds are reproduced bit-for-bit across
//!   `--pool` widths for every `--devices` in {1, 2, 4};
//! * shrinking `--ram-budget` strictly increases SSD-ladder exposure at
//!   a fixed device budget (the `fig_hierarchy` gate, in-test).

use std::sync::Arc;

use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};

fn deep_bundle() -> Arc<ModelBundle> {
    testkit::bundle(&SynthSpec::default().two_moe_layers()).unwrap()
}

/// Paper-scale simulated bytes of one expert — the canonical rule from
/// `bench_support` (what `fig_hierarchy` sizes its budgets with).
fn sim_expert_bytes(b: &ModelBundle) -> usize {
    sida_moe::bench_support::sim_expert_bytes(b).unwrap()
}

#[test]
fn tier_occupancy_respects_budgets_and_conserves_bytes() {
    let b = deep_bundle();
    let sim = sim_expert_bytes(&b);
    let device_budget = 3 * sim + 1024;
    let ram_budget = 2 * sim + 1024;
    let cfg = PipelineConfig {
        k_used: 2,
        budget_sim_bytes: device_budget,
        ram_budget_bytes: ram_budget,
        prefetch: false,
        pool_threads: 1,
        want_cls: true,
        ..Default::default()
    };
    let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&testkit::tiny_trace(&b, 12, 3)).unwrap();
    let h = &out.stats.hierarchy;
    assert!(h.device_bytes <= device_budget, "device tier over budget");
    assert!(h.ram_bytes <= ram_budget, "RAM tier over budget");
    assert!(out.stats.evictions > 0, "tight budget must evict");
    assert!(h.demotions_to_ram > 0, "evictions must demote into RAM");
    assert!(
        h.demotions_to_ssd > 0,
        "a 2-expert RAM window must overflow to SSD"
    );
    // conservation: every expert the ladder has seen sits in exactly one
    // tier, in whole (equal-sized) expert units
    let tracked = h.device_bytes + h.ram_bytes + h.ssd_bytes;
    let total = b.topology.moe_blocks.len() * b.topology.num_experts;
    // budgets carry +1024 slack, so allow the per-tier remainders
    assert!(tracked >= sim, "ladder tracked nothing");
    assert!(
        tracked <= total * sim,
        "ladder tracks more bytes than the expert pool holds"
    );
    assert_eq!(
        tracked % sim,
        0,
        "tier sums must be whole experts (tracked {tracked}, expert {sim})"
    );
    // the cache's own invariants include the exact-device-set drift check
    p.cache.check_invariants().unwrap();
}

#[test]
fn ladder_seconds_equal_modeled_transfer_on_one_timeline() {
    let b = deep_bundle();
    let sim = sim_expert_bytes(&b);
    let cfg = PipelineConfig {
        k_used: 2,
        budget_sim_bytes: 3 * sim + 1024,
        ram_budget_bytes: sim + 1024,
        prefetch: false,
        pool_threads: 1,
        want_cls: true,
        ..Default::default()
    };
    let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&testkit::tiny_trace(&b, 10, 5)).unwrap();
    let st = &out.stats;
    let h = &st.hierarchy;
    assert!(st.modeled_transfer_secs > 0.0);
    let drift = (h.ladder_secs() - st.modeled_transfer_secs).abs();
    assert!(
        drift <= 1e-9 * st.modeled_transfer_secs,
        "ladder attribution {} != modeled transfer {} (parallel timelines?)",
        h.ladder_secs(),
        st.modeled_transfer_secs
    );
    // and the tiers are priced differently: with RAM + SSD traffic both
    // present, SSD promotions must dominate per event
    if h.promotions_from_ram > 0 && h.promotions_from_ssd > 0 {
        let per_ram = h.ram_promote_secs / h.promotions_from_ram as f64;
        let per_ssd = h.ssd_promote_secs / h.promotions_from_ssd as f64;
        assert!(
            per_ssd > 5.0 * per_ram,
            "SSD promote ({per_ssd}) must cost several x a RAM promote ({per_ram})"
        );
    }
}

#[test]
fn ladder_seconds_bit_identical_across_pool_widths_and_device_counts() {
    // Generous budgets: no evictions, so every predicted expert is
    // fetched exactly once (from SSD) per holder.  The ladder seconds
    // must then be byte-for-byte reproducible across worker-pool widths
    // for every device count — concurrency must not change what the
    // ladder charges.
    let b = deep_bundle();
    let sim = sim_expert_bytes(&b);
    let reqs = testkit::tiny_trace(&b, 8, 21);
    for devices in [1usize, 2, 4] {
        let mut reference: Option<(u64, u64)> = None;
        for pool in [1usize, 4] {
            let cfg = PipelineConfig {
                k_used: 2,
                budget_sim_bytes: 64 * sim,
                devices,
                replicate_top: 1,
                pool_threads: pool,
                want_cls: true,
                ..Default::default()
            };
            let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
            let out = p.serve(&reqs).unwrap();
            let h = &out.stats.hierarchy;
            assert_eq!(
                out.stats.evictions, 0,
                "devices={devices} pool={pool}: generous budget must not evict"
            );
            let bits = (h.ram_promote_secs.to_bits(), h.ssd_promote_secs.to_bits());
            match &reference {
                None => reference = Some(bits),
                Some(want) => assert_eq!(
                    *want, bits,
                    "devices={devices}: ladder seconds differ across pool widths \
                     (pool={pool})"
                ),
            }
        }
    }
}

#[test]
fn shrinking_ram_budget_strictly_increases_ssd_exposure() {
    // the fig_hierarchy CI gate, as a test: fixed tight device budget,
    // RAM window from "holds everything" to zero
    let b = deep_bundle();
    let sim = sim_expert_bytes(&b);
    let total = b.topology.moe_blocks.len() * b.topology.num_experts;
    let reqs = testkit::tiny_trace(&b, 12, 9);
    let mut last: Option<f64> = None;
    let mut first: Option<f64> = None;
    for ram_experts in [total, 2, 0] {
        let cfg = PipelineConfig {
            k_used: 2,
            budget_sim_bytes: 4 * sim + 1024,
            ram_budget_bytes: ram_experts * sim + if ram_experts > 0 { 1024 } else { 0 },
            prefetch: false,
            pool_threads: 1,
            want_cls: true,
            ..Default::default()
        };
        let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
        let out = p.serve(&reqs).unwrap();
        let ssd = out.stats.hierarchy.ssd_promote_secs;
        if let Some(prev) = last {
            assert!(
                ssd >= prev - 1e-12,
                "ram={ram_experts} experts: SSD exposure {ssd} fell below {prev}"
            );
        }
        first.get_or_insert(ssd);
        last = Some(ssd);
    }
    assert!(
        last.unwrap() > first.unwrap() + 1e-12,
        "no RAM window must cost strictly more SSD ladder than a full one"
    );
}
