//! End-to-end pipeline tests: the two-thread SiDA coordinator over real
//! artifacts, plus cross-method behavioural checks.

use std::path::PathBuf;
use std::sync::Arc;

use sida_moe::baselines::{run_baseline, BaselineConfig, Method};
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::runtime::ModelBundle;
use sida_moe::workload::{ArrivalProcess, Profile, TraceGenerator};

fn artifacts_root() -> Option<PathBuf> {
    let root = sida_moe::default_artifacts_root();
    if root.join("switch8").join("model.json").is_file() {
        Some(root)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn bundle() -> Option<Arc<ModelBundle>> {
    let root = artifacts_root()?;
    Some(Arc::new(ModelBundle::load_named(&root, "switch8").expect("load bundle")))
}

fn trace(b: &ModelBundle, n: usize, seed: u64) -> Vec<sida_moe::workload::Request> {
    let mut gen =
        TraceGenerator::new(Profile::named("sst2").unwrap(), b.topology.vocab, seed);
    gen.trace(n, ArrivalProcess::ClosedLoop)
}

#[test]
fn pipeline_serves_every_request_exactly_once() {
    let Some(b) = bundle() else { return };
    let reqs = trace(&b, 10, 0);
    let p = Pipeline::new(b, "sst2", PipelineConfig::default()).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 10);
    let mut ids: Vec<u64> = out.per_request.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    // two-thread overlap: hash building happened
    assert!(out.stats.hash_build_secs > 0.0);
    // cache was exercised
    assert!(out.stats.cache_hits + out.stats.cache_misses > 0);
}

#[test]
fn pipeline_respects_memory_budget() {
    let Some(b) = bundle() else { return };
    let reqs = trace(&b, 8, 1);
    // budget of exactly 3 paper-scale experts
    let expert_sim = sida_moe::memory::CostModel::paper_scale(
        b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap(),
    )
    .sim_expert_bytes;
    let cfg = PipelineConfig {
        budget_sim_bytes: 3 * expert_sim + 1024,
        ..Default::default()
    };
    let p = Pipeline::new(b, "sst2", cfg).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 8);
    assert!(
        out.stats.peak_device_bytes <= 3 * expert_sim + 1024,
        "peak {} exceeds budget",
        out.stats.peak_device_bytes
    );
    assert!(out.stats.evictions > 0, "tight budget must evict");
    let cache = p.cache.lock().unwrap();
    cache.check_invariants().unwrap();
}

#[test]
fn prefetch_reduces_blocking_misses() {
    let Some(b) = bundle() else { return };
    let reqs = trace(&b, 12, 2);
    let with = Pipeline::new(
        b.clone(),
        "sst2",
        PipelineConfig { prefetch: true, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    let without = Pipeline::new(
        b,
        "sst2",
        PipelineConfig { prefetch: false, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    assert!(
        with.stats.blocking_misses <= without.stats.blocking_misses,
        "prefetch ({}) should not block more than no-prefetch ({})",
        with.stats.blocking_misses,
        without.stats.blocking_misses
    );
    // with prefetch, (nearly) all misses come from the prefetch stage
    assert!(with.stats.blocking_misses < with.stats.cache_misses.max(1));
}

#[test]
fn standard_invokes_every_expert_sida_does_not() {
    let Some(b) = bundle() else { return };
    let reqs = trace(&b, 4, 3);
    let e = b.topology.num_experts as u64;
    let m = b.topology.num_moe_layers() as u64;

    let std_out = run_baseline(
        b.clone(),
        "sst2",
        Method::Standard,
        &reqs,
        &BaselineConfig::default(),
    )
    .unwrap();
    assert_eq!(
        std_out.stats.phases.expert_invocations,
        e * m * reqs.len() as u64,
        "Standard must invoke every expert every layer (paper §2.3)"
    );

    let sida_out = Pipeline::new(b, "sst2", PipelineConfig::default())
        .unwrap()
        .serve(&reqs)
        .unwrap();
    assert!(
        sida_out.stats.phases.expert_invocations < std_out.stats.phases.expert_invocations,
        "SiDA must invoke fewer experts"
    );
}

#[test]
fn sida_and_baseline_agree_on_classifier_when_hash_is_accurate() {
    // cls predictions from SiDA (hash routing) should mostly agree with
    // the router-driven baseline — fidelity (Tab 4's mechanism)
    let Some(b) = bundle() else { return };
    let reqs = trace(&b, 10, 4);
    let bcfg = BaselineConfig { want_cls: true, ..Default::default() };
    let base = run_baseline(b.clone(), "sst2", Method::TutelLike, &reqs, &bcfg).unwrap();
    let pcfg = PipelineConfig { want_cls: true, ..Default::default() };
    let sida = Pipeline::new(b, "sst2", pcfg).unwrap().serve(&reqs).unwrap();
    let mut sida_sorted = sida.per_request.clone();
    sida_sorted.sort_by_key(|r| r.id);
    let mut base_sorted = base.per_request.clone();
    base_sorted.sort_by_key(|r| r.id);
    let agree = sida_sorted
        .iter()
        .zip(base_sorted.iter())
        .filter(|(a, b)| a.cls_pred == b.cls_pred)
        .count();
    assert!(
        agree * 10 >= reqs.len() * 8,
        "classifier agreement too low: {agree}/{}",
        reqs.len()
    );
}

#[test]
fn layerwise_transfers_more_than_sida_under_same_budget() {
    let Some(b) = bundle() else { return };
    let reqs = trace(&b, 6, 5);
    let expert_sim = sida_moe::memory::CostModel::paper_scale(
        b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap(),
    )
    .sim_expert_bytes;
    let budget = 6 * expert_sim; // below one full layer (8 experts)

    let lw = run_baseline(
        b.clone(),
        "sst2",
        Method::Layerwise,
        &reqs,
        &BaselineConfig { budget_sim_bytes: budget, ..Default::default() },
    )
    .unwrap();
    let sida = Pipeline::new(
        b,
        "sst2",
        PipelineConfig { budget_sim_bytes: budget, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    assert!(
        sida.stats.transferred_bytes < lw.stats.transferred_bytes,
        "SiDA ({}) must move fewer bytes than layer streaming ({})",
        sida.stats.transferred_bytes,
        lw.stats.transferred_bytes
    );
}

#[test]
fn server_state_serves_requests() {
    let Some(b) = bundle() else { return };
    let state =
        sida_moe::server::ServerState::new(b, "sst2", 8 << 30, 1).unwrap();
    let (label, secs) = state.serve_one(&[1, 40, 41, 42, 2]).unwrap();
    assert!(label < 4);
    assert!(secs > 0.0);
    let (label2, _) = state.serve_one(&[1, 40, 41, 42, 2]).unwrap();
    assert_eq!(label, label2, "same input, same prediction");
}
