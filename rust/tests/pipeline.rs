//! End-to-end pipeline tests over the synthetic testkit bundle: the
//! two-thread SiDA coordinator, prefetch-vs-on-demand miss accounting,
//! budget/eviction behavior, queue backpressure, and cross-method
//! behavioural checks — all hermetic (no artifacts, no PJRT).

use sida_moe::baselines::{run_baseline, BaselineConfig, Method};
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::memory::CostModel;
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, TINY_PROFILE};
use sida_moe::workload::Request;

fn trace(b: &ModelBundle, n: usize, seed: u64) -> Vec<Request> {
    testkit::tiny_trace(b, n, seed)
}

fn expert_sim_bytes(b: &ModelBundle) -> usize {
    CostModel::paper_scale(
        b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap(),
    )
    .sim_expert_bytes
}

#[test]
fn pipeline_serves_every_request_exactly_once() {
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 10, 0);
    let p = Pipeline::new(b, TINY_PROFILE, PipelineConfig::default()).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 10);
    let mut ids: Vec<u64> = out.per_request.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    // two-thread overlap: hash building happened
    assert!(out.stats.hash_build_secs > 0.0);
    // cache was exercised
    assert!(out.stats.cache_hits + out.stats.cache_misses > 0);
}

#[test]
fn pipeline_preserves_arrival_order() {
    // the bounded queues are FIFO end to end: the inference thread must
    // complete requests in submission order
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 12, 7);
    let p = Pipeline::new(b, TINY_PROFILE, PipelineConfig::default()).unwrap();
    let out = p.serve(&reqs).unwrap();
    let served: Vec<u64> = out.per_request.iter().map(|r| r.id).collect();
    assert_eq!(served, (0..12).collect::<Vec<u64>>());
}

#[test]
fn pipeline_respects_memory_budget_and_evicts() {
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 8, 1);
    let expert_sim = expert_sim_bytes(&b);
    // budget of exactly 2 paper-scale experts (pool holds 8)
    let budget = 2 * expert_sim + 1024;
    let cfg = PipelineConfig { budget_sim_bytes: budget, ..Default::default() };
    let p = Pipeline::new(b, TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 8);
    assert!(
        out.stats.peak_device_bytes <= budget,
        "peak {} exceeds budget {budget}",
        out.stats.peak_device_bytes
    );
    assert!(out.stats.evictions > 0, "tight budget must evict");
    p.cache.check_invariants().unwrap();
    assert!(p.cache.used() <= p.cache.budget());
}

#[test]
fn prefetch_strictly_reduces_blocking_misses() {
    // The paper's core pipelining claim, on the synthetic bundle: with
    // the look-ahead prefetch stage, no fetch ever stalls the inference
    // thread; without it, every cold fetch is a blocking miss.
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 12, 2);
    let with = Pipeline::new(
        b.clone(),
        TINY_PROFILE,
        PipelineConfig { prefetch: true, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    let without = Pipeline::new(
        b,
        TINY_PROFILE,
        PipelineConfig { prefetch: false, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    assert!(without.stats.blocking_misses > 0, "cold cache must miss on demand");
    assert_eq!(
        with.stats.blocking_misses, 0,
        "prefetch left {} fetches on the critical path",
        with.stats.blocking_misses
    );
    assert!(with.stats.blocking_misses < without.stats.blocking_misses);
    // both variants computed the same requests
    assert_eq!(with.stats.requests, without.stats.requests);
}

#[test]
fn queue_depth_one_applies_backpressure_and_still_serves_all() {
    // hash-table queue bounded at depth 1: the hash-building thread can
    // be at most one table ahead; everything still flows exactly once
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 16, 4);
    let cfg = PipelineConfig { queue_depth: 1, ..Default::default() };
    let p = Pipeline::new(b, TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 16);
    let served: Vec<u64> = out.per_request.iter().map(|r| r.id).collect();
    assert_eq!(served, (0..16).collect::<Vec<u64>>());
}

#[test]
fn standard_invokes_every_expert_sida_does_not() {
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 4, 3);
    let e = b.topology.num_experts as u64;
    let m = b.topology.num_moe_layers() as u64;

    let std_out = run_baseline(
        b.clone(),
        TINY_PROFILE,
        Method::Standard,
        &reqs,
        &BaselineConfig::default(),
    )
    .unwrap();
    assert_eq!(
        std_out.stats.phases.expert_invocations,
        e * m * reqs.len() as u64,
        "Standard must invoke every expert every layer (paper §2.3)"
    );

    let sida_out = Pipeline::new(b, TINY_PROFILE, PipelineConfig::default())
        .unwrap()
        .serve(&reqs)
        .unwrap();
    assert!(
        sida_out.stats.phases.expert_invocations < std_out.stats.phases.expert_invocations,
        "SiDA must invoke fewer experts"
    );
}

#[test]
fn sida_classifier_matches_baseline_with_perfect_hash() {
    // agreement = 1.0: not just "mostly agree" — every classifier
    // prediction must match the router-driven baseline
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 10, 4);
    let bcfg = BaselineConfig { want_cls: true, ..Default::default() };
    let base = run_baseline(b.clone(), TINY_PROFILE, Method::TutelLike, &reqs, &bcfg).unwrap();
    let pcfg = PipelineConfig { want_cls: true, ..Default::default() };
    let sida = Pipeline::new(b, TINY_PROFILE, pcfg).unwrap().serve(&reqs).unwrap();
    let mut sida_sorted = sida.per_request.clone();
    sida_sorted.sort_by_key(|r| r.id);
    let mut base_sorted = base.per_request.clone();
    base_sorted.sort_by_key(|r| r.id);
    for (s, bl) in sida_sorted.iter().zip(base_sorted.iter()) {
        assert_eq!(s.cls_pred, bl.cls_pred, "request {} diverged", s.id);
    }
}

#[test]
fn degraded_hash_lowers_classifier_fidelity_mechanism() {
    // With a 0%-agreement hash the pipeline still serves everything;
    // predictions go through the wrong experts (Tab 4's failure mode).
    let b = testkit::bundle_with_agreement(0.0);
    let reqs = trace(&b, 8, 6);
    let bcfg = BaselineConfig { want_cls: true, ..Default::default() };
    let base = run_baseline(b.clone(), TINY_PROFILE, Method::TutelLike, &reqs, &bcfg).unwrap();
    let pcfg = PipelineConfig { want_cls: true, ..Default::default() };
    let sida = Pipeline::new(b, TINY_PROFILE, pcfg).unwrap().serve(&reqs).unwrap();
    assert_eq!(sida.stats.requests, 8);
    // logits differ per request even if coarse argmax sometimes agrees;
    // at tiny dims we just require the runs to be well-formed and the
    // baseline unaffected
    assert_eq!(base.stats.requests, 8);
}

#[test]
fn all_resident_baselines_agree_with_different_memory_traffic() {
    // same logits, different memory traffic: Standard (host literals),
    // DeepSpeed-like (staged, fixed bucket) and Tutel-like (staged,
    // adaptive bucket) must predict identically; the offloading methods
    // move bytes while the all-resident ones do not.
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 6, 5);
    let cfg = BaselineConfig { want_cls: true, ..Default::default() };
    let std_out = run_baseline(b.clone(), TINY_PROFILE, Method::Standard, &reqs, &cfg).unwrap();
    let ds_out =
        run_baseline(b.clone(), TINY_PROFILE, Method::DeepspeedLike, &reqs, &cfg).unwrap();
    let tut_out = run_baseline(b.clone(), TINY_PROFILE, Method::TutelLike, &reqs, &cfg).unwrap();
    for ((a, c), d) in std_out
        .per_request
        .iter()
        .zip(ds_out.per_request.iter())
        .zip(tut_out.per_request.iter())
    {
        assert_eq!(a.cls_pred, c.cls_pred);
        assert_eq!(a.cls_pred, d.cls_pred);
    }
    // all-resident methods report the full MoE footprint, no transfers
    assert_eq!(std_out.stats.transferred_bytes, 0);
    assert_eq!(ds_out.stats.transferred_bytes, 0);
    assert!(ds_out.stats.peak_device_bytes > 0);

    // offloading under the same tight budget DOES move bytes
    let expert_sim = expert_sim_bytes(&b);
    let tight = BaselineConfig {
        budget_sim_bytes: 3 * expert_sim + 1024,
        want_cls: true,
        ..Default::default()
    };
    let react =
        run_baseline(b.clone(), TINY_PROFILE, Method::Reactive, &reqs, &tight).unwrap();
    assert!(react.stats.transferred_bytes > 0);
    for (a, r) in tut_out.per_request.iter().zip(react.per_request.iter()) {
        assert_eq!(a.cls_pred, r.cls_pred, "offloading must not change predictions");
    }
}

#[test]
fn layerwise_transfers_more_than_sida_under_same_budget() {
    let b = testkit::tiny_bundle();
    let reqs = trace(&b, 6, 5);
    let expert_sim = expert_sim_bytes(&b);
    let budget = 6 * expert_sim; // below one full layer (8 experts)

    let lw = run_baseline(
        b.clone(),
        TINY_PROFILE,
        Method::Layerwise,
        &reqs,
        &BaselineConfig { budget_sim_bytes: budget, ..Default::default() },
    )
    .unwrap();
    let sida = Pipeline::new(
        b,
        TINY_PROFILE,
        PipelineConfig { budget_sim_bytes: budget, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    assert!(
        sida.stats.transferred_bytes < lw.stats.transferred_bytes,
        "SiDA ({}) must move fewer bytes than layer streaming ({})",
        sida.stats.transferred_bytes,
        lw.stats.transferred_bytes
    );
}

#[test]
fn two_moe_layer_pipeline_serves_and_prefetches() {
    let b = testkit::bundle(&testkit::SynthSpec::default().two_moe_layers()).unwrap();
    let reqs = trace(&b, 6, 8);
    let p = Pipeline::new(b, TINY_PROFILE, PipelineConfig::default()).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 6);
    assert_eq!(out.stats.blocking_misses, 0, "prefetch covers both MoE layers");
    assert!(out.stats.cache_misses > 0);
}
