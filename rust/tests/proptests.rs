//! Property-based tests over the pure-logic substrates (no artifacts
//! needed): device pool, eviction policies, hash table, batcher, JSON
//! round-trips, cost model, histogram quantiles, workload structure.
//!
//! Uses the in-repo `util::prop` harness (the vendored crate set has no
//! proptest); failing cases shrink and report a replayable seed.

use std::collections::HashSet;

use sida_moe::coordinator::{AdmitOutcome, Batcher, HashTable};
use sida_moe::experts::{make_policy, ExpertCache, ExpertKey};
use sida_moe::memory::{CostModel, DevicePool, ReserveOutcome};
use sida_moe::metrics::LatencyHistogram;
use sida_moe::runtime::stage_expert_parts;
use sida_moe::util::json::Json;
use sida_moe::util::prop::{shrink_vec, Prop};
use sida_moe::util::rng::Rng;
use sida_moe::workload::Request;

// ---------------------------------------------------------------------------
// DevicePool: used <= budget under arbitrary reserve/release sequences
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum PoolOp {
    Reserve(u8, usize),
    Release(u8),
}

fn gen_pool_ops(r: &mut Rng) -> Vec<PoolOp> {
    (0..r.usize_below(60))
        .map(|_| {
            if r.bool(0.6) {
                PoolOp::Reserve(r.below(12) as u8, r.usize_below(40))
            } else {
                PoolOp::Release(r.below(12) as u8)
            }
        })
        .collect()
}

#[test]
fn pool_never_exceeds_budget() {
    Prop::new(256).check(
        "pool: used <= budget, accounting consistent",
        gen_pool_ops,
        |v| shrink_vec(v),
        |ops| {
            let budget = 100;
            let mut pool: DevicePool<u8> = DevicePool::new(budget);
            let mut model: std::collections::HashMap<u8, usize> = Default::default();
            for op in ops {
                match op {
                    PoolOp::Reserve(k, b) => {
                        let out = pool.reserve(*k, *b);
                        match out {
                            ReserveOutcome::Ok => {
                                model.insert(*k, *b);
                            }
                            ReserveOutcome::AlreadyResident => {
                                if !model.contains_key(k) {
                                    return Err("AlreadyResident but model disagrees".into());
                                }
                            }
                            ReserveOutcome::WouldExceed => {
                                let used: usize = model.values().sum();
                                if used + b <= budget {
                                    return Err(format!(
                                        "WouldExceed but {used}+{b} <= {budget}"
                                    ));
                                }
                            }
                        }
                    }
                    PoolOp::Release(k) => {
                        let freed = pool.release(k);
                        let want = model.remove(k).unwrap_or(0);
                        if freed != want {
                            return Err(format!("release {k}: {freed} != {want}"));
                        }
                    }
                }
                let used: usize = model.values().sum();
                if pool.used() != used {
                    return Err(format!("used {} != model {used}", pool.used()));
                }
                if pool.used() > budget {
                    return Err("budget exceeded".into());
                }
                if pool.peak() < pool.used() {
                    return Err("peak below used".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Eviction policies: victims are resident, never pinned
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum CacheOp {
    Insert(u8),
    Access(u8),
    Evict,
    Pin(u8),
    Unpin(u8),
}

fn gen_cache_ops(r: &mut Rng) -> Vec<CacheOp> {
    (0..r.usize_below(80))
        .map(|_| match r.below(5) {
            0 | 1 => CacheOp::Insert(r.below(10) as u8),
            2 => CacheOp::Access(r.below(10) as u8),
            3 => CacheOp::Evict,
            _ => {
                if r.bool(0.5) {
                    CacheOp::Pin(r.below(10) as u8)
                } else {
                    CacheOp::Unpin(r.below(10) as u8)
                }
            }
        })
        .collect()
}

#[test]
fn policies_never_evict_pinned_and_track_membership() {
    for policy_name in ["fifo", "lru", "lfu", "clock"] {
        Prop::new(128).check(
            policy_name,
            gen_cache_ops,
            |v| shrink_vec(v),
            |ops| {
                let mut policy = make_policy(policy_name).unwrap();
                let mut resident: HashSet<ExpertKey> = HashSet::new();
                let mut pinned: HashSet<ExpertKey> = HashSet::new();
                for op in ops {
                    match op {
                        CacheOp::Insert(e) => {
                            let k = ExpertKey::new(0, *e as usize);
                            if resident.insert(k) {
                                policy.on_insert(k);
                            }
                        }
                        CacheOp::Access(e) => {
                            let k = ExpertKey::new(0, *e as usize);
                            if resident.contains(&k) {
                                policy.on_access(k);
                            }
                        }
                        CacheOp::Pin(e) => {
                            let k = ExpertKey::new(0, *e as usize);
                            if resident.contains(&k) {
                                pinned.insert(k);
                            }
                        }
                        CacheOp::Unpin(e) => {
                            pinned.remove(&ExpertKey::new(0, *e as usize));
                        }
                        CacheOp::Evict => match policy.victim(&pinned) {
                            Some(v) => {
                                if !resident.remove(&v) {
                                    return Err(format!("victim {v:?} not resident"));
                                }
                                if pinned.contains(&v) {
                                    return Err(format!("evicted pinned {v:?}"));
                                }
                            }
                            None => {
                                if !resident.iter().all(|k| pinned.contains(k)) {
                                    return Err(
                                        "no victim though unpinned entries exist".into()
                                    );
                                }
                            }
                        },
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn fifo_evicts_in_insertion_order() {
    Prop::new(128).check(
        "fifo order",
        |r| {
            let mut seen = HashSet::new();
            let mut v = Vec::new();
            for _ in 0..r.usize_below(12) {
                let e = r.below(100) as usize;
                if seen.insert(e) {
                    v.push(e);
                }
            }
            v
        },
        |v| shrink_vec(v),
        |inserts| {
            let mut policy = make_policy("fifo").unwrap();
            for &e in inserts {
                policy.on_insert(ExpertKey::new(1, e));
            }
            let none = HashSet::new();
            for &want in inserts {
                match policy.victim(&none) {
                    Some(got) if got.expert == want => {}
                    other => return Err(format!("expected {want}, got {other:?}")),
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Cost model
// ---------------------------------------------------------------------------

#[test]
fn cost_model_monotone_in_bytes() {
    Prop::new(128).check(
        "transfer cost monotone",
        |r| (r.usize_below(1 << 20), r.usize_below(1 << 20)),
        |_| vec![],
        |(a, b)| {
            let cm = CostModel::paper_scale(66_048);
            let (lo, hi) = (a.min(b), a.max(b));
            if cm.transfer_secs(*lo.min(&hi)) <= cm.transfer_secs(*hi.max(&lo)) + 1e-12 {
                Ok(())
            } else {
                Err("not monotone".into())
            }
        },
    );
}

// ---------------------------------------------------------------------------
// HashTable: prefetch set == union of per-token experts
// ---------------------------------------------------------------------------

#[test]
fn hash_table_predicted_set_is_union() {
    Prop::new(128).check(
        "hash table union",
        |r| {
            let l = 1 + r.usize_below(20);
            let m = 1 + r.usize_below(3);
            let k = 1 + r.usize_below(4);
            let e = 4 + r.usize_below(12);
            let idx: Vec<i32> = (0..l * m * k).map(|_| r.below(e as u64) as i32).collect();
            let alpha: Vec<f32> = (0..l * m * k).map(|_| r.f64() as f32).collect();
            let mask: Vec<f32> =
                (0..l).map(|_| if r.bool(0.8) { 1.0 } else { 0.0 }).collect();
            (l, m, k, idx, alpha, mask)
        },
        |_| vec![],
        |(l, m, k, idx, alpha, mask)| {
            let t = HashTable::new(0, *l, *m, *k, idx.clone(), alpha.clone(), 0.0)
                .map_err(|e| e.to_string())?;
            for layer in 0..*m {
                for k_used in 1..=*k {
                    let got = t.predicted_experts(layer, k_used, mask);
                    let mut want: Vec<usize> = Vec::new();
                    for tok in 0..*l {
                        if mask[tok] == 0.0 {
                            continue;
                        }
                        for r in 0..k_used {
                            want.push(t.expert_at(tok, layer, r));
                        }
                    }
                    want.sort_unstable();
                    want.dedup();
                    if got != want {
                        return Err(format!("layer {layer} k {k_used}: {got:?} != {want:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Batcher: exactly-once, order-preserving under interleaved fill/drain
// ---------------------------------------------------------------------------

#[test]
fn batcher_exactly_once_in_order() {
    Prop::new(128).check(
        "batcher exactly once",
        |r| (1 + r.usize_below(30), 1 + r.usize_below(40)),
        |_| vec![],
        |(cap, n)| {
            let mut b = Batcher::new(*cap);
            let mut next_out = 0u64;
            let mut next_in = 0u64;
            while next_out < *n as u64 {
                while next_in < *n as u64 {
                    let req = Request {
                        id: next_in,
                        ids: vec![1, 2],
                        n_tokens: 2,
                        label: 0,
                        arrival: 0.0,
                        class: Default::default(),
                    };
                    if b.admit(req) == AdmitOutcome::Rejected {
                        break;
                    }
                    next_in += 1;
                }
                match b.next() {
                    Some(r) if r.id == next_out => next_out += 1,
                    Some(r) => return Err(format!("out of order: {} != {next_out}", r.id)),
                    None => return Err("empty while work remains".into()),
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// JSON round-trip on random documents
// ---------------------------------------------------------------------------

fn gen_json(r: &mut Rng, depth: usize) -> Json {
    if depth == 0 {
        return match r.below(4) {
            0 => Json::Null,
            1 => Json::Bool(r.bool(0.5)),
            2 => Json::Num((r.below(2_000_000) as f64) / 4.0 - 1000.0),
            _ => Json::Str(format!("s{}", r.below(1000))),
        };
    }
    match r.below(6) {
        0 => Json::Arr((0..r.usize_below(5)).map(|_| gen_json(r, depth - 1)).collect()),
        1 => Json::Obj(
            (0..r.usize_below(5))
                .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                .collect(),
        ),
        _ => gen_json(r, 0),
    }
}

#[test]
fn json_roundtrip_random_documents() {
    Prop::new(256).check(
        "json roundtrip",
        |r| gen_json(r, 3),
        |_| vec![],
        |doc| {
            let text = doc.to_string();
            let back = Json::parse(&text).map_err(|e| e.to_string())?;
            if back == *doc {
                Ok(())
            } else {
                Err(format!("{back:?} != {doc:?}"))
            }
        },
    );
}

// ---------------------------------------------------------------------------
// Histogram quantiles vs naive reference
// ---------------------------------------------------------------------------

#[test]
fn histogram_quantiles_match_reference() {
    Prop::new(128).check(
        "histogram quantiles",
        |r| (0..1 + r.usize_below(200)).map(|_| r.f64() * 100.0).collect::<Vec<f64>>(),
        |v| shrink_vec(v),
        |samples| {
            let mut h = LatencyHistogram::default();
            for &s in samples {
                h.record(s);
            }
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.5, 0.95, 0.99, 1.0] {
                let rank = ((q * sorted.len() as f64).ceil() as usize).max(1) - 1;
                let want = sorted[rank.min(sorted.len() - 1)];
                let got = h.quantile(q);
                if (got - want).abs() > 1e-12 {
                    return Err(format!("q{q}: {got} != {want}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Workload structure
// ---------------------------------------------------------------------------

#[test]
fn trace_requests_well_formed() {
    use sida_moe::workload::{ArrivalProcess, Profile, TraceGenerator};
    Prop::new(32).check(
        "trace well-formed",
        |r| (r.next_u64(), ["sst2", "mrpc", "multirc"][r.usize_below(3)]),
        |_| vec![],
        |(seed, profile)| {
            let p = Profile::named(profile).unwrap();
            let seq_len = p.seq_len;
            let mut g = TraceGenerator::new(p, 256, *seed);
            for req in g.trace(10, ArrivalProcess::ClosedLoop) {
                if req.ids.len() != seq_len {
                    return Err("bad len".into());
                }
                if req.ids[0] != 1 {
                    return Err("no BOS".into());
                }
                let n = req.n_tokens;
                if req.ids[n - 1] != 2 {
                    return Err("no EOS".into());
                }
                if req.ids[n..].iter().any(|&t| t != 0) {
                    return Err("garbage after EOS".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Full ExpertCache over the testkit bundle: budget invariant + pinned
// experts never evicted, under arbitrary ensure/pin/unpin/invalidate
// sequences, for every eviction policy
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum FullCacheOp {
    /// ensure expert e resident (blocking flag varies)
    Ensure(u8, bool),
    /// pin expert e if resident (bounded so ensure can always evict)
    Pin(u8),
    Unpin(u8),
    /// drop expert e if resident and not pinned
    Invalidate(u8),
}

fn gen_full_cache_ops(r: &mut Rng) -> Vec<FullCacheOp> {
    (0..r.usize_below(60))
        .map(|_| match r.below(6) {
            0 | 1 | 2 => FullCacheOp::Ensure(r.below(8) as u8, r.bool(0.5)),
            3 => FullCacheOp::Pin(r.below(8) as u8),
            4 => FullCacheOp::Unpin(r.below(8) as u8),
            _ => FullCacheOp::Invalidate(r.below(8) as u8),
        })
        .collect()
}

#[test]
fn expert_cache_budget_and_pinning_invariants_all_policies() {
    let bundle = sida_moe::testkit::tiny_bundle();
    let block = bundle.topology.moe_blocks[0];
    let num_experts = bundle.topology.num_experts;
    let real = bundle.weights.expert_bytes(block, 0).unwrap();
    for policy_name in ["fifo", "lru", "lfu", "clock"] {
        let bundle = bundle.clone();
        Prop::new(48).check(
            &format!("expert cache invariants ({policy_name})"),
            gen_full_cache_ops,
            |v| shrink_vec(v),
            |ops| {
                // room for exactly 3 experts; at most 2 ever pinned, so
                // ensure always has an evictable victim available
                let mut cache = ExpertCache::new(
                    3 * real + 64,
                    CostModel::physical(real),
                    make_policy(policy_name).unwrap(),
                );
                let mut pinned: Vec<ExpertKey> = Vec::new();
                for op in ops {
                    match op {
                        FullCacheOp::Ensure(e, blocking) => {
                            let expert = *e as usize % num_experts;
                            let key = ExpertKey::new(block, expert);
                            let engine = bundle.engine.clone();
                            let weights = bundle.weights.clone();
                            cache
                                .ensure(key, real, *blocking, || {
                                    stage_expert_parts(&engine, &weights, block, expert)
                                })
                                .map_err(|err| format!("ensure {expert}: {err}"))?;
                            if !cache.contains(&key) {
                                return Err(format!("{expert} not resident after ensure"));
                            }
                        }
                        FullCacheOp::Pin(e) => {
                            let key = ExpertKey::new(block, *e as usize % num_experts);
                            if cache.contains(&key) && pinned.len() < 2 && !pinned.contains(&key)
                            {
                                cache.pin(key);
                                pinned.push(key);
                            }
                        }
                        FullCacheOp::Unpin(e) => {
                            let key = ExpertKey::new(block, *e as usize % num_experts);
                            cache.unpin(&key);
                            pinned.retain(|k| *k != key);
                        }
                        FullCacheOp::Invalidate(e) => {
                            let key = ExpertKey::new(block, *e as usize % num_experts);
                            if !pinned.contains(&key) {
                                cache.invalidate(&key);
                            }
                        }
                    }
                    cache.check_invariants().map_err(|err| err.to_string())?;
                    if cache.used() > cache.budget() {
                        return Err(format!(
                            "budget violated: {} > {}",
                            cache.used(),
                            cache.budget()
                        ));
                    }
                    for key in &pinned {
                        if !cache.contains(key) {
                            return Err(format!("pinned {key:?} was evicted"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

// ---------------------------------------------------------------------------
// Drift-kill: after arbitrary interleavings of ensure / prefetch /
// evict(invalidate) the residency ledger's Device tier is EXACTLY the
// cache's resident set, for EVERY (cache policy x RAM policy) pair —
// the invariant the PR-4 modeled FIFO side-car could not hold.  Tier
// byte sums are conserved and RAM respects its budget throughout.
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum LadderOp {
    /// blocking ensure (the compute path's fetch)
    Ensure(u8),
    /// non-blocking ensure (the prefetch/warmer path's fetch)
    Prefetch(u8),
    /// explicit device-tier eviction
    Invalidate(u8),
}

fn gen_ladder_ops(r: &mut Rng) -> Vec<LadderOp> {
    (0..r.usize_below(70))
        .map(|_| match r.below(5) {
            0 | 1 => LadderOp::Ensure(r.below(8) as u8),
            2 | 3 => LadderOp::Prefetch(r.below(8) as u8),
            _ => LadderOp::Invalidate(r.below(8) as u8),
        })
        .collect()
}

#[test]
fn cache_resident_set_is_exactly_the_ledger_device_tier_for_all_policies() {
    use sida_moe::memory::Tier;

    let bundle = sida_moe::testkit::tiny_bundle();
    let block = bundle.topology.moe_blocks[0];
    let num_experts = bundle.topology.num_experts;
    let real = bundle.weights.expert_bytes(block, 0).unwrap();
    for policy_name in ["fifo", "lru", "lfu", "clock"] {
        for ram_policy_name in ["fifo", "lfu"] {
            let bundle = bundle.clone();
            Prop::new(32).check(
                &format!("ledger drift ({policy_name} device / {ram_policy_name} ram)"),
                gen_ladder_ops,
                |v| shrink_vec(v),
                |ops| {
                    // device: 3 experts; RAM window: 2 — demotions must
                    // overflow to SSD regularly
                    let mut cache = ExpertCache::with_hierarchy(
                        3 * real + 64,
                        CostModel::physical(real),
                        make_policy(policy_name).unwrap(),
                        2 * real + 32,
                        make_policy(ram_policy_name).unwrap(),
                    );
                    let mut seen: HashSet<usize> = HashSet::new();
                    for op in ops {
                        match op {
                            LadderOp::Ensure(e) | LadderOp::Prefetch(e) => {
                                let expert = *e as usize % num_experts;
                                let key = ExpertKey::new(block, expert);
                                let blocking = matches!(op, LadderOp::Ensure(_));
                                let engine = bundle.engine.clone();
                                let weights = bundle.weights.clone();
                                cache
                                    .ensure(key, real, blocking, || {
                                        stage_expert_parts(&engine, &weights, block, expert)
                                    })
                                    .map_err(|err| format!("ensure {expert}: {err}"))?;
                                seen.insert(expert);
                                if cache.tier_of(&key) != Tier::Device {
                                    return Err(format!(
                                        "{expert} resident but ledger says {:?}",
                                        cache.tier_of(&key)
                                    ));
                                }
                            }
                            LadderOp::Invalidate(e) => {
                                let expert = *e as usize % num_experts;
                                let key = ExpertKey::new(block, expert);
                                let was_resident = cache.contains(&key);
                                cache.invalidate(&key);
                                if was_resident && cache.tier_of(&key) == Tier::Device {
                                    return Err(format!(
                                        "{expert} evicted but ledger kept it on Device"
                                    ));
                                }
                            }
                        }
                        // the drift check proper lives in
                        // check_invariants: ledger Device tier == the
                        // resident set, exactly, plus per-tier sums
                        cache
                            .check_invariants()
                            .map_err(|err| format!("{err:#}"))?;
                        let h = cache.hierarchy_stats();
                        if h.device_bytes != cache.used() {
                            return Err(format!(
                                "ledger device bytes {} != cache used {}",
                                h.device_bytes,
                                cache.used()
                            ));
                        }
                        // conservation: every key ever fetched sits in
                        // exactly one tier (physical cost model: sim ==
                        // real bytes)
                        let tracked = h.device_bytes + h.ram_bytes + h.ssd_bytes;
                        if tracked != seen.len() * real {
                            return Err(format!(
                                "tier sums {tracked} != {} known experts x {real}",
                                seen.len()
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Hash oracle agreement knob: measured top-1 agreement tracks the
// configured rate, and corrupted predictions stay within the expert pool
// ---------------------------------------------------------------------------

#[test]
fn hash_agreement_rate_tracks_configuration() {
    use sida_moe::coordinator::HashBuilder;
    use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};

    for (agreement, lo, hi) in [(1.0f64, 1.0f64, 1.0f64), (0.0, 0.0, 0.0), (0.5, 0.2, 0.8)] {
        let b = sida_moe::testkit::bundle_with_agreement(agreement);
        let runner = ModelRunner::new(b.clone(), sida_moe::testkit::TINY_PROFILE).unwrap();
        let builder = HashBuilder::new(&b, sida_moe::testkit::TINY_PROFILE).unwrap();
        let staged = runner.stage_all_experts().unwrap();
        let mut agree = 0usize;
        let mut total = 0usize;
        for seed in 0..10 {
            let req = sida_moe::testkit::tiny_trace(&b, 1, seed).remove(0);
            let mut p = ExpertProvider::AllResident(&staged);
            let out = runner
                .forward(&req.ids, None, &mut p, ForwardOptions::default())
                .unwrap();
            let table = builder.build(seed, &req.ids).unwrap();
            let mask = ModelRunner::mask_of(&req.ids);
            for (m, routing) in out.routing.iter().enumerate() {
                for t in 0..runner.seq_len {
                    if mask[t] == 0.0 {
                        continue;
                    }
                    let predicted = table.expert_at(t, m, 0);
                    if predicted >= b.topology.num_experts {
                        panic!("prediction {predicted} outside expert pool");
                    }
                    if predicted == routing.top1[t] {
                        agree += 1;
                    }
                    total += 1;
                }
            }
        }
        let rate = agree as f64 / total as f64;
        assert!(
            (lo..=hi).contains(&rate),
            "agreement {agreement}: measured {rate} outside [{lo}, {hi}] over {total} tokens"
        );
    }
}

// ---------------------------------------------------------------------------
// On-disk expert store: interleaved promote/demote storms across threads
// on overlapping keys — exactly one blob per content hash, every
// successful read verifies, and the byte accounting matches a du-style
// enumeration of the blobs directory (ISSUE 7).
// ---------------------------------------------------------------------------

/// Deterministic, per-key-distinct payload for store storms.
fn storm_payload(e: usize) -> Vec<u8> {
    format!("expert-{e}-payload-").repeat(3 + e).into_bytes()
}

/// `du` over the store's blobs directory; also verifies content
/// addressing file-by-file: every blob's name IS the FNV-1a hash of the
/// bytes inside it (a torn or half-published blob cannot satisfy this).
fn du_verified(dir: &std::path::Path) -> u64 {
    use sida_moe::memory::fnv1a64;
    let mut total = 0u64;
    let mut seen = HashSet::new();
    for entry in std::fs::read_dir(dir.join("blobs")).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_str().unwrap().to_string();
        let hash = u64::from_str_radix(name.strip_suffix(".blob").unwrap(), 16).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert_eq!(fnv1a64(&data), hash, "blob {name} does not hash to its own name");
        assert!(seen.insert(hash), "duplicate blob for hash {hash:016x}");
        total += data.len() as u64;
    }
    total
}

#[test]
fn prop_store_concurrent_writers_publish_exactly_once_per_hash() {
    use sida_moe::memory::{ExpertStore, ReadOutcome};

    let dir = std::env::temp_dir()
        .join(format!("sida_prop_store_{}", std::process::id()));
    Prop::new(16).check(
        "store: concurrent put/get storm",
        |r| {
            // 4 threads x up to 30 ops over <= 6 overlapping keys
            (0..4)
                .map(|_| (0..r.usize_below(30)).map(|_| r.below(6) as u8).collect())
                .collect::<Vec<Vec<u8>>>()
        },
        |v| shrink_vec(v),
        |plans| {
            let _ = std::fs::remove_dir_all(&dir);
            let store = ExpertStore::open(&dir, 0).unwrap();
            std::thread::scope(|s| {
                for plan in plans {
                    let store = store.clone();
                    s.spawn(move || {
                        for &e in plan {
                            let key = ExpertKey::new(1, e as usize);
                            store.put(key, &storm_payload(e as usize)).unwrap();
                            // a key this thread just put can never miss:
                            // entries only leave on corruption/budget,
                            // and this storm has neither
                            match store.get(&key) {
                                ReadOutcome::Hit(d) => {
                                    assert_eq!(d, storm_payload(e as usize))
                                }
                                _ => panic!("own put of {key:?} must read back"),
                            }
                        }
                    });
                }
            });
            let st = store.stats();
            let touched: HashSet<u8> =
                plans.iter().flatten().copied().collect();
            if st.integrity_failures != 0 {
                return Err(format!("{} torn/corrupt reads", st.integrity_failures));
            }
            if st.misses != 0 {
                return Err(format!("{} misses in an all-hot storm", st.misses));
            }
            if st.writes != touched.len() as u64 {
                return Err(format!(
                    "{} blobs written for {} distinct payloads",
                    st.writes,
                    touched.len()
                ));
            }
            let du = du_verified(&dir);
            if st.bytes_on_disk != du {
                return Err(format!(
                    "accounted {} bytes on disk, enumeration finds {du}",
                    st.bytes_on_disk
                ));
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn prop_shared_cache_store_storm_keeps_disk_accounting_exact() {
    use sida_moe::experts::SharedExpertCache;
    use sida_moe::memory::ExpertStore;

    let bundle = sida_moe::testkit::tiny_bundle();
    let block = bundle.topology.moe_blocks[0];
    let num_experts = bundle.topology.num_experts;
    let real = bundle.weights.expert_bytes(block, 0).unwrap();
    let dir = std::env::temp_dir()
        .join(format!("sida_prop_cache_store_{}", std::process::id()));
    Prop::new(8).check(
        "shared cache + store: concurrent ensure storm",
        |r| {
            (0..3)
                .map(|_| (0..r.usize_below(25)).map(|_| r.below(8) as u8).collect())
                .collect::<Vec<Vec<u8>>>()
        },
        |v| shrink_vec(v),
        |plans| {
            let _ = std::fs::remove_dir_all(&dir);
            let store = ExpertStore::open(&dir, 0).unwrap();
            // 2-expert device tier, no RAM window: every eviction falls
            // to SSD and spills a real blob; promotions read them back
            let mut core = ExpertCache::with_hierarchy(
                2 * real + 64,
                CostModel::physical(real),
                make_policy("fifo").unwrap(),
                0,
                make_policy("fifo").unwrap(),
            );
            core.attach_store(sida_moe::experts::bind_store(&bundle, store));
            let cache = SharedExpertCache::new(core);
            std::thread::scope(|s| {
                for plan in plans {
                    let cache = &cache;
                    let bundle = bundle.clone();
                    s.spawn(move || {
                        for &e in plan {
                            let expert = e as usize % num_experts;
                            let key = ExpertKey::new(block, expert);
                            cache
                                .ensure(key, real, true, || {
                                    stage_expert_parts(
                                        &bundle.engine,
                                        &bundle.weights,
                                        block,
                                        expert,
                                    )
                                })
                                .unwrap();
                        }
                    });
                }
            });
            cache.check_invariants().map_err(|e| format!("{e:#}"))?;
            let h = cache.hierarchy_stats();
            if h.integrity_failures != 0 {
                return Err(format!(
                    "{} integrity failures without injected faults",
                    h.integrity_failures
                ));
            }
            let du = du_verified(&dir);
            if h.store_bytes_on_disk as u64 != du {
                return Err(format!(
                    "HierarchyStats accounts {} store bytes, enumeration finds {du}",
                    h.store_bytes_on_disk
                ));
            }
            Ok(())
        },
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Worker pool: scatter order is independent of expert completion order
// ---------------------------------------------------------------------------

/// Random per-"expert" jobs with random completion delays, run through
/// the pool at several widths; the merged (scattered) accumulator must
/// be bit-identical to the sequential merge, no matter which job
/// finishes first.  This is the order contract `model::forward` relies
/// on for bit-identical parallel expert execution.
#[test]
fn prop_pool_scatter_is_completion_order_independent() {
    use sida_moe::util::pool::WorkerPool;

    #[derive(Debug, Clone)]
    struct Job {
        /// token slots this job scatters into
        tokens: Vec<usize>,
        /// per-token contribution
        values: Vec<f32>,
        /// artificial completion skew in microseconds
        delay_us: u64,
    }

    const SLOTS: usize = 16;

    fn gen_jobs(r: &mut Rng) -> Vec<Job> {
        (0..r.usize_below(12))
            .map(|_| {
                let n = 1 + r.usize_below(6);
                Job {
                    tokens: (0..n).map(|_| r.usize_below(SLOTS)).collect(),
                    values: (0..n).map(|_| (r.f64() as f32 - 0.5) * 2.0).collect(),
                    // later jobs get shorter delays -> reversed completion
                    delay_us: r.below(300),
                }
            })
            .collect()
    }

    fn scatter(acc: &mut [f32], outs: &[Vec<(usize, f32)>]) {
        for rows in outs {
            for &(t, v) in rows {
                acc[t] += v;
            }
        }
    }

    Prop::new(48).check(
        "pool merge == sequential merge",
        gen_jobs,
        |v| shrink_vec(v),
        |jobs| {
            // sequential reference (pool width 1)
            let compute = |job: &Job| -> Vec<(usize, f32)> {
                job.tokens
                    .iter()
                    .zip(job.values.iter())
                    .map(|(&t, &v)| (t, v * 3.0 + 1.0))
                    .collect()
            };
            let seq: Vec<Vec<(usize, f32)>> = jobs.iter().map(compute).collect();
            let mut want = vec![0f32; SLOTS];
            scatter(&mut want, &seq);

            for threads in [2usize, 5] {
                let pool = WorkerPool::new(threads);
                let outs = pool.run(jobs.clone(), |i, job| {
                    // skew completion order away from submission order
                    std::thread::sleep(std::time::Duration::from_micros(job.delay_us));
                    assert_eq!(jobs[i].tokens, job.tokens, "index/job mismatch");
                    compute(&job)
                });
                let mut got = vec![0f32; SLOTS];
                scatter(&mut got, &outs);
                if got != want {
                    return Err(format!(
                        "pool width {threads}: merged accumulator diverged: {got:?} vs {want:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}
