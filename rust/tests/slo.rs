//! SLO-aware open-loop serving tests: replay accounting invariants
//! under load and overload, per-class latency bookkeeping, and
//! exactly-once delivery through the two-lane batch former — all
//! hermetic (synthetic bundle, no artifacts).
//!
//! These tests are invariant-based, not absolute-timing-based: they
//! assert that every trace request lands in exactly one outcome bucket
//! and that the per-class histograms partition the served set, never
//! that a particular request met a wall-clock deadline (CI machines
//! are too noisy for that — the timing shape is the `fig_slo` bench's
//! job).

use sida_moe::coordinator::{replay_open_loop, BatchFormer, BatchPolicy, Pipeline, PipelineConfig};
use sida_moe::testkit::{self, TINY_PROFILE};
use sida_moe::util::rng::Rng;
use sida_moe::workload::{ArrivalProcess, ClassMix, Request, SloClass};

fn pipeline() -> Pipeline {
    let bundle = testkit::tiny_bundle();
    let cfg = PipelineConfig { want_cls: true, ..Default::default() };
    Pipeline::new(bundle, TINY_PROFILE, cfg).unwrap()
}

/// Every trace request ends in exactly one bucket, whatever the load:
/// `served + shed + rejected + rejected_slo == trace.len()`.
#[test]
fn open_loop_accounting_is_exact_under_overload() {
    let p = pipeline();
    let bundle = testkit::tiny_bundle();
    // a burst storm into a tiny queue with a sub-millisecond deadline:
    // capacity rejects, SLO rejects and sheds all plausible at once
    let mix = ClassMix { interactive_frac: 0.5, deadline_secs: 0.0005 };
    let trace = testkit::tiny_trace_classed(
        &bundle,
        24,
        3,
        ArrivalProcess::Bursty { rate_on: 5_000.0, mean_on_secs: 0.01, mean_off_secs: 0.01 },
        mix,
    );
    let interactive_offered =
        trace.iter().filter(|r| r.class.is_interactive()).count() as u64;
    let report = replay_open_loop(&p, &trace, 4).unwrap();
    let stats = report.outcome.stats;

    let total =
        stats.requests as u64 + report.shed + report.rejected + report.rejected_slo;
    assert_eq!(
        total,
        trace.len() as u64,
        "every request must land in exactly one bucket \
         (served {} + shed {} + rejected {} + rejected_slo {})",
        stats.requests, report.shed, report.rejected, report.rejected_slo
    );
    // report and stats must tell the same story
    assert_eq!(stats.shed, report.shed);
    assert_eq!(stats.rejected, report.rejected);
    assert_eq!(stats.rejected_slo, report.rejected_slo);
    assert_eq!(stats.requests as usize, report.outcome.per_request.len());

    // the per-class histograms partition the served set
    assert_eq!(
        stats.latency_interactive.len() + stats.latency_batch.len(),
        stats.requests as usize,
        "per-class histograms must partition served requests"
    );
    // attainment denominates over OFFERED interactive traffic: shed and
    // rejected interactive requests count against it
    assert_eq!(stats.interactive_offered, interactive_offered);
    assert!(stats.slo_attained <= interactive_offered);
    if let Some(att) = stats.slo_attainment() {
        assert!((0.0..=1.0).contains(&att), "attainment {att} out of range");
    }
    // only interactive requests can be shed or SLO-rejected
    assert!(report.shed + report.rejected_slo <= interactive_offered);
}

#[test]
fn open_loop_low_load_serves_everything_within_slo() {
    let p = pipeline();
    let bundle = testkit::tiny_bundle();
    // arrivals far apart, a deadline of 10 s: nothing can drop
    let mix = ClassMix { interactive_frac: 0.5, deadline_secs: 10.0 };
    let trace = testkit::tiny_trace_classed(
        &bundle,
        6,
        5,
        ArrivalProcess::Poisson { rate: 200.0 },
        mix,
    );
    let report = replay_open_loop(&p, &trace, 64).unwrap();
    let mut stats = report.outcome.stats;
    assert_eq!(stats.requests as usize, trace.len());
    assert_eq!(report.shed + report.rejected + report.rejected_slo, 0);
    assert_eq!(
        stats.slo_attainment(),
        (stats.interactive_offered > 0).then_some(1.0),
        "a 10 s deadline at idle load must attain fully"
    );
    assert!(stats.latency.p999() >= stats.latency.p50());
}

#[test]
fn classed_trace_respects_the_mix() {
    let bundle = testkit::tiny_bundle();
    let all_int = testkit::tiny_trace_classed(
        &bundle, 16, 9, ArrivalProcess::ClosedLoop,
        ClassMix { interactive_frac: 1.0, deadline_secs: 0.1 },
    );
    assert!(all_int.iter().all(|r| r.class.is_interactive()));
    assert!(all_int
        .iter()
        .all(|r| r.class.deadline_secs() == Some(0.1)));
    let all_batch = testkit::tiny_trace_classed(
        &bundle, 16, 9, ArrivalProcess::ClosedLoop, ClassMix::batch_only(),
    );
    assert!(all_batch.iter().all(|r| r.class == SloClass::Batch));
    let mixed = testkit::tiny_trace_classed(
        &bundle, 64, 9, ArrivalProcess::ClosedLoop,
        ClassMix { interactive_frac: 0.5, deadline_secs: 0.1 },
    );
    let n_int = mixed.iter().filter(|r| r.class.is_interactive()).count();
    assert!(
        (8..=56).contains(&n_int),
        "a 50/50 mix over 64 requests produced {n_int} interactive"
    );
}

/// Randomized two-lane former property: under arbitrary interleavings
/// of admits and cuts, every admitted request is delivered exactly once
/// (served or shed), FIFO order holds within each lane, and only
/// interactive requests are ever shed.
#[test]
fn two_lane_former_delivers_exactly_once_under_random_interleaving() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(0x5EED ^ seed);
        let mut f: BatchFormer<()> = BatchFormer::new(BatchPolicy {
            max_batch: 1 + rng.usize_below(4),
            max_delay_secs: 0.001,
            capacity: 64,
            batch_aging_cuts: 1 + rng.usize_below(3) as u32,
        });
        let mut admitted_ids = Vec::new();
        let mut served = Vec::new();
        let mut served_interactive = Vec::new();
        let mut served_batch = Vec::new();
        let mut shed = Vec::new();
        let mut next_id = 0u64;
        let mut now = 0.0f64;
        for _ in 0..120 {
            now += 0.0003;
            if rng.bool(0.6) {
                // admit: half interactive with a deadline that may or
                // may not blow before the next cut
                let class = if rng.bool(0.5) {
                    SloClass::Interactive { deadline_secs: rng.f64() * 0.002 }
                } else {
                    SloClass::Batch
                };
                let req = Request {
                    id: next_id,
                    ids: vec![1, 5, 2, 0],
                    n_tokens: 3,
                    label: 0,
                    arrival: now,
                    class,
                };
                next_id += 1;
                if f.admit(req, (), now) == sida_moe::coordinator::AdmitOutcome::Admitted {
                    admitted_ids.push(next_id - 1);
                }
            }
            if rng.bool(0.4) {
                if let Some(b) = f.form_now(now) {
                    for (r, _) in &b.requests {
                        served.push(r.id);
                        if r.class.is_interactive() {
                            served_interactive.push(r.id);
                        } else {
                            served_batch.push(r.id);
                        }
                    }
                    for (r, _) in &b.shed {
                        assert!(
                            r.class.is_interactive(),
                            "only interactive requests may be shed"
                        );
                        shed.push(r.id);
                    }
                }
            }
        }
        // drain
        now += 1.0;
        while let Some(b) = f.form_now(now) {
            for (r, _) in &b.requests {
                served.push(r.id);
                if r.class.is_interactive() {
                    served_interactive.push(r.id);
                } else {
                    served_batch.push(r.id);
                }
            }
            for (r, _) in &b.shed {
                shed.push(r.id);
            }
        }
        let mut delivered = served.clone();
        delivered.extend(&shed);
        delivered.sort_unstable();
        let mut expected = admitted_ids.clone();
        expected.sort_unstable();
        assert_eq!(
            delivered, expected,
            "seed {seed}: every admitted request exactly once (served or shed)"
        );
        assert_eq!(f.shed, shed.len() as u64);
        // FIFO holds within each lane: ids are assigned in admission
        // order, so the served sequence restricted to one class must be
        // increasing (the lanes may interleave, each lane may not)
        for class_ids in [&served_interactive, &served_batch] {
            assert!(
                class_ids.windows(2).all(|w| w[0] < w[1]),
                "seed {seed}: a lane served out of admission order: {class_ids:?}"
            );
        }
    }
}
