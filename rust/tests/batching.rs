//! Cross-request batched serving tests over the synthetic bundle: the
//! batched pipeline vs batch-1 (predictions, exactly-once delivery),
//! batch-union expert traffic under tight budgets, batches larger than
//! the expert-cache budget, and mixed-length padding — all hermetic.
//!
//! Batch-former unit edge cases (deadline fires with a partial batch,
//! rejection accounting under overflow, profile grouping) live next to
//! the implementation in `coordinator::batcher`.

use sida_moe::coordinator::{HashBuilder, Pipeline, PipelineConfig};
use sida_moe::memory::CostModel;
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, TINY_PROFILE};
use sida_moe::workload::{Request, SloClass};

fn expert_sim_bytes(b: &ModelBundle) -> usize {
    CostModel::paper_scale(
        b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap(),
    )
    .sim_expert_bytes
}

#[test]
fn batched_pipeline_matches_batch1_predictions_with_mixed_lengths() {
    // the trace has varied true lengths (different padding per request);
    // coalescing them into batches of 4 must not change any prediction
    let b = testkit::tiny_bundle();
    let reqs = testkit::tiny_trace(&b, 10, 9);
    let lens: std::collections::BTreeSet<usize> = reqs.iter().map(|r| r.n_tokens).collect();
    assert!(lens.len() > 1, "trace must mix true lengths to exercise padding");

    let cfg1 = PipelineConfig { want_cls: true, ..Default::default() };
    let out1 = Pipeline::new(b.clone(), TINY_PROFILE, cfg1).unwrap().serve(&reqs).unwrap();
    let cfg4 = PipelineConfig { want_cls: true, max_batch: 4, ..Default::default() };
    let out4 = Pipeline::new(b, TINY_PROFILE, cfg4).unwrap().serve(&reqs).unwrap();

    assert_eq!(out4.stats.requests, 10);
    assert_eq!(out4.stats.batches, 3, "10 requests at max_batch 4 -> 4+4+2");
    assert!((out4.stats.mean_batch_size().unwrap() - 10.0 / 3.0).abs() < 1e-9);
    assert_eq!(out1.stats.batches, out1.stats.requests, "batch-1 serves one per forward");

    // exactly-once, and identical predictions request by request
    let mut a = out1.per_request.clone();
    let mut c = out4.per_request.clone();
    a.sort_by_key(|r| r.id);
    c.sort_by_key(|r| r.id);
    assert_eq!(a.len(), c.len());
    for (x, y) in a.iter().zip(c.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.cls_pred, y.cls_pred, "request {} diverged under batching", x.id);
    }
}

#[test]
fn batch_larger_than_cache_budget_still_serves_within_budget() {
    // a batch of 8 requests can activate every expert in the pool while
    // the device holds only 2: the joint dispatch pins one expert at a
    // time, so everything must serve, stay within budget, and evict
    let b = testkit::tiny_bundle();
    let reqs = testkit::tiny_trace(&b, 16, 5);
    let budget = 2 * expert_sim_bytes(&b) + 1024;
    let cfg = PipelineConfig {
        budget_sim_bytes: budget,
        max_batch: 8,
        want_cls: true,
        ..Default::default()
    };
    let p = Pipeline::new(b, TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 16);
    assert_eq!(out.stats.batches, 2);
    assert!(
        out.stats.peak_device_bytes <= budget,
        "peak {} exceeds budget {budget}",
        out.stats.peak_device_bytes
    );
    assert!(out.stats.evictions > 0, "tight budget must evict");
    p.cache.check_invariants().unwrap();
    assert!(p.cache.used() <= p.cache.budget());
}

/// Find a generated sentence whose layer-0 predicted expert set has at
/// least `min_distinct` members (so a 2-slot FIFO cache must thrash on
/// it), scanning seeds deterministically.
fn diverse_sentence(b: &ModelBundle, builder: &HashBuilder, min_distinct: usize, skip: usize) -> Vec<i32> {
    let mut found = 0;
    for seed in 0..200u64 {
        let req = testkit::tiny_trace(b, 1, seed).remove(0);
        let table = builder.build(0, &req.ids).unwrap();
        let distinct = table.predicted_experts(0, 1, &req.mask()).len();
        if distinct >= min_distinct {
            if found == skip {
                return req.ids;
            }
            found += 1;
        }
    }
    panic!("no sentence with >= {min_distinct} distinct experts in 200 seeds");
}

#[test]
fn batched_mode_moves_strictly_fewer_bytes_per_request() {
    // Acceptance criterion (hermetic twin of the fig9b check): under a
    // tight budget, batched serving charges each activated expert once
    // per batch instead of once per request, so H2D transfers per
    // request — and expert invocations per request — drop strictly.
    //
    // Construction makes the margin structural: 3 sentences, each with
    // >= 3 distinct experts (a 2-expert cache thrashes on every pass),
    // each repeated 4x consecutively so every batch of 4 holds one
    // sentence's expert set exactly once.
    let b = testkit::tiny_bundle();
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let mut reqs: Vec<Request> = Vec::new();
    for s in 0..3 {
        let ids = diverse_sentence(&b, &builder, 3, s);
        for copy in 0..4 {
            reqs.push(Request {
                id: (s * 4 + copy) as u64,
                ids: ids.clone(),
                n_tokens: ids.iter().filter(|&&t| t != 0).count(),
                label: 0,
                arrival: 0.0,
                class: SloClass::Batch,
            });
        }
    }
    let budget = 2 * expert_sim_bytes(&b) + 1024;

    let b1 = Pipeline::new(
        b.clone(),
        TINY_PROFILE,
        PipelineConfig { budget_sim_bytes: budget, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();
    let b4 = Pipeline::new(
        b,
        TINY_PROFILE,
        PipelineConfig { budget_sim_bytes: budget, max_batch: 4, ..Default::default() },
    )
    .unwrap()
    .serve(&reqs)
    .unwrap();

    assert_eq!(b1.stats.requests, 12);
    assert_eq!(b4.stats.requests, 12);
    assert!(
        b4.stats.transferred_bytes_per_request() < b1.stats.transferred_bytes_per_request(),
        "batched {} >= batch-1 {} bytes/request",
        b4.stats.transferred_bytes_per_request(),
        b1.stats.transferred_bytes_per_request()
    );
    assert!(
        b4.stats.phases.expert_invocations < b1.stats.phases.expert_invocations,
        "batched {} >= batch-1 {} expert invocations",
        b4.stats.phases.expert_invocations,
        b1.stats.phases.expert_invocations
    );
}

#[test]
fn batched_two_moe_layer_pipeline_prefetches_the_union() {
    // both MoE layers of the deeper spec must be covered by the
    // batch-union prefetch: no fetch on the inference critical path
    let b = testkit::bundle(&testkit::SynthSpec::default().two_moe_layers()).unwrap();
    let reqs = testkit::tiny_trace(&b, 8, 8);
    let cfg = PipelineConfig { max_batch: 4, ..Default::default() };
    let p = Pipeline::new(b, TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 8);
    assert_eq!(out.stats.batches, 2);
    assert_eq!(
        out.stats.blocking_misses, 0,
        "batch-union prefetch left fetches on the critical path"
    );
    assert!(out.stats.cache_misses > 0);
}
