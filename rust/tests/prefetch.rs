//! Cross-layer prefetch bandwidth scheduler: hermetic integration
//! invariants (ISSUE 10 acceptance criteria).
//!
//! * EDF admission issues in deadline order even when the shared
//!   window is saturated;
//! * confidence weighting is one-directional: a low-agreement fetch can
//!   be deferred, but it can never displace (or outrank) a
//!   high-agreement fetch with an earlier-or-equal deadline;
//! * tier-derived staging leads match the ladder arithmetic: SSD-deep
//!   experts want 2–3 layers of head start at paper-scale costs, RAM
//!   hops 1, device-resident experts are never staged;
//! * f32 serving outputs are **bit-identical** with the scheduler
//!   effectively off (`prefetch_depth = 1`, the one-layer-ahead
//!   baseline) and on (`prefetch_depth = 3`) across worker pools
//!   {1, 4} × devices {1, 2, 4} — scheduling reorders and defers
//!   non-blocking staging only, never what compute sees.

use std::sync::Arc;

use sida_moe::coordinator::{HashBuilder, Pipeline, PipelineConfig};
use sida_moe::experts::{admit_edf, make_policy, plan_prefetch, ExpertCache, PlannedFetch};
use sida_moe::memory::{fetch_deadline_secs, layer_window_secs, lead_layers, CostModel, Tier};
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};

fn deep_bundle() -> Arc<ModelBundle> {
    testkit::bundle(&SynthSpec::default().two_moe_layers()).unwrap()
}

fn sim_expert_bytes(b: &ModelBundle) -> usize {
    let real = b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap();
    CostModel::paper_scale(real).sim_bytes(real)
}

/// A full-depth plan for one real request against a cold cache: every
/// predicted expert is SSD-deep, layers carry increasing deadlines.
fn cold_plan(b: &ModelBundle, max_lead: usize) -> (Vec<PlannedFetch>, ExpertCache) {
    let builder = HashBuilder::new(b, TINY_PROFILE).unwrap();
    let req = &testkit::tiny_trace(b, 1, 97)[0];
    let table = builder.build(req.id, &req.ids).unwrap();
    let real = b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap();
    let cache = ExpertCache::new(
        1 << 40,
        CostModel::paper_scale(real),
        make_policy("fifo").unwrap(),
    );
    let mask = req.mask();
    let plan =
        plan_prefetch(&table, &b.topology.moe_blocks, 2, &mask, &cache, max_lead);
    (plan, cache)
}

#[test]
fn edf_issues_in_deadline_order_under_saturated_window() {
    let b = deep_bundle();
    let (plan, cache) = cold_plan(&b, 3);
    assert!(plan.len() >= 2, "need fetches from both MoE layers");
    assert!(
        plan.iter().any(|f| f.layers_ahead > 1),
        "a two-layer plan must stage the deeper layer ahead"
    );
    let costs = cache.cost_model().tier_costs();
    let sim = cache.cost_model().sim_expert_bytes;
    // saturate: backlog far past every deadline in the plan
    let backlog = plan.iter().map(|f| f.deadline_secs).fold(0.0, f64::max) * 10.0 + 1.0;
    let adm = admit_edf(plan.clone(), backlog, |f| costs.promote_secs(f.tier, sim));
    assert_eq!(
        adm.admit.len() + adm.deferred,
        plan.len(),
        "every planned fetch is admitted or deferred, never lost"
    );
    for w in adm.admit.windows(2) {
        assert!(
            w[0].deadline_secs <= w[1].deadline_secs,
            "EDF order violated: {:?} before {:?}",
            w[0],
            w[1]
        );
    }
    // saturation means zero hideable window everywhere
    assert!(adm.min_slack_secs.unwrap() < 0.0);
    // real router agreement is high in the synthetic bundle: nothing
    // from a genuine plan is confidence-deferred
    assert_eq!(adm.deferred, 0);
}

#[test]
fn low_confidence_never_displaces_earlier_high_confidence() {
    let b = deep_bundle();
    let (plan, cache) = cold_plan(&b, 3);
    let costs = cache.cost_model().tier_costs();
    let sim = cache.cost_model().sim_expert_bytes;
    // degrade the deeper layer's fetches to rumor-grade confidence
    let mut mixed = plan;
    for f in mixed.iter_mut().filter(|f| f.layers_ahead > 1) {
        f.confidence = 0.01;
    }
    let sure: Vec<PlannedFetch> =
        mixed.iter().filter(|f| f.confidence >= 0.25).cloned().collect();
    assert!(!sure.is_empty() && sure.len() < mixed.len());
    for backlog in [0.0, 0.5 * sure[0].deadline_secs, 1e3] {
        let adm =
            admit_edf(mixed.clone(), backlog, |f| costs.promote_secs(f.tier, sim));
        // every high-confidence fetch is admitted, whatever the rumors
        // around it wanted
        for want in &sure {
            assert!(
                adm.admit.iter().any(|f| f.key == want.key && f.layers_ahead == want.layers_ahead),
                "high-confidence fetch {:?} displaced at backlog {backlog}",
                want.key
            );
        }
        // and no admitted low-confidence fetch sits before a
        // high-confidence one with an earlier-or-equal deadline
        for (i, f) in adm.admit.iter().enumerate() {
            if f.confidence >= 0.25 {
                continue;
            }
            for earlier in &adm.admit[..i] {
                assert!(
                    earlier.confidence >= 0.25 || earlier.deadline_secs < f.deadline_secs,
                    "low-confidence {:?} outranked {:?}",
                    f.key,
                    earlier.key
                );
            }
        }
        // deferral only ever hits speculative low-confidence fetches
        assert!(adm.deferred <= mixed.len() - sure.len());
    }
}

#[test]
fn tier_leads_match_ladder_arithmetic() {
    let cm = CostModel::paper_scale(66_048);
    let costs = cm.tier_costs();
    let sim = cm.sim_expert_bytes;
    // device-resident experts are never staged
    assert_eq!(lead_layers(&costs, Tier::Device, sim, 4, 3), 0);
    for experts in 1..=16 {
        // a RAM hop always fits inside one layer window
        assert_eq!(lead_layers(&costs, Tier::Ram, sim, experts, 3), 1);
        // the lead is exactly the ladder ratio folded into layer windows
        let want = ((costs.promote_secs(Tier::Ssd, sim)
            / layer_window_secs(&costs, sim, experts))
        .ceil() as usize)
            .clamp(1, 3);
        assert_eq!(lead_layers(&costs, Tier::Ssd, sim, experts, 3), want);
    }
    // paper-scale ladder ratio (~9x): SSD wants 2–3 layers of head
    // start at typical per-layer expert counts
    assert_eq!(lead_layers(&costs, Tier::Ssd, sim, 4, 3), 3);
    assert_eq!(lead_layers(&costs, Tier::Ssd, sim, 8, 3), 2);
    // depth 1 clamps every lead to the one-layer-ahead baseline
    assert_eq!(lead_layers(&costs, Tier::Ssd, sim, 4, 1), 1);
    // deadlines are layer windows, on the modeled timeline
    let w = layer_window_secs(&costs, sim, 4);
    assert!((fetch_deadline_secs(&costs, sim, 4, 3) - 3.0 * w).abs() < 1e-15);
}

#[test]
fn planned_metadata_agrees_with_cost_model() {
    let b = deep_bundle();
    let (plan, cache) = cold_plan(&b, 3);
    let costs = cache.cost_model().tier_costs();
    let sim = cache.cost_model().sim_expert_bytes;
    use std::collections::BTreeMap;
    let mut per_layer: BTreeMap<usize, usize> = BTreeMap::new();
    for f in &plan {
        *per_layer.entry(f.layers_ahead).or_insert(0) += 1;
    }
    for f in &plan {
        let experts = per_layer[&f.layers_ahead];
        assert_eq!(
            f.lead_layers,
            lead_layers(&costs, f.tier, sim, experts, 3),
            "{:?}: planned lead drifted from the cost model",
            f.key
        );
        assert!(
            (f.deadline_secs - fetch_deadline_secs(&costs, sim, experts, f.layers_ahead)).abs()
                < 1e-12,
            "{:?}: planned deadline drifted from the cost model",
            f.key
        );
        assert!((0.0..=1.0).contains(&f.confidence));
    }
    // depth 1: every lead clamps to 1, so no fetch qualifies for
    // staging deeper than one layer ahead — the exact PR 5 baseline
    let (base, _) = cold_plan(&b, 1);
    assert!(base.iter().all(|f| f.lead_layers <= 1));
}

#[test]
fn outputs_bit_identical_with_scheduler_on_and_off() {
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 8, 33);
    let sim = sim_expert_bytes(&b);
    let mut reference: Option<Vec<(Option<usize>, Option<f64>)>> = None;
    for pool_threads in [1usize, 4] {
        for devices in [1usize, 2, 4] {
            for depth in [1usize, 3] {
                let cfg = PipelineConfig {
                    k_used: 2,
                    pool_threads,
                    devices,
                    prefetch_depth: depth,
                    // tight budgets: misses and SSD-deep promotions on
                    // every path, so the scheduler really runs
                    budget_sim_bytes: 4 * sim,
                    ram_budget_bytes: 2 * sim,
                    want_lm: true,
                    want_cls: true,
                    ..Default::default()
                };
                let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
                let out = p.serve(&reqs).unwrap();
                assert_eq!(out.stats.requests, reqs.len() as u64);
                let got: Vec<(Option<usize>, Option<f64>)> =
                    out.per_request.iter().map(|r| (r.cls_pred, r.lm_nll)).collect();
                match &reference {
                    None => reference = Some(got),
                    Some(want) => assert_eq!(
                        want, &got,
                        "pool={pool_threads} devices={devices} depth={depth}: \
                         outputs diverged"
                    ),
                }
                // the ladder attribution identity survives scheduling
                assert!(
                    (out.stats.ladder_secs() - out.stats.modeled_transfer_secs).abs()
                        <= 1e-9 * out.stats.modeled_transfer_secs.max(1.0),
                    "pool={pool_threads} devices={devices} depth={depth}: ladder drifted"
                );
                assert!(out.stats.prefetch_admitted > 0, "scheduler must have run");
            }
        }
    }
}
