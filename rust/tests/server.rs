//! TCP front-end tests: real sockets against `run_server_on` with the
//! synthetic bundle behind it — protocol round-trips, error paths,
//! multi-client sessions, the shared batch worker, stats, and clean
//! shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use sida_moe::coordinator::BatchPolicy;
use sida_moe::server::{run_server_on, ServerConfig, ServerState};
use sida_moe::testkit::{self, TINY_PROFILE};
use sida_moe::util::json::Json;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .expect("read timeout");
        let writer = stream.try_clone().expect("clone");
        Client { reader: BufReader::new(stream), writer }
    }

    fn roundtrip(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").expect("send");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("recv");
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }
}

/// Spawn the server on an ephemeral port; returns (addr, join handle).
fn start_server() -> (std::net::SocketAddr, Arc<ServerState>, std::thread::JoinHandle<()>) {
    start_server_with(ServerConfig::default())
}

fn start_server_with(
    cfg: ServerConfig,
) -> (std::net::SocketAddr, Arc<ServerState>, std::thread::JoinHandle<()>) {
    let bundle = testkit::tiny_bundle();
    let state = Arc::new(ServerState::new(bundle, TINY_PROFILE, cfg).unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let st = state.clone();
    let handle = std::thread::spawn(move || {
        run_server_on(st, listener).expect("server run");
    });
    (addr, state, handle)
}

fn shutdown(addr: std::net::SocketAddr) {
    let mut c = Client::connect(addr);
    let resp = c.roundtrip(r#"{"cmd": "shutdown"}"#);
    assert!(resp.get("ok").unwrap().as_bool().unwrap());
}

#[test]
fn serves_requests_and_reports_stats_over_tcp() {
    let (addr, _state, handle) = start_server();
    {
        let mut c = Client::connect(addr);
        // unpadded ids are fine; the server pads to the profile seq len
        let resp = c.roundtrip(r#"{"ids": [1, 40, 41, 42, 2]}"#);
        let label = resp.get("label").unwrap().as_usize().unwrap();
        assert!(label < 4, "label {label} out of range");
        assert!(resp.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        let first_id = resp.get("id").unwrap().as_u64().unwrap();

        // same sentence again: same prediction, fresh id
        let resp2 = c.roundtrip(r#"{"ids": [1, 40, 41, 42, 2]}"#);
        assert_eq!(
            resp2.get("label").unwrap().as_usize().unwrap(),
            label,
            "same input, same prediction"
        );
        assert!(resp2.get("id").unwrap().as_u64().unwrap() > first_id);

        let stats = c.roundtrip(r#"{"cmd": "stats"}"#);
        assert_eq!(stats.get("served").unwrap().as_u64().unwrap(), 2);
        assert_eq!(stats.get("rejected").unwrap().as_u64().unwrap(), 0);
        // the batching counters must be reported and coherent
        let batches = stats.get("batches_formed").unwrap().as_u64().unwrap();
        assert!(batches >= 1 && batches <= 2, "2 requests -> 1..=2 batches, got {batches}");
        assert!(stats.get("mean_batch_size").unwrap().as_f64().unwrap() >= 1.0);
        assert!(stats.get("batching_delay_ms_mean").unwrap().as_f64().unwrap() >= 0.0);
        assert!(stats.get("infer_ms_mean").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            stats.get("cache_hits").unwrap().as_u64().unwrap()
                + stats.get("cache_misses").unwrap().as_u64().unwrap()
                > 0
        );
    }
    shutdown(addr);
    handle.join().expect("server thread");
}

#[test]
fn rejects_garbage_and_unknown_commands_without_dying() {
    let (addr, _state, handle) = start_server();
    {
        let mut c = Client::connect(addr);
        let err = c.roundtrip("this is not json");
        assert!(err.get("error").is_ok(), "malformed input must yield an error object");

        let err = c.roundtrip(r#"{"cmd": "frobnicate"}"#);
        assert!(
            err.get("error").unwrap().as_str().unwrap().contains("unknown cmd"),
            "unknown command must be reported"
        );

        // connection still usable after both errors
        let ok = c.roundtrip(r#"{"ids": [1, 10, 2]}"#);
        assert!(ok.get("label").is_ok());

        // hostile token ids (out of vocab, negative) must not kill the
        // connection: the backend clips like jnp.take and still answers
        let ok = c.roundtrip(r#"{"ids": [1, 4096, -7, 2]}"#);
        assert!(
            ok.get("label").is_ok(),
            "out-of-vocab ids dropped the connection: {ok:?}"
        );
    }
    shutdown(addr);
    handle.join().expect("server thread");
}

#[test]
fn multiple_concurrent_client_sessions() {
    let (addr, state, handle) = start_server();
    let mut clients = Vec::new();
    for client_id in 0..3u64 {
        clients.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            let mut labels = Vec::new();
            for i in 0..4 {
                let tok = 10 + client_id * 7 + i;
                let resp = c.roundtrip(&format!(r#"{{"ids": [1, {tok}, {tok}, 2]}}"#));
                labels.push(resp.get("label").unwrap().as_usize().unwrap());
            }
            labels
        }));
    }
    let mut all = Vec::new();
    for c in clients {
        all.extend(c.join().expect("client"));
    }
    assert_eq!(all.len(), 12);
    assert!(all.iter().all(|&l| l < 4));
    use std::sync::atomic::Ordering;
    assert_eq!(state.served.load(Ordering::SeqCst), 12);
    shutdown(addr);
    handle.join().expect("server thread");
}

#[test]
fn concurrent_requests_share_batches() {
    // six clients fire one request each inside the forming window: the
    // shared worker must coalesce them into fewer forward passes than
    // requests (cross-request batching), and every client still gets a
    // well-formed reply with latency attribution.
    let cfg = ServerConfig {
        batch: BatchPolicy { max_batch: 6, max_delay_secs: 0.5, capacity: 64, ..Default::default() },
        ..Default::default()
    };
    let (addr, state, handle) = start_server_with(cfg);
    let mut clients = Vec::new();
    for i in 0..6u64 {
        clients.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr);
            let tok = 20 + i;
            let resp = c.roundtrip(&format!(r#"{{"ids": [1, {tok}, {tok}, 2]}}"#));
            assert!(resp.get("label").is_ok(), "bad reply {resp:?}");
            assert!(resp.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
            assert!(resp.get("infer_ms").unwrap().as_f64().unwrap() > 0.0);
            let total = resp.get("latency_ms").unwrap().as_f64().unwrap();
            let parts = resp.get("queue_ms").unwrap().as_f64().unwrap()
                + resp.get("infer_ms").unwrap().as_f64().unwrap();
            assert!((total - parts).abs() < 1e-6, "latency must equal queue + infer");
        }));
    }
    for c in clients {
        c.join().expect("client");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(state.served.load(Ordering::SeqCst), 6);
    {
        let b = state.batching.lock().unwrap();
        assert_eq!(b.batched_requests, 6);
        assert!(
            b.batches < 6,
            "6 concurrent requests never shared a batch ({} batches)",
            b.batches
        );
    }
    shutdown(addr);
    handle.join().expect("server thread");
}

#[test]
fn interactive_class_round_trips_and_stats_report_slo_counters() {
    let (addr, _state, handle) = start_server();
    {
        let mut c = Client::connect(addr);
        // a generous deadline: served normally, counted as interactive
        let resp =
            c.roundtrip(r#"{"ids": [1, 30, 31, 2], "class": "interactive", "deadline_ms": 5000}"#);
        assert!(resp.get("label").is_ok(), "interactive request must serve: {resp:?}");

        // unknown class names are a protocol error, not a silent default
        let err = c.roundtrip(r#"{"ids": [1, 30, 2], "class": "premium"}"#);
        assert!(
            err.get("error").unwrap().as_str().unwrap().contains("unknown class"),
            "bad class must be reported: {err:?}"
        );

        let stats = c.roundtrip(r#"{"cmd": "stats"}"#);
        assert_eq!(stats.get("served").unwrap().as_u64().unwrap(), 1);
        assert_eq!(stats.get("rejected_slo").unwrap().as_u64().unwrap(), 0);
        assert_eq!(stats.get("shed").unwrap().as_u64().unwrap(), 0);
        assert_eq!(stats.get("worker_panics").unwrap().as_u64().unwrap(), 0);
        let att = stats.get("slo_attainment").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&att), "attainment {att} out of range");
        assert!(stats.get("latency_p99_ms_interactive").unwrap().as_f64().unwrap() > 0.0);
        assert!(stats.get("latency_p999_ms_interactive").unwrap().as_f64().unwrap() > 0.0);
    }
    shutdown(addr);
    handle.join().expect("server thread");
}

#[test]
fn worker_panic_fails_requests_and_shuts_down_instead_of_hanging() {
    // regression: a panicking batch used to kill the worker thread
    // silently — every later client hit the 30 s reply timeout while
    // the accept loop kept admitting.  The worker must now error out
    // the in-flight requests, flip shutdown, and surface the panic in
    // stats.
    use std::sync::atomic::Ordering;
    let (addr, state, handle) = start_server();
    state.inject_panic.store(true, Ordering::SeqCst);
    {
        let mut c = Client::connect(addr);
        let resp = c.roundtrip(r#"{"ids": [1, 50, 51, 2]}"#);
        let err = resp.get("error").unwrap().as_str().unwrap().to_string();
        assert!(
            err.contains("panicked"),
            "client must see the worker panic, got: {err}"
        );
    }
    handle.join().expect("server thread must exit after a worker panic");
    assert!(state.shutdown.load(Ordering::SeqCst), "panic must flip shutdown");
    assert_eq!(state.worker_panics.load(Ordering::SeqCst), 1);
    assert_eq!(state.served.load(Ordering::SeqCst), 0);
}

#[test]
fn metrics_exposition_agrees_with_stats_field_for_field() {
    // ISSUE 9 satellite: `cmd:stats` and `cmd:metrics` render the SAME
    // snapshot builder, so a scrape and a stats reply taken back to
    // back on a quiescent server must agree field for field.
    let (addr, _state, handle) = start_server();
    {
        let mut c = Client::connect(addr);
        for tok in [40u64, 41, 42] {
            let r = c.roundtrip(&format!(r#"{{"ids": [1, {tok}, {tok}, 2]}}"#));
            assert!(r.get("label").is_ok(), "bad reply {r:?}");
        }
        let stats = c.roundtrip(r#"{"cmd": "stats"}"#);

        // the metrics reply is multi-line Prometheus text terminated by
        // a literal `# EOF` line
        writeln!(c.writer, r#"{{"cmd": "metrics"}}"#).expect("send");
        let mut text = String::new();
        loop {
            let mut line = String::new();
            let n = c.reader.read_line(&mut line).expect("recv");
            assert!(n > 0, "connection closed before # EOF");
            if line.trim_end() == "# EOF" {
                break;
            }
            text.push_str(&line);
        }

        // well-formed text exposition: every sample line parses, and the
        // scrape carries a real series count (acceptance: >= 25)
        let mut samples = 0;
        for line in text.lines() {
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (name, value) =
                line.rsplit_once(' ').unwrap_or_else(|| panic!("bad sample line: {line}"));
            assert!(!name.is_empty(), "empty series name in '{line}'");
            assert!(
                matches!(value, "+Inf" | "-Inf" | "NaN") || value.parse::<f64>().is_ok(),
                "unparseable sample value in '{line}'"
            );
            samples += 1;
        }
        assert!(samples >= 25, "only {samples} samples exposed");

        // field-for-field agreement with the stats reply
        let Json::Obj(map) = &stats else { panic!("stats must be an object: {stats:?}") };
        let mut checked = 0;
        for (name, v) in map.iter() {
            let Json::Num(want) = v else { continue };
            let got = sida_moe::obs::prom::sample(&text, &format!("sida_server_{name}"))
                .unwrap_or_else(|| panic!("scrape missing sida_server_{name}"));
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "sida_server_{name}: scrape says {got}, cmd:stats says {want}"
            );
            checked += 1;
        }
        assert!(checked >= 25, "only {checked} numeric stats fields compared");

        // the connection stays usable after a multi-line reply
        let ok = c.roundtrip(r#"{"ids": [1, 10, 2]}"#);
        assert!(ok.get("label").is_ok());
    }
    shutdown(addr);
    handle.join().expect("server thread");
}

#[test]
fn shutdown_terminates_accept_loop() {
    let (addr, state, handle) = start_server();
    shutdown(addr);
    handle.join().expect("server thread should exit after shutdown");
    use std::sync::atomic::Ordering;
    assert!(state.shutdown.load(Ordering::SeqCst));
    // a fresh connection attempt must now fail (listener dropped);
    // allow a little slack for the OS to tear the socket down
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        TcpStream::connect_timeout(&addr, Duration::from_millis(200)).is_err(),
        "listener still accepting after shutdown"
    );
}
