//! Observability layer tests (ISSUE 9 acceptance): registry atomics
//! under contention, bucketed-histogram agreement with the exact
//! [`LatencyHistogram`], tracing bit-identity, trace self-consistency
//! (a request's exposed span components reconcile with its reported
//! latency), and the 4-device faulted flow/lane/promotion structure of
//! an exported Chrome trace.
//!
//! The tracer is process-global, so every test that enables it holds
//! [`tracer_lock`] — registry tests use per-instance registries and
//! need no serialization.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex, MutexGuard};

use sida_moe::coordinator::{replay_open_loop, Pipeline, PipelineConfig, ServeOutcome};
use sida_moe::metrics::LatencyHistogram;
use sida_moe::obs::trace::{self, ArgValue, Event};
use sida_moe::obs::{Registry, SnapValue};
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::Json;
use sida_moe::util::rng::Rng;
use sida_moe::workload::{ArrivalProcess, ClassMix};

static TRACER: Mutex<()> = Mutex::new(());

fn tracer_lock() -> MutexGuard<'static, ()> {
    TRACER.lock().unwrap_or_else(|e| e.into_inner())
}

fn deep_bundle() -> Arc<ModelBundle> {
    testkit::bundle(&SynthSpec::default().two_moe_layers()).unwrap()
}

/// Order-normalized per-request outputs for bit-identity comparison.
fn outputs(out: &ServeOutcome) -> Vec<(u64, Option<usize>, Option<f64>)> {
    let mut v: Vec<_> = out.per_request.iter().map(|r| (r.id, r.cls_pred, r.lm_nll)).collect();
    v.sort_by_key(|(id, ..)| *id);
    assert!(!v.is_empty());
    v
}

fn arg_f(ev: &Event, key: &str) -> f64 {
    match ev.args.iter().find(|(k, _)| *k == key) {
        Some((_, ArgValue::F(x))) => *x,
        other => panic!("event '{}' missing f64 arg '{key}': {other:?}", ev.name),
    }
}

fn arg_u(ev: &Event, key: &str) -> u64 {
    match ev.args.iter().find(|(k, _)| *k == key) {
        Some((_, ArgValue::U(n))) => *n,
        other => panic!("event '{}' missing u64 arg '{key}': {other:?}", ev.name),
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

#[test]
fn registry_counts_exactly_under_concurrent_increment_storm() {
    // 8 threads x 10k increments against ONE underlying atomic (all
    // handles resolve to the same (name, labels) series): the total
    // must be exact, not approximately right.
    let reg = Registry::new();
    let threads = 8;
    let per_thread = 10_000u64;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                let c = reg.counter("obs_test_storm_total", "storm test");
                let h = reg.histogram("obs_test_storm_secs", "storm test");
                for _ in 0..per_thread {
                    c.inc();
                    h.observe(0.001);
                }
            });
        }
    });
    let want = threads * per_thread;
    assert_eq!(reg.counter("obs_test_storm_total", "storm test").get(), want);

    let h = reg.histogram("obs_test_storm_secs", "storm test");
    assert_eq!(h.count(), want);
    let (_, total) = *h.cumulative().last().unwrap();
    assert_eq!(total, want, "every observation lands in exactly one bucket");
    // the CAS-loop f64 sum is order-independent for identical addends:
    // each retry re-adds onto the freshest value, so the final sum is
    // the sequential fold bit-for-bit
    let sequential = (0..want).fold(0.0f64, |acc, _| acc + 0.001);
    assert_eq!(h.sum().to_bits(), sequential.to_bits());

    // the snapshot sees the same numbers the handles do
    let snap = reg.snapshot();
    let counter = snap.iter().find(|s| s.name == "obs_test_storm_total").unwrap();
    match &counter.value {
        SnapValue::Counter(n) => assert_eq!(*n, want),
        other => panic!("counter snapshotted as {other:?}"),
    }
}

#[test]
fn registry_histogram_brackets_agree_with_exact_latency_histogram() {
    // The bucketed exposition histogram can only bracket a quantile;
    // the bracket must always contain the exact nearest-rank quantile
    // the serve report computes from the full sample set.
    let mut rng = Rng::new(0xB0B5);
    let reg = Registry::new();
    let h = reg.histogram("obs_test_latency_secs", "agreement test");
    let mut exact = LatencyHistogram::default();
    for _ in 0..500 {
        // log-ish spread across the default bucket range
        let v = 1e-5 * (1.0 + rng.f64() * 9999.0);
        exact.record(v);
        h.observe(v);
    }
    assert_eq!(h.count(), exact.len() as u64);
    assert!((h.sum() - exact.sum()).abs() <= 1e-9 * exact.sum().max(1.0));
    for q in [0.0, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
        let want = exact.quantile(q);
        let (lo, hi) = h.quantile_bounds(q);
        assert!(
            lo <= want && want <= hi,
            "q={q}: exact quantile {want} outside bucket bracket [{lo}, {hi}]"
        );
    }
    // reload() mirrors an exact sample set: counts and sum must match
    h.reload(exact.samples().iter().copied());
    assert_eq!(h.count(), exact.len() as u64);
    assert!((h.sum() - exact.sum()).abs() <= 1e-9 * exact.sum().max(1.0));
}

// ---------------------------------------------------------------------------
// tracer
// ---------------------------------------------------------------------------

#[test]
fn tracer_ring_wraparound_keeps_newest_and_counts_drops() {
    let _g = tracer_lock();
    trace::enable(8);
    for i in 0..20u64 {
        trace::instant("obs_test_wrap", "test", trace::host_pid(), vec![("seq", ArgValue::U(i))]);
    }
    let events: Vec<Event> = trace::snapshot_events()
        .into_iter()
        .filter(|e| e.name == "obs_test_wrap")
        .collect();
    trace::disable();
    assert_eq!(events.len(), 8, "ring bounded at capacity");
    assert_eq!(trace::dropped(), 12, "overflow is counted, not silent");
    let seqs: Vec<u64> = events.iter().map(|e| arg_u(e, "seq")).collect();
    assert_eq!(seqs, (12..20).collect::<Vec<_>>(), "the OLDEST events are dropped");
}

#[test]
fn serving_with_tracing_enabled_is_bit_identical() {
    // Tracing never touches the f32 compute path or the modeled cost
    // ledger: predictions, NLLs and ladder attribution are bitwise
    // equal with the tracer on.
    let _g = tracer_lock();
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 8, 21);
    let run = || {
        let cfg = PipelineConfig {
            k_used: 2,
            devices: 2,
            replicate_top: 1,
            want_lm: true,
            want_cls: true,
            ..Default::default()
        };
        Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap().serve(&reqs).unwrap()
    };
    trace::disable();
    let plain = run();
    trace::enable(trace::DEFAULT_CAPACITY);
    let traced = run();
    trace::disable();
    assert!(trace::len() > 0, "the traced run must have recorded spans");
    assert_eq!(outputs(&plain), outputs(&traced), "tracing changed what serving computed");
    assert_eq!(
        plain.stats.hierarchy.ladder_secs().to_bits(),
        traced.stats.hierarchy.ladder_secs().to_bits(),
        "tracing changed the modeled ladder attribution"
    );
}

#[test]
fn request_done_span_components_reconcile_with_reported_latency() {
    // Self-consistency: the exact f64 components exposed on each
    // `request_done` instant must sum to the latency the serve report
    // recorded — same values, same addition order, bitwise equal.
    let _g = tracer_lock();
    let bundle = testkit::tiny_bundle();
    let p = Pipeline::new(
        bundle.clone(),
        TINY_PROFILE,
        PipelineConfig { want_cls: true, ..Default::default() },
    )
    .unwrap();
    let mix = ClassMix { interactive_frac: 0.5, deadline_secs: 10.0 };
    let reqs =
        testkit::tiny_trace_classed(&bundle, 6, 5, ArrivalProcess::Poisson { rate: 200.0 }, mix);
    trace::enable(trace::DEFAULT_CAPACITY);
    let report = replay_open_loop(&p, &reqs, 64).unwrap();
    trace::disable();
    let events = trace::snapshot_events();

    let done: Vec<&Event> = events.iter().filter(|e| e.name == "request_done").collect();
    assert_eq!(
        done.len(),
        report.outcome.stats.requests as usize,
        "one request_done instant per served request"
    );
    let mut latencies: Vec<f64> = Vec::new();
    for ev in &done {
        let latency = arg_f(ev, "latency_secs");
        let parts = arg_f(ev, "wait_secs") + arg_f(ev, "hash_secs") + arg_f(ev, "service_secs");
        assert_eq!(
            parts.to_bits(),
            latency.to_bits(),
            "request {}: span components {parts} != reported latency {latency}",
            arg_u(ev, "request")
        );
        latencies.push(latency);
    }
    // ... and those latencies are exactly what the report's histogram
    // recorded (order-normalized: both are per-request exact values)
    let mut recorded: Vec<f64> = report.outcome.stats.latency.samples().to_vec();
    recorded.sort_by(|a, b| a.total_cmp(b));
    latencies.sort_by(|a, b| a.total_cmp(b));
    assert_eq!(
        latencies.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        recorded.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "trace-exposed latencies drifted from the report histogram"
    );
    // every served request opened and closed its flow on the host
    for ev in &done {
        let fid = arg_u(ev, "request") + 1;
        assert!(events.iter().any(|e| e.ph == 's' && e.id == fid), "flow start missing");
        assert!(events.iter().any(|e| e.ph == 'f' && e.id == fid), "flow end missing");
    }
}

#[test]
fn faulted_cluster_trace_follows_requests_across_devices() {
    // ISSUE 9 acceptance: a 4-device faulted run with tracing on stays
    // bit-identical AND its trace follows a request id from batch
    // formation ('s' flow on the host) through per-layer lanes on >= 2
    // device timelines ('t' flows) to completion ('f'), with the fault
    // window and ladder promotions visible as instants.
    let _g = tracer_lock();
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 7);
    let run = || {
        let cfg = PipelineConfig {
            k_used: 2,
            devices: 4,
            replicate_top: 1,
            min_replicas: 2,
            fault_plan: "down:1@3..8".into(),
            want_lm: true,
            want_cls: true,
            ..Default::default()
        };
        Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap().serve(&reqs).unwrap()
    };
    trace::disable();
    let plain = run();
    trace::enable(trace::DEFAULT_CAPACITY);
    let traced = run();
    trace::disable();
    assert_eq!(outputs(&plain), outputs(&traced), "tracing changed a faulted cluster run");

    let events = trace::snapshot_events();
    // lanes computed on at least two distinct device timelines
    let lane_pids: BTreeSet<u32> =
        events.iter().filter(|e| e.name == "lane").map(|e| e.pid).collect();
    assert!(lane_pids.len() >= 2, "lane spans on one pid only: {lane_pids:?}");
    assert!(!lane_pids.contains(&trace::host_pid()), "lanes belong on device pids");

    // every flow step/end resolves to a start (Perfetto would render a
    // dangling arrow otherwise)
    let starts: BTreeSet<u64> =
        events.iter().filter(|e| e.ph == 's').map(|e| e.id).collect();
    assert!(!starts.is_empty(), "no flow starts recorded");
    for e in events.iter().filter(|e| e.ph == 't' || e.ph == 'f') {
        assert!(starts.contains(&e.id), "flow {} ({}) has no start", e.id, e.ph);
    }
    // ... and at least one request's flow steps across >= 2 devices
    let mut step_pids: BTreeMap<u64, BTreeSet<u32>> = BTreeMap::new();
    for e in events.iter().filter(|e| e.ph == 't') {
        step_pids.entry(e.id).or_default().insert(e.pid);
    }
    assert!(
        step_pids.values().any(|pids| pids.len() >= 2),
        "no request flowed across two devices: {step_pids:?}"
    );

    // the fault window and the ladder are visible
    let count = |name: &str| events.iter().filter(|e| e.name == name).count();
    assert!(count("device_down") >= 1, "the injected failure must appear");
    assert!(count("device_up") >= 1, "the recovery must appear");
    assert!(count("promote") >= 1, "cold fetches must appear as ladder promotions");

    // the export is a well-formed Chrome trace document: it parses,
    // names every pid, and round-trips the event count
    let doc = Json::parse(&trace::export_json().to_string()).unwrap();
    let arr = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let meta = arr
        .iter()
        .filter(|e| e.get_str("ph").is_ok_and(|p| p == "M"))
        .count();
    assert_eq!(arr.len(), meta + events.len());
    for pid in &lane_pids {
        let label = format!("device{}", pid - 1);
        assert!(
            arr.iter().any(|e| {
                e.get_str("name").is_ok_and(|n| n == "process_name")
                    && e.get("pid").unwrap().as_u64().unwrap() == *pid as u64
                    && e.get("args").unwrap().get_str("name").is_ok_and(|n| n == label)
            }),
            "device pid {pid} lacks a process_name metadata record"
        );
    }
}
