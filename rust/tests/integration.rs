//! Artifact-backed integration tests: load the real switch8 bundle and
//! check the Rust serving stack against the Python goldens emitted at
//! build time (`artifacts/switch8/golden.json`).
//!
//! These tests are skipped (with a visible message) if artifacts are
//! missing — run `make artifacts` first.

use std::path::PathBuf;
use std::sync::Arc;

use sida_moe::coordinator::HashBuilder;
use sida_moe::experts::{make_policy, ExpertCache};
use sida_moe::memory::CostModel;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::runtime::ModelBundle;
use sida_moe::util::json::Json;

fn artifacts_root() -> Option<PathBuf> {
    let root = sida_moe::default_artifacts_root();
    if root.join("switch8").join("model.json").is_file() {
        Some(root)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn bundle() -> Option<Arc<ModelBundle>> {
    let root = artifacts_root()?;
    Some(Arc::new(ModelBundle::load_named(&root, "switch8").expect("load bundle")))
}

fn golden(bundle: &ModelBundle) -> Json {
    let text =
        std::fs::read_to_string(bundle.engine.artifacts_dir().join("golden.json")).unwrap();
    Json::parse(&text).unwrap()
}

fn ids_of(sentence: &Json) -> Vec<Vec<i32>> {
    sentence
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            row.as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect()
        })
        .collect()
}

#[test]
fn manifest_weights_and_topology_consistent() {
    let Some(b) = bundle() else { return };
    let topo = &b.topology;
    // every expert of every MoE layer is individually addressable
    for &blk in &topo.moe_blocks {
        for e in 0..topo.num_experts {
            let bytes = b.weights.expert_bytes(blk, e).unwrap();
            assert_eq!(bytes, topo.expert_param_bytes, "expert ({blk},{e})");
        }
    }
    // Tab 2 shape: MoE bytes dominate as expert count grows; for switch8
    // at tiny dims just check the bookkeeping matches the manifest
    let moe_from_manifest: usize = topo
        .moe_blocks
        .iter()
        .map(|&blk| b.weights.bytes_with_prefix(&format!("blocks.{blk}.expert.")))
        .sum();
    assert_eq!(moe_from_manifest, topo.moe_param_bytes);
}

#[test]
fn router_decisions_match_python_golden() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let prof = g.get("profiles").unwrap().get("sst2").unwrap();
    let ids = ids_of(prof.get("ids").unwrap());
    let want_idx = prof.get("router_idx").unwrap(); // [B][M][L]
    let staged = runner.stage_all_experts().unwrap();
    for (s, sent_ids) in ids.iter().enumerate() {
        let mut provider = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(sent_ids, None, &mut provider, ForwardOptions::default())
            .unwrap();
        let mask = ModelRunner::mask_of(sent_ids);
        for (m, routing) in out.routing.iter().enumerate() {
            let want: Vec<usize> = want_idx.as_arr().unwrap()[s].as_arr().unwrap()[m]
                .usize_vec()
                .unwrap();
            for (t, (&got, &want)) in routing.top1.iter().zip(want.iter()).enumerate() {
                if mask[t] > 0.0 {
                    assert_eq!(got, want, "sentence {s} layer {m} token {t}");
                }
            }
        }
    }
}

#[test]
fn hash_tables_match_python_golden() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    for profile in ["sst2", "mrpc", "multirc"] {
        let builder = HashBuilder::new(&b, profile).unwrap();
        let prof = g.get("profiles").unwrap().get(profile).unwrap();
        let ids = ids_of(prof.get("ids").unwrap());
        let want = prof.get("hash_top_idx").unwrap(); // [B][L][M][K]
        for (s, sent_ids) in ids.iter().enumerate() {
            let table = builder.build(s as u64, sent_ids).unwrap();
            let ws = &want.as_arr().unwrap()[s];
            for t in 0..table.seq_len {
                for m in 0..table.m {
                    for r in 0..table.k {
                        let w = ws.as_arr().unwrap()[t].as_arr().unwrap()[m]
                            .as_arr()
                            .unwrap()[r]
                            .as_usize()
                            .unwrap();
                        assert_eq!(
                            table.expert_at(t, m, r),
                            w,
                            "{profile} s{s} t{t} m{m} r{r}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lm_logits_match_python_golden_slice() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let prof = g.get("profiles").unwrap().get("sst2").unwrap();
    let ids = ids_of(prof.get("ids").unwrap());
    let want_slice = prof.get("lm_logits_slice").unwrap(); // [B][4][8]
    let staged = runner.stage_all_experts().unwrap();
    let v = b.topology.vocab;
    for (s, sent_ids) in ids.iter().enumerate() {
        let mut provider = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(
                sent_ids,
                None,
                &mut provider,
                ForwardOptions { want_lm: true, want_cls: true, ..Default::default() },
            )
            .unwrap();
        let lm = out.lm_logits.unwrap();
        for t in 0..4 {
            for c in 0..8 {
                let want = want_slice.as_arr().unwrap()[s].as_arr().unwrap()[t]
                    .as_arr()
                    .unwrap()[c]
                    .as_f64()
                    .unwrap() as f32;
                let got = lm[t * v + c];
                assert!(
                    (got - want).abs() < 2e-2 + 0.01 * want.abs(),
                    "sentence {s} tok {t} vocab {c}: {got} vs {want}"
                );
            }
        }
        // classifier agreement
        let want_cls: Vec<f64> = prof.get("cls_logits").unwrap().as_arr().unwrap()[s]
            .f64_vec()
            .unwrap();
        let got_cls = out.cls_logits.unwrap();
        let got_arg = sida_moe::coordinator::argmax(&got_cls);
        let want_arg = want_cls
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(got_arg, want_arg, "sentence {s} classifier argmax");
    }
}

#[test]
fn sida_forward_equals_router_forward_when_hash_is_perfect() {
    // If we build a hash table FROM the router's decisions, the SiDA
    // path must reproduce the router path bit-for-bit (same experts,
    // same alphas).
    let Some(b) = bundle() else { return };
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let staged = runner.stage_all_experts().unwrap();
    let mut gen = sida_moe::workload::TraceGenerator::new(
        sida_moe::workload::Profile::named("sst2").unwrap(),
        b.topology.vocab,
        3,
    );
    let (ids, _, _) = gen.sentence();

    let mut provider = ExpertProvider::AllResident(&staged);
    let base = runner
        .forward(&ids, None, &mut provider, ForwardOptions { want_lm: true, ..Default::default() })
        .unwrap();

    // fabricate a "perfect" hash table from the observed routing
    let l = runner.seq_len;
    let m = b.topology.num_moe_layers();
    let k = b.topology.hash.top_k;
    let mut idx = vec![0i32; l * m * k];
    let mut alpha = vec![0f32; l * m * k];
    for (mi, routing) in base.routing.iter().enumerate() {
        for t in 0..l {
            let (e, a) = routing.assignments[t][0];
            idx[(t * m + mi) * k] = e as i32;
            alpha[(t * m + mi) * k] = a;
        }
    }
    let table = sida_moe::coordinator::HashTable::new(0, l, m, k, idx, alpha, 0.0).unwrap();

    let mut provider = ExpertProvider::AllResident(&staged);
    let sida = runner
        .forward(
            &ids,
            Some((&table, 1)),
            &mut provider,
            ForwardOptions { want_lm: true, ..Default::default() },
        )
        .unwrap();

    let base_lm = base.lm_logits.unwrap();
    let sida_lm = sida.lm_logits.unwrap();
    for (i, (a, c)) in base_lm.iter().zip(sida_lm.iter()).enumerate() {
        assert!((a - c).abs() < 1e-3, "lm logit {i}: {a} vs {c}");
    }
}

#[test]
fn cached_provider_matches_all_resident_numerically() {
    let Some(b) = bundle() else { return };
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let staged = runner.stage_all_experts().unwrap();
    let mut gen = sida_moe::workload::TraceGenerator::new(
        sida_moe::workload::Profile::named("sst2").unwrap(),
        b.topology.vocab,
        11,
    );
    let (ids, _, _) = gen.sentence();
    let mut p1 = ExpertProvider::AllResident(&staged);
    let o1 = runner.forward(&ids, None, &mut p1, ForwardOptions::default()).unwrap();

    let real = b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap();
    let mut cache = ExpertCache::new(
        1 << 30,
        CostModel::physical(real),
        make_policy("fifo").unwrap(),
    );
    let mut p2 = ExpertProvider::Cached { cache: &mut cache, blocking: true };
    let o2 = runner.forward(&ids, None, &mut p2, ForwardOptions::default()).unwrap();
    for (a, c) in o1.hidden.iter().zip(o2.hidden.iter()) {
        assert!((a - c).abs() < 1e-4);
    }
    cache.check_invariants().unwrap();
    assert!(cache.stats().misses > 0);

    // a second pass over the same sentence must be all hits
    let miss_before = cache.stats().misses;
    let mut p3 = ExpertProvider::Cached { cache: &mut cache, blocking: true };
    let _ = runner.forward(&ids, None, &mut p3, ForwardOptions::default()).unwrap();
    assert_eq!(cache.stats().misses, miss_before, "second pass should hit");
}

#[test]
fn host_literal_provider_matches_buffers() {
    let Some(b) = bundle() else { return };
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let staged = runner.stage_all_experts().unwrap();
    let mut gen = sida_moe::workload::TraceGenerator::new(
        sida_moe::workload::Profile::named("sst2").unwrap(),
        b.topology.vocab,
        13,
    );
    let (ids, _, _) = gen.sentence();
    let mut p1 = ExpertProvider::AllResident(&staged);
    let o1 = runner.forward(&ids, None, &mut p1, ForwardOptions::default()).unwrap();
    let mut p2 = ExpertProvider::HostLiterals;
    let o2 = runner.forward(&ids, None, &mut p2, ForwardOptions::default()).unwrap();
    for (a, c) in o1.hidden.iter().zip(o2.hidden.iter()) {
        assert!((a - c).abs() < 1e-4);
    }
}

#[test]
fn invoke_all_matches_selective_numerics() {
    // Standard's "invoke every expert" must not change outputs — idle
    // experts contribute zero (their token set is empty / zero alpha).
    let Some(b) = bundle() else { return };
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let staged = runner.stage_all_experts().unwrap();
    let mut gen = sida_moe::workload::TraceGenerator::new(
        sida_moe::workload::Profile::named("sst2").unwrap(),
        b.topology.vocab,
        17,
    );
    let (ids, _, _) = gen.sentence();
    let mut p1 = ExpertProvider::AllResident(&staged);
    let o1 = runner.forward(&ids, None, &mut p1, ForwardOptions::default()).unwrap();
    let mut p2 = ExpertProvider::AllResident(&staged);
    let o2 = runner
        .forward(
            &ids,
            None,
            &mut p2,
            ForwardOptions { invoke_all: true, fixed_bucket: true, ..Default::default() },
        )
        .unwrap();
    for (a, c) in o1.hidden.iter().zip(o2.hidden.iter()) {
        assert!((a - c).abs() < 1e-4);
    }
    assert!(o2.times.expert_invocations > o1.times.expert_invocations);
}

#[test]
fn lm_nll_matches_golden_mean() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let prof = g.get("profiles").unwrap().get("sst2").unwrap();
    let ids = ids_of(prof.get("ids").unwrap());
    let want_mean = prof.get_f64("lm_mean_nll").unwrap();
    let staged = runner.stage_all_experts().unwrap();
    let mut total_nll = 0.0;
    let mut total_tok = 0.0;
    for sent_ids in &ids {
        let mut p = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(
                sent_ids,
                None,
                &mut p,
                ForwardOptions { want_lm: true, ..Default::default() },
            )
            .unwrap();
        let (nll, cnt) = runner.lm_nll(&out.lm_logits.unwrap(), sent_ids).unwrap();
        total_nll += nll;
        total_tok += cnt;
    }
    let got_mean = total_nll / total_tok;
    assert!(
        (got_mean - want_mean).abs() < 0.02 * want_mean.abs() + 0.02,
        "mean NLL {got_mean} vs golden {want_mean}"
    );
}
