//! Hermetic integration tests: the full forward/routing/caching contract
//! exercised on the synthetic testkit bundle — no Python artifacts, no
//! PJRT, runs everywhere `cargo test` runs.
//!
//! The paper-fidelity core lives here: a hash artifact with 100% router
//! agreement must yield logits *identical* to the dense baseline
//! (SiDA-MoE's Tab 3/4 contract), and the expert-provider variants
//! (all-resident buffers, the budgeted cache, host literals) must be
//! numerically interchangeable.

use std::sync::Arc;

use sida_moe::coordinator::{HashBuilder, HashTable};
use sida_moe::experts::{make_policy, ExpertCache, SharedExpertCache};
use sida_moe::memory::CostModel;
use sida_moe::model::{BatchItem, ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, TINY_PROFILE};
use sida_moe::util::pool::WorkerPool;

fn runner(b: &Arc<ModelBundle>) -> ModelRunner {
    ModelRunner::new(b.clone(), TINY_PROFILE).unwrap()
}

fn sentence(b: &ModelBundle, seed: u64) -> Vec<i32> {
    testkit::tiny_trace(b, 1, seed).remove(0).ids
}

#[test]
fn synthetic_manifest_weights_and_topology_consistent() {
    let b = testkit::tiny_bundle();
    let topo = &b.topology;
    for &blk in &topo.moe_blocks {
        for e in 0..topo.num_experts {
            let bytes = b.weights.expert_bytes(blk, e).unwrap();
            assert_eq!(bytes, topo.expert_param_bytes, "expert ({blk},{e})");
        }
    }
    let moe_from_manifest: usize = topo
        .moe_blocks
        .iter()
        .map(|&blk| b.weights.bytes_with_prefix(&format!("blocks.{blk}.expert.")))
        .sum();
    assert_eq!(moe_from_manifest, topo.moe_param_bytes);
    assert!(topo.total_param_bytes > topo.moe_param_bytes);
}

#[test]
fn all_expert_providers_agree_exactly() {
    let b = testkit::tiny_bundle();
    let r = runner(&b);
    let ids = sentence(&b, 11);
    let staged = r.stage_all_experts().unwrap();

    let mut p1 = ExpertProvider::AllResident(&staged);
    let o1 = r.forward(&ids, None, &mut p1, ForwardOptions::default()).unwrap();

    let mut p2 = ExpertProvider::HostLiterals;
    let o2 = r.forward(&ids, None, &mut p2, ForwardOptions::default()).unwrap();
    assert_eq!(o1.hidden, o2.hidden, "host literals vs staged buffers");

    let real = b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap();
    let mut cache =
        ExpertCache::new(1 << 30, CostModel::physical(real), make_policy("fifo").unwrap());
    let mut p3 = ExpertProvider::Cached { cache: &mut cache, blocking: true };
    let o3 = r.forward(&ids, None, &mut p3, ForwardOptions::default()).unwrap();
    assert_eq!(o1.hidden, o3.hidden, "cached vs staged buffers");
    cache.check_invariants().unwrap();
    assert!(cache.stats().misses > 0);

    // a second pass over the same sentence must be all hits
    let miss_before = cache.stats().misses;
    let mut p4 = ExpertProvider::Cached { cache: &mut cache, blocking: true };
    let _ = r.forward(&ids, None, &mut p4, ForwardOptions::default()).unwrap();
    assert_eq!(cache.stats().misses, miss_before, "second pass should hit");
    assert!(cache.stats().hit_rate().unwrap() > 0.0);
}

#[test]
fn perfect_hash_routing_equals_dense_baseline_exactly() {
    // Acceptance criterion: agreement = 1.0 -> the SiDA path (routers
    // never execute; the hash table decides) reproduces the dense
    // baseline's logits bit-for-bit.
    let b = testkit::tiny_bundle(); // agreement = 1.0
    let r = runner(&b);
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let staged = r.stage_all_experts().unwrap();
    for seed in 0..5 {
        let ids = sentence(&b, seed);
        let opts = ForwardOptions { want_lm: true, want_cls: true, ..Default::default() };

        let mut pb = ExpertProvider::AllResident(&staged);
        let base = r.forward(&ids, None, &mut pb, opts).unwrap();

        let table = builder.build(seed, &ids).unwrap();
        let mut ps = ExpertProvider::AllResident(&staged);
        let sida = r.forward(&ids, Some((&table, 1)), &mut ps, opts).unwrap();

        assert_eq!(base.hidden, sida.hidden, "seed {seed}: hidden states diverged");
        assert_eq!(
            base.lm_logits.unwrap(),
            sida.lm_logits.unwrap(),
            "seed {seed}: lm logits diverged"
        );
        assert_eq!(
            base.cls_logits.unwrap(),
            sida.cls_logits.unwrap(),
            "seed {seed}: cls logits diverged"
        );
        // and the hash table's top-1 is exactly the router's decision
        let mask = ModelRunner::mask_of(&ids);
        for (m, routing) in base.routing.iter().enumerate() {
            for t in 0..r.seq_len {
                if mask[t] > 0.0 {
                    assert_eq!(routing.top1[t], table.expert_at(t, m, 0));
                }
            }
        }
    }
}

#[test]
fn batched_forward_matches_sequential_bit_for_bit() {
    // Acceptance criterion: at agreement = 1.0 the cross-request
    // batched path reproduces the sequential batch-1 logits bit-for-bit
    // for every request — mixed true lengths (different padding) in one
    // batch, under both hash routing and router routing.
    let b = testkit::tiny_bundle(); // agreement = 1.0
    let r = runner(&b);
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let staged = r.stage_all_experts().unwrap();
    let reqs = testkit::tiny_trace(&b, 6, 31);
    let opts = ForwardOptions { want_lm: true, want_cls: true, ..Default::default() };
    let tables: Vec<_> = reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();

    // hash-routed batch (the SiDA serving path)
    let items: Vec<BatchItem<'_>> = reqs
        .iter()
        .zip(tables.iter())
        .map(|(q, t)| BatchItem { ids: &q.ids[..], hash: Some((t, 1)) })
        .collect();
    let mut pb = ExpertProvider::AllResident(&staged);
    let batch = r.forward_batch(&items, &mut pb, opts).unwrap();
    assert_eq!(batch.outputs.len(), reqs.len());
    let mut sequential_invocations = 0u64;
    for ((q, t), out) in reqs.iter().zip(tables.iter()).zip(batch.outputs.iter()) {
        let mut p = ExpertProvider::AllResident(&staged);
        let seq = r.forward(&q.ids, Some((t, 1)), &mut p, opts).unwrap();
        sequential_invocations += seq.times.expert_invocations;
        assert_eq!(seq.hidden, out.hidden, "request {}: hidden diverged", q.id);
        assert_eq!(seq.lm_logits, out.lm_logits, "request {}: lm logits diverged", q.id);
        assert_eq!(seq.cls_logits, out.cls_logits, "request {}: cls logits diverged", q.id);
        assert_eq!(seq.routing.len(), out.routing.len());
        for (a, c) in seq.routing.iter().zip(out.routing.iter()) {
            assert_eq!(a.top1, c.top1, "request {}: routing diverged", q.id);
        }
    }
    // expert sharing: one invocation per activated expert per batch can
    // never exceed the per-request sum, and is bounded by the pool size
    assert!(batch.times.expert_invocations <= sequential_invocations);
    assert!(
        batch.times.expert_invocations
            <= (b.topology.num_experts * b.topology.num_moe_layers()) as u64
    );

    // router-routed batch (no hash tables) must match too
    let items: Vec<BatchItem<'_>> =
        reqs.iter().map(|q| BatchItem { ids: &q.ids[..], hash: None }).collect();
    let mut pb = ExpertProvider::AllResident(&staged);
    let batch = r.forward_batch(&items, &mut pb, opts).unwrap();
    for (q, out) in reqs.iter().zip(batch.outputs.iter()) {
        let mut p = ExpertProvider::AllResident(&staged);
        let seq = r.forward(&q.ids, None, &mut p, opts).unwrap();
        assert_eq!(seq.hidden, out.hidden, "request {}: router-mode hidden diverged", q.id);
        assert_eq!(seq.lm_logits, out.lm_logits);
        assert_eq!(seq.cls_logits, out.cls_logits);
    }
}

#[test]
fn duplicated_sentence_batch_shares_expert_invocations_strictly() {
    // The same sentence twice in one batch activates the same experts,
    // so the batch must issue strictly fewer invocations than the two
    // sequential forwards — while staying bit-identical.
    let b = testkit::tiny_bundle();
    let r = runner(&b);
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let staged = r.stage_all_experts().unwrap();
    let ids = sentence(&b, 13);
    let table = builder.build(0, &ids).unwrap();
    let opts = ForwardOptions::default();

    let mut p = ExpertProvider::AllResident(&staged);
    let seq = r.forward(&ids, Some((&table, 1)), &mut p, opts).unwrap();

    let items = vec![
        BatchItem { ids: &ids[..], hash: Some((&table, 1)) },
        BatchItem { ids: &ids[..], hash: Some((&table, 1)) },
    ];
    let mut pb = ExpertProvider::AllResident(&staged);
    let batch = r.forward_batch(&items, &mut pb, opts).unwrap();
    assert_eq!(batch.outputs[0].hidden, seq.hidden);
    assert_eq!(batch.outputs[1].hidden, seq.hidden);
    assert_eq!(
        batch.times.expert_invocations, seq.times.expert_invocations,
        "the duplicate's experts must ride the same invocations"
    );
    assert!(batch.times.expert_invocations < 2 * seq.times.expert_invocations);
}

#[test]
fn pooled_forward_is_bit_identical_across_pool_sizes() {
    // Acceptance criterion: the parallel expert path must reproduce the
    // sequential path bit-for-bit at every pool width — compute order
    // varies with the pool, but scatter order (and therefore every f32
    // accumulation chain) does not.
    let b = testkit::tiny_bundle();
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let reqs = testkit::tiny_trace(&b, 5, 77);
    let tables: Vec<_> =
        reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();
    let opts = ForwardOptions { want_lm: true, want_cls: true, ..Default::default() };

    // reference: fully sequential (pool width 1)
    let mut reference: Option<Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>> = None;
    for threads in [1usize, 2, 8] {
        let r = ModelRunner::with_pool(b.clone(), TINY_PROFILE, WorkerPool::new(threads))
            .unwrap();
        assert_eq!(r.pool_threads(), threads);
        let staged = r.stage_all_experts().unwrap();

        // per-request forwards
        let mut outs = Vec::new();
        for (q, t) in reqs.iter().zip(tables.iter()) {
            let mut p = ExpertProvider::AllResident(&staged);
            let o = r.forward(&q.ids, Some((t, 1)), &mut p, opts).unwrap();
            outs.push((o.hidden, o.lm_logits.unwrap(), o.cls_logits.unwrap()));
        }
        // the batched forward at this pool width must agree with the
        // per-request forwards at the same width
        let items: Vec<BatchItem<'_>> = reqs
            .iter()
            .zip(tables.iter())
            .map(|(q, t)| BatchItem { ids: &q.ids[..], hash: Some((t, 1)) })
            .collect();
        let mut pb = ExpertProvider::AllResident(&staged);
        let batch = r.forward_batch(&items, &mut pb, opts).unwrap();
        for (seq, out) in outs.iter().zip(batch.outputs.iter()) {
            assert_eq!(seq.0, out.hidden, "pool {threads}: batch hidden diverged");
            assert_eq!(&seq.1, out.lm_logits.as_ref().unwrap());
            assert_eq!(&seq.2, out.cls_logits.as_ref().unwrap());
        }
        match &reference {
            None => reference = Some(outs),
            Some(want) => {
                for (i, (w, g)) in want.iter().zip(outs.iter()).enumerate() {
                    assert_eq!(w.0, g.0, "pool {threads}: request {i} hidden diverged");
                    assert_eq!(w.1, g.1, "pool {threads}: request {i} lm logits diverged");
                    assert_eq!(w.2, g.2, "pool {threads}: request {i} cls logits diverged");
                }
            }
        }
    }
}

#[test]
fn pooled_forward_through_shared_cache_matches_all_resident() {
    // The worker pool resolving residency through the RwLock'd shared
    // cache (pins, concurrent ensure) must agree exactly with the
    // all-resident provider at pool width 1.
    let b = testkit::tiny_bundle();
    let real = b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap();
    let reqs = testkit::tiny_trace(&b, 4, 41);
    let opts = ForwardOptions { want_lm: true, ..Default::default() };

    let seq_runner =
        ModelRunner::with_pool(b.clone(), TINY_PROFILE, WorkerPool::new(1)).unwrap();
    let staged = seq_runner.stage_all_experts().unwrap();

    let par_runner =
        ModelRunner::with_pool(b.clone(), TINY_PROFILE, WorkerPool::new(8)).unwrap();
    let shared = SharedExpertCache::new(ExpertCache::new(
        1 << 30,
        CostModel::physical(real),
        make_policy("fifo").unwrap(),
    ));

    for q in &reqs {
        let mut p_ref = ExpertProvider::AllResident(&staged);
        let want = seq_runner.forward(&q.ids, None, &mut p_ref, opts).unwrap();
        let mut p_shared = ExpertProvider::Shared { cache: &shared, blocking: true };
        let got = par_runner.forward(&q.ids, None, &mut p_shared, opts).unwrap();
        assert_eq!(want.hidden, got.hidden, "request {}: hidden diverged", q.id);
        assert_eq!(want.lm_logits, got.lm_logits, "request {}: lm diverged", q.id);
    }
    shared.check_invariants().unwrap();
    let stats = shared.stats();
    assert!(stats.misses > 0, "cold shared cache must fetch");
    assert!(stats.hits > 0, "repeated experts must hit the read path");
}

#[test]
fn zero_agreement_hash_contradicts_router_everywhere() {
    let b = testkit::bundle_with_agreement(0.0);
    let r = runner(&b);
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let staged = r.stage_all_experts().unwrap();
    let ids = sentence(&b, 3);
    let mask = ModelRunner::mask_of(&ids);

    let mut p = ExpertProvider::AllResident(&staged);
    let base = r
        .forward(&ids, None, &mut p, ForwardOptions { want_lm: true, ..Default::default() })
        .unwrap();
    let table = builder.build(0, &ids).unwrap();
    for (m, routing) in base.routing.iter().enumerate() {
        for t in 0..r.seq_len {
            if mask[t] > 0.0 {
                assert_ne!(
                    routing.top1[t],
                    table.expert_at(t, m, 0),
                    "layer {m} token {t}: corrupted hash still agrees"
                );
            }
        }
    }
    // routing through wrong experts must actually change the output
    let mut p2 = ExpertProvider::AllResident(&staged);
    let sida = r
        .forward(
            &ids,
            Some((&table, 1)),
            &mut p2,
            ForwardOptions { want_lm: true, ..Default::default() },
        )
        .unwrap();
    assert_ne!(base.lm_logits.unwrap(), sida.lm_logits.unwrap());
}

#[test]
fn hash_builder_is_deterministic_per_sentence() {
    let b = testkit::bundle_with_agreement(0.6);
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let ids = sentence(&b, 9);
    let t1 = builder.build(0, &ids).unwrap();
    let t2 = builder.build(1, &ids).unwrap();
    assert_eq!(t1.idx, t2.idx, "same sentence must hash identically");
    assert_eq!(t1.alpha, t2.alpha);
    assert_eq!(t2.batch_id, 1);
    assert_eq!(t1.m, b.topology.num_moe_layers());
    assert_eq!(t1.k, b.topology.hash.top_k);
}

#[test]
fn invoke_all_matches_selective_numerics() {
    // Standard's "invoke every expert" must not change outputs — idle
    // experts contribute zero (their token set is empty / zero alpha).
    let b = testkit::tiny_bundle();
    let r = runner(&b);
    let staged = r.stage_all_experts().unwrap();
    let ids = sentence(&b, 17);
    let mut p1 = ExpertProvider::AllResident(&staged);
    let o1 = r.forward(&ids, None, &mut p1, ForwardOptions::default()).unwrap();
    let mut p2 = ExpertProvider::AllResident(&staged);
    let o2 = r
        .forward(
            &ids,
            None,
            &mut p2,
            ForwardOptions { invoke_all: true, fixed_bucket: true, ..Default::default() },
        )
        .unwrap();
    for (a, c) in o1.hidden.iter().zip(o2.hidden.iter()) {
        assert!((a - c).abs() < 1e-4);
    }
    assert!(o2.times.expert_invocations > o1.times.expert_invocations);
    assert_eq!(
        o2.times.expert_invocations,
        (b.topology.num_experts * b.topology.num_moe_layers()) as u64
    );
}

#[test]
fn lm_nll_matches_manual_reference() {
    let b = testkit::tiny_bundle();
    let r = runner(&b);
    let staged = r.stage_all_experts().unwrap();
    let ids = sentence(&b, 23);
    let mut p = ExpertProvider::AllResident(&staged);
    let out = r
        .forward(&ids, None, &mut p, ForwardOptions { want_lm: true, ..Default::default() })
        .unwrap();
    let lm = out.lm_logits.unwrap();
    let (nll, cnt) = r.lm_nll(&lm, &ids).unwrap();

    // naive reference: next-token NLL over real target positions
    let v = b.topology.vocab;
    let l = r.seq_len;
    let mask = ModelRunner::mask_of(&ids);
    let mut want_nll = 0.0f64;
    let mut want_cnt = 0.0f64;
    for t in 0..l - 1 {
        let row = &lm[t * v..(t + 1) * v];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse = row.iter().map(|&x| ((x as f64) - mx).exp()).sum::<f64>().ln() + mx;
        let logp = lm[t * v + ids[t + 1] as usize] as f64 - lse;
        want_nll += -logp * mask[t + 1] as f64;
        want_cnt += mask[t + 1] as f64;
    }
    assert!((cnt - want_cnt).abs() < 1e-6, "token count {cnt} vs {want_cnt}");
    assert!((nll - want_nll).abs() < 1e-3, "nll {nll} vs {want_nll}");
}

#[test]
fn routing_from_hash_clamps_k_to_table() {
    let b = testkit::tiny_bundle();
    let r = runner(&b);
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let ids = sentence(&b, 2);
    let table = builder.build(0, &ids).unwrap();
    // k_used far beyond table.k must not panic and uses at most k experts
    let routing = r.routing_from_hash(&table, 0, 99);
    for assign in &routing.assignments {
        assert!(assign.len() <= table.k);
        let total: f32 = assign.iter().map(|(_, a)| *a).sum();
        assert!(total.is_finite());
    }
}

#[test]
fn fabricated_hash_table_drives_routing() {
    // A hand-built table (every token -> expert 0) must route every
    // masked token to expert 0 — the mechanism golden.rs uses to check
    // perfect-hash equivalence on real artifacts.
    let b = testkit::tiny_bundle();
    let r = runner(&b);
    let staged = r.stage_all_experts().unwrap();
    let ids = sentence(&b, 5);
    let l = r.seq_len;
    let m = b.topology.num_moe_layers();
    let k = b.topology.hash.top_k;
    let idx = vec![0i32; l * m * k];
    let alpha = vec![0.5f32; l * m * k];
    let table = HashTable::new(0, l, m, k, idx, alpha, 0.0).unwrap();
    let mut p = ExpertProvider::AllResident(&staged);
    let out = r.forward(&ids, Some((&table, 1)), &mut p, ForwardOptions::default()).unwrap();
    for routing in &out.routing {
        assert!(routing.top1.iter().all(|&e| e == 0));
    }
    // exactly one expert invoked per MoE layer
    assert_eq!(out.times.expert_invocations, m as u64);
}
