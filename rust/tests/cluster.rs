//! Multi-device expert-parallel serving: hermetic cluster invariants.
//!
//! The contract under test (ISSUE 4 acceptance criteria):
//! * N-device forwards are **bit-identical** to the single-device path
//!   for devices ∈ {1, 2, 4} — placement and replica steering decide
//!   only *where* an invocation computes, never what it returns;
//! * placement covers every (layer, expert) exactly once (plus
//!   replicas), and replication never exceeds per-device budgets;
//! * per-device expert memory shrinks as the fleet grows at a fixed
//!   replication factor;
//! * the load-imbalance statistic is sane (>= 1.0, finite, rows
//!   conserved).

use std::sync::Arc;

use sida_moe::cluster::{ActivationProfile, ClusterConfig, ClusterRouter, FaultPlan, PlacementPlanner};
use sida_moe::coordinator::{HashBuilder, Pipeline, PipelineConfig, ServeOutcome};
use sida_moe::experts::ExpertKey;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::prop::Prop;

fn deep_bundle() -> Arc<ModelBundle> {
    testkit::bundle(&SynthSpec::default().two_moe_layers()).unwrap()
}

fn sim_expert_bytes(b: &ModelBundle) -> usize {
    let real = b.weights.expert_bytes(b.topology.moe_blocks[0], 0).unwrap();
    sida_moe::memory::CostModel::paper_scale(real).sim_bytes(real)
}

#[test]
fn cluster_forward_bit_identical_across_device_counts() {
    // Acceptance criterion: the cluster provider must reproduce the
    // all-resident single-device forward bit-for-bit at 1, 2 and 4
    // devices, for several sentences, including hash routing.
    let b = deep_bundle();
    let r = ModelRunner::new(b.clone(), TINY_PROFILE).unwrap();
    let builder = HashBuilder::new(&b, TINY_PROFILE).unwrap();
    let staged = r.stage_all_experts().unwrap();
    let opts = ForwardOptions { want_lm: true, want_cls: true, ..Default::default() };
    let reqs = testkit::tiny_trace(&b, 4, 51);

    for devices in [1usize, 2, 4] {
        let router = ClusterRouter::new(
            &b,
            &ClusterConfig { devices, replicate_top: 1, ..ClusterConfig::default() },
        )
        .unwrap();
        for q in &reqs {
            let table = builder.build(q.id, &q.ids).unwrap();
            let mut p_ref = ExpertProvider::AllResident(&staged);
            let want = r.forward(&q.ids, Some((&table, 1)), &mut p_ref, opts).unwrap();
            let mut p_cluster = ExpertProvider::Cluster { router: &router, blocking: true };
            let got = r.forward(&q.ids, Some((&table, 1)), &mut p_cluster, opts).unwrap();
            assert_eq!(
                want.hidden, got.hidden,
                "devices={devices} req={}: hidden diverged",
                q.id
            );
            assert_eq!(want.lm_logits, got.lm_logits, "devices={devices}: lm diverged");
            assert_eq!(want.cls_logits, got.cls_logits, "devices={devices}: cls diverged");
        }
        router.check_invariants().unwrap();
    }
}

#[test]
fn pipeline_cluster_serving_matches_single_device_exactly() {
    // End-to-end: the full pipeline (hash thread, prefetch stages,
    // layer-ahead warmer, batched forward) must produce identical
    // predictions and LM NLLs at every device count.
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 10, 33);
    let mut reference: Option<Vec<(Option<usize>, Option<f64>)>> = None;
    for devices in [1usize, 2, 4] {
        let cfg = PipelineConfig {
            k_used: 2,
            devices,
            replicate_top: 1,
            want_lm: true,
            want_cls: true,
            ..Default::default()
        };
        let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
        let out = p.serve(&reqs).unwrap();
        assert_eq!(out.stats.requests, reqs.len() as u64);
        let got: Vec<(Option<usize>, Option<f64>)> = out
            .per_request
            .iter()
            .map(|r| (r.cls_pred, r.lm_nll))
            .collect();
        match &reference {
            None => reference = Some(got),
            Some(want) => {
                // bit-identical logits imply exactly equal argmax + NLL
                assert_eq!(want, &got, "devices={devices}: outputs diverged");
            }
        }
        if devices > 1 {
            let cluster = out.stats.cluster.expect("cluster stats must be reported");
            assert_eq!(cluster.devices.len(), devices);
            // every device's ladder is driven by its own cache: the
            // Device-tier occupancy IS the cache residency, and the
            // aggregate ladder lands in the top-level ServeStats
            let mut agg_ssd = 0.0;
            for d in &cluster.devices {
                assert_eq!(
                    d.hierarchy.device_bytes, d.used_bytes,
                    "device {}: ledger Device tier drifted from the cache",
                    d.device
                );
                agg_ssd += d.hierarchy.ssd_promote_secs;
            }
            assert!(
                (out.stats.hierarchy.ssd_promote_secs - agg_ssd).abs() < 1e-12,
                "ServeStats hierarchy must aggregate the per-device ledgers"
            );
            if let Some(router) = &p.cluster {
                router.placement().check_invariants(&b.topology).unwrap();
                router.check_invariants().unwrap();
            }
        } else {
            assert!(out.stats.cluster.is_none(), "single device reports no cluster");
        }
    }
}

#[test]
fn per_device_memory_shrinks_as_devices_grow() {
    // Acceptance criterion: at a fixed replication factor, the worst
    // device's expert footprint strictly decreases with device count.
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 16, 5);
    let sim = sim_expert_bytes(&b);
    let mut assigned = Vec::new();
    let mut peaks = Vec::new();
    for devices in [1usize, 2, 4] {
        let cfg = PipelineConfig {
            k_used: 2,
            budget_sim_bytes: 64 * sim,
            devices,
            replicate_top: 1,
            ..Default::default()
        };
        let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
        let out = p.serve(&reqs).unwrap();
        let per_device_assigned = match &out.stats.cluster {
            Some(cl) => cl.max_device_assigned(),
            None => b.topology.moe_blocks.len() * b.topology.num_experts,
        };
        assigned.push(per_device_assigned * sim);
        peaks.push(out.stats.peak_device_bytes);
    }
    assert!(
        assigned.windows(2).all(|w| w[1] < w[0]),
        "per-device assigned bytes must strictly decrease: {assigned:?}"
    );
    assert!(
        peaks[2] < peaks[0],
        "4-device worst peak {} must be below the single-device peak {}",
        peaks[2],
        peaks[0]
    );
}

#[test]
fn replication_and_residency_respect_per_device_budgets() {
    // A budget with room for ⌈E/N⌉ homes + 1 leaves exactly one replica
    // slot per device; placement must not exceed it and the runtime
    // caches must never exceed the byte budget.
    let b = deep_bundle();
    let e = b.topology.num_experts;
    let sim = sim_expert_bytes(&b);
    let devices = 2usize;
    let capacity = e.div_ceil(devices) + 1;
    let cfg = PipelineConfig {
        k_used: 2,
        budget_sim_bytes: capacity * sim + sim / 2, // room for `capacity` experts
        devices,
        replicate_top: e, // ask for far more replication than fits
        ..Default::default()
    };
    let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&testkit::tiny_trace(&b, 8, 11)).unwrap();
    let router = p.cluster.as_ref().expect("cluster mode");
    let placement = router.placement();
    placement.check_invariants(&b.topology).unwrap();
    for dev in 0..devices {
        // per layer the device homes at most ⌈E/N⌉; across both layers
        // plus replicas it must stay within the modeled capacity
        assert!(
            placement.assigned_to(dev) <= capacity * b.topology.moe_blocks.len(),
            "device {dev} over-assigned: {} entries for capacity {capacity}/layer",
            placement.assigned_to(dev)
        );
        let cache = router.device_cache(dev);
        assert!(
            cache.used() <= cache.budget(),
            "device {dev} cache over budget: {} > {}",
            cache.used(),
            cache.budget()
        );
    }
    let cluster = out.stats.cluster.expect("cluster stats");
    for d in &cluster.devices {
        assert!(d.peak_bytes <= d.budget_bytes, "device {} peak over budget", d.device);
    }
}

#[test]
fn load_imbalance_stat_is_sane() {
    let b = deep_bundle();
    let cfg = PipelineConfig { k_used: 2, devices: 4, replicate_top: 1, ..Default::default() };
    let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
    let out = p.serve(&testkit::tiny_trace(&b, 12, 2)).unwrap();
    let cluster = out.stats.cluster.expect("cluster stats");
    let imb = cluster.load_imbalance().expect("work was dispatched");
    assert!(imb >= 1.0, "imbalance {imb} below the max/mean floor");
    assert!(imb <= cluster.devices.len() as f64 + 1e-9, "imbalance {imb} above N");
    assert!(imb.is_finite());
    // rows are conserved: the per-device loads sum to the total rows
    let total: u64 = cluster.devices.iter().map(|d| d.rows).sum();
    assert!(total > 0);
    // bucket-weighted lane balancing: each device's dispatched compute
    // (rows rounded up to the kernel's padded chunks) is at least its
    // raw rows, and the compute-imbalance stat is well-formed
    for d in &cluster.devices {
        assert!(
            d.bucket_units >= d.rows,
            "device {}: bucket units {} below raw rows {}",
            d.device,
            d.bucket_units,
            d.rows
        );
    }
    let cimb = cluster.compute_imbalance().expect("compute was dispatched");
    assert!(cimb >= 1.0 && cimb.is_finite());
    // interconnect charged only when work left the primary
    let off_primary: u64 =
        cluster.devices.iter().filter(|d| d.device != 0).map(|d| d.rows).sum();
    if off_primary > 0 {
        assert!(cluster.cross_device_bytes > 0);
        assert!(cluster.interconnect_secs > 0.0);
    }
}

/// Exact per-request outputs, order-normalized: bit-identical logits
/// imply exactly equal argmax + NLL.
fn outputs(out: &ServeOutcome) -> Vec<(u64, Option<usize>, Option<f64>)> {
    let mut v: Vec<_> =
        out.per_request.iter().map(|r| (r.id, r.cls_pred, r.lm_nll)).collect();
    v.sort_by_key(|(id, ..)| *id);
    assert!(!v.is_empty());
    v
}

/// One cluster serving run under `fault_plan` ("" = fault-free).
fn run_with_faults(
    b: &Arc<ModelBundle>,
    reqs: &[sida_moe::workload::Request],
    devices: usize,
    min_replicas: usize,
    fault_plan: &str,
) -> ServeOutcome {
    let cfg = PipelineConfig {
        k_used: 2,
        devices,
        replicate_top: 1,
        min_replicas,
        fault_plan: fault_plan.into(),
        want_lm: true,
        want_cls: true,
        ..Default::default()
    };
    let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
    let out = p.serve(reqs).unwrap();
    let router = p.cluster.as_ref().expect("cluster mode");
    router.check_invariants().unwrap();
    router.placement().check_invariants(&b.topology).unwrap();
    // per-device budgets hold under every fault schedule
    for dev in 0..devices {
        let cache = router.device_cache(dev);
        assert!(
            cache.used() <= cache.budget(),
            "device {dev} cache over budget under plan '{fault_plan}'"
        );
    }
    out
}

#[test]
fn faulted_cluster_serving_is_bit_identical_and_accounted() {
    // ISSUE 8 acceptance: 1 of 4 devices down mid-trace with later
    // recovery — serving continues (zero hung requests), outputs are
    // bit-identical to the fault-free run, and the failover work is
    // visible in ClusterStats.
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 7);

    let clean = run_with_faults(&b, &reqs, 4, 2, "");
    // batch-1 serving ticks once per request: device 1 crashes on tick
    // 3 (in-flight lanes retry), is Down for ticks 4..7, recovers at 8
    let faulted = run_with_faults(&b, &reqs, 4, 2, "down:1@3..8");

    assert_eq!(
        faulted.stats.requests,
        reqs.len() as u64,
        "every request must complete exactly once — none hung, none lost"
    );
    assert_eq!(
        outputs(&faulted),
        outputs(&clean),
        "a fault schedule may move work, never change what it computes"
    );
    let cl = faulted.stats.cluster.expect("cluster stats");
    assert_eq!(cl.device_failures, 1);
    assert_eq!(cl.recoveries, 1);
    assert!(cl.failovers > 0, "the evacuated experts are failovers");
    assert!(cl.downtime_secs > 0.0, "the outage has measured wall duration");
    // the fault-free run reports a quiet fault ledger
    let quiet = clean.stats.cluster.expect("cluster stats");
    assert_eq!(quiet.device_failures, 0);
    assert_eq!(quiet.failovers, 0);
    assert_eq!(quiet.retries, 0);
    assert_eq!(quiet.downtime_secs, 0.0);
}

#[test]
fn random_fault_schedules_never_change_outputs_or_break_invariants() {
    // Property: for random seeded fault schedules x devices {2,4} x
    // min-replicas {1,2}, serving completes every request exactly
    // once, outputs match the fault-free run bit-for-bit, budgets
    // hold, and the router invariants stay clean (all checked inside
    // `run_with_faults`).
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 8, 3);
    let clean: std::collections::HashMap<(usize, usize), Vec<(u64, Option<usize>, Option<f64>)>> =
        [(2usize, 1usize), (2, 2), (4, 1), (4, 2)]
            .into_iter()
            .map(|(d, k)| ((d, k), outputs(&run_with_faults(&b, &reqs, d, k, ""))))
            .collect();
    Prop::new(10).check(
        "fault schedules preserve outputs",
        |rng| {
            let devices = if rng.below(2) == 0 { 2usize } else { 4 };
            let min_replicas = 1 + rng.usize_below(2);
            let seed = rng.below(1 << 20);
            (devices, min_replicas, seed)
        },
        |_| Vec::new(),
        |(devices, min_replicas, seed)| {
            let plan = FaultPlan::seeded_random(*seed, *devices, reqs.len() as u64).to_string();
            let out = run_with_faults(&b, &reqs, *devices, *min_replicas, &plan);
            if out.stats.requests != reqs.len() as u64 {
                return Err(format!(
                    "plan '{plan}': {} of {} requests served",
                    out.stats.requests,
                    reqs.len()
                ));
            }
            let want = &clean[&(*devices, *min_replicas)];
            if &outputs(&out) != want {
                return Err(format!("plan '{plan}': outputs diverged from fault-free run"));
            }
            Ok(())
        },
    );
}

#[test]
fn placement_invariants_hold_for_random_profiles() {
    // Property: whatever the observed activation profile, every
    // (layer, expert) keeps exactly one home, replicas stay within
    // capacity, and holders are well-formed.
    let b = deep_bundle();
    let topo = b.topology.clone();
    let moe_blocks = topo.moe_blocks.clone();
    let e = topo.num_experts;
    Prop::new(48).check(
        "cluster placement invariants",
        |rng| {
            let devices = 1 + rng.usize_below(5);
            let replicate = rng.usize_below(4);
            let capacity = 1 + rng.usize_below(2 * e);
            let counts: Vec<(usize, usize, u64)> = (0..rng.usize_below(24))
                .map(|_| {
                    (
                        moe_blocks[rng.usize_below(moe_blocks.len())],
                        rng.usize_below(e),
                        rng.below(1000),
                    )
                })
                .collect();
            (devices, replicate, capacity, counts)
        },
        |_| Vec::new(),
        |(devices, replicate, capacity, counts)| {
            let mut profile = ActivationProfile::default();
            // feed the counts through the public observation API by
            // fabricating single-token tables
            for &(block, expert, n) in counts {
                let layer = moe_blocks.iter().position(|&bl| bl == block).unwrap();
                for _ in 0..(n % 7) + 1 {
                    let mut idx = vec![0i32; moe_blocks.len()];
                    idx[layer] = expert as i32;
                    let table = sida_moe::coordinator::HashTable::new(
                        0,
                        1,
                        moe_blocks.len(),
                        1,
                        idx,
                        vec![1.0; moe_blocks.len()],
                        0.0,
                    )
                    .map_err(|err| err.to_string())?;
                    profile.observe_table(&table, &moe_blocks, 1, &[1.0]);
                }
            }
            let placement =
                PlacementPlanner::new(*devices, *replicate, *capacity).plan(&topo, &profile);
            placement.check_invariants(&topo).map_err(|err| format!("{err:#}"))?;
            // exactly one home per expert, and replica capacity holds
            // whenever homes alone fit the capacity
            let home_cap = e.div_ceil(*devices);
            for dev in 0..*devices {
                let assigned = placement.assigned_to(dev);
                let max_homes = home_cap * moe_blocks.len();
                if max_homes <= *capacity {
                    if assigned > *capacity {
                        return Err(format!(
                            "device {dev}: {assigned} entries exceed capacity {capacity}"
                        ));
                    }
                }
            }
            let mut total_holders = 0usize;
            for &block in &moe_blocks {
                for expert in 0..e {
                    let key = ExpertKey::new(block, expert);
                    let holders = placement.holders(&key);
                    if holders.is_empty() {
                        return Err(format!("{key:?} has no holders"));
                    }
                    total_holders += holders.len();
                }
            }
            if total_holders
                != moe_blocks.len() * e + placement.replicated_entries()
            {
                return Err("holder count != homes + replicas".into());
            }
            Ok(())
        },
    );
}
