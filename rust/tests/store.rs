//! On-disk expert store: restart-warm serving and fault injection
//! (hermetic, synthetic bundle + TempDir stores).
//!
//! The contract under test (ISSUE 7):
//! * a pipeline reopening an existing `--store-dir` serves warm — the
//!   manifest pre-seeds the ledger's SSD tier, promotions do real
//!   verified file reads (`store_hits > 0`), nothing is refabricated,
//!   and the outputs are bit-for-bit what a cold (and a store-less) run
//!   produces;
//! * corrupting a blob (flipped byte, truncation) is DETECTED at
//!   promotion time — the read fails its content-hash check, serving
//!   falls back to re-fabrication from the bundle, outputs stay
//!   bit-identical, and the incident is counted in
//!   `integrity_failures`;
//! * deleting a manifest-listed blob is a clean miss (refabrication,
//!   no panic, no integrity failure — nothing lied about its content).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use sida_moe::coordinator::{Pipeline, PipelineConfig, ServeOutcome};
use sida_moe::memory::HierarchyStats;
use sida_moe::runtime::ModelBundle;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::workload::Request;

fn deep_bundle() -> Arc<ModelBundle> {
    testkit::bundle(&SynthSpec::default().two_moe_layers()).unwrap()
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sida_tstore_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// One serving run at a store-stressing configuration: tight device
/// tier, no RAM window (every eviction falls to SSD), deterministic
/// fetch order (no prefetch, one lane).  `store_dir = None` runs
/// store-less (modeled SSD only) — the bit-identity reference.
fn run(
    bundle: &Arc<ModelBundle>,
    requests: &[Request],
    store_dir: Option<&Path>,
) -> (ServeOutcome, HierarchyStats) {
    let sim = sida_moe::bench_support::sim_expert_bytes(bundle).unwrap();
    let cfg = PipelineConfig {
        k_used: 2,
        budget_sim_bytes: 4 * sim + 1024,
        ram_budget_bytes: 0,
        prefetch: false,
        pool_threads: 1,
        want_cls: true,
        want_lm: true,
        store_dir: store_dir.map(|p| p.display().to_string()).unwrap_or_default(),
        ..Default::default()
    };
    let p = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg).unwrap();
    let out = p.serve(requests).unwrap();
    p.cache.check_invariants().unwrap();
    let h = out.stats.hierarchy.clone();
    (out, h)
}

/// Exact per-request outputs, order-normalized: bit-identity means the
/// classification argmax AND the full-precision LM NLL agree.
fn outputs(out: &ServeOutcome) -> Vec<(u64, Option<usize>, Option<f64>)> {
    let mut v: Vec<_> =
        out.per_request.iter().map(|r| (r.id, r.cls_pred, r.lm_nll)).collect();
    v.sort_by_key(|(id, ..)| *id);
    assert!(!v.is_empty());
    v
}

fn blob_files(dir: &Path) -> Vec<PathBuf> {
    let mut v: Vec<_> = std::fs::read_dir(dir.join("blobs"))
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map(|e| e == "blob").unwrap_or(false))
        .collect();
    v.sort();
    assert!(!v.is_empty(), "cold run must leave blobs on disk");
    v
}

#[test]
fn reopened_store_serves_warm_and_bit_identical() {
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 11);
    let dir = tmp("warm");

    let (ref_out, ref_h) = run(&b, &reqs, None); // store-less reference
    let (cold_out, cold_h) = run(&b, &reqs, Some(&dir));
    assert!(cold_h.store_writes > 0, "cold run must write blobs");
    assert_eq!(cold_h.integrity_failures, 0);
    // attaching a store must not change what the model computes
    assert_eq!(outputs(&cold_out), outputs(&ref_out));
    // and the modeled timeline is untouched by the measured one
    assert_eq!(ref_h.ladder_secs(), cold_h.ladder_secs());

    // restart: drop every in-memory structure, reopen the directory
    let (warm_out, warm_h) = run(&b, &reqs, Some(&dir));
    assert!(
        warm_h.promotions_from_ssd > 0,
        "reopened store must pre-seed the SSD tier"
    );
    assert!(warm_h.store_hits > 0, "warm promotions must read from disk");
    assert_eq!(
        warm_h.refabrications, 0,
        "a warm store refabricates nothing"
    );
    assert_eq!(warm_h.integrity_failures, 0);
    assert!(warm_h.measured_ssd_read_secs > 0.0, "real reads take real time");
    assert!(warm_h.store_bytes_on_disk > 0);
    assert_eq!(outputs(&warm_out), outputs(&ref_out));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_byte_is_detected_and_refabricated_bit_identically() {
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 13);
    let dir = tmp("flip");

    let (cold_out, _) = run(&b, &reqs, Some(&dir));

    // corrupt every blob: flip one payload byte in each, so whichever
    // experts the warm run promotes first, it meets a liar
    for blob in blob_files(&dir) {
        let mut bytes = std::fs::read(&blob).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&blob, &bytes).unwrap();
    }

    let (warm_out, warm_h) = run(&b, &reqs, Some(&dir));
    assert!(
        warm_h.integrity_failures > 0,
        "flipped bytes must fail the content-hash check"
    );
    assert!(
        warm_h.refabrications > 0,
        "corrupt blobs must fall back to bundle re-fabrication"
    );
    // the fallback is invisible in the outputs
    assert_eq!(outputs(&warm_out), outputs(&cold_out));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_blob_is_detected_and_refabricated_bit_identically() {
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 17);
    let dir = tmp("trunc");

    let (cold_out, _) = run(&b, &reqs, Some(&dir));
    for blob in blob_files(&dir) {
        let bytes = std::fs::read(&blob).unwrap();
        std::fs::write(&blob, &bytes[..bytes.len() / 2]).unwrap();
    }

    let (warm_out, warm_h) = run(&b, &reqs, Some(&dir));
    assert!(
        warm_h.integrity_failures > 0,
        "truncation must fail the length/hash check"
    );
    assert!(warm_h.refabrications > 0);
    assert_eq!(outputs(&warm_out), outputs(&cold_out));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_between_blob_publish_and_manifest_is_swept_on_reopen() {
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 23);
    let dir = tmp("crash");

    let (cold_out, cold_h) = run(&b, &reqs, Some(&dir));
    assert!(cold_h.store_bytes_on_disk > 0);

    // simulate the crash window in `put`: the blob rename published,
    // but the process died before MANIFEST.json was rewritten — an
    // on-disk blob no manifest entry references
    let orphan = dir.join("blobs").join(format!("{:016x}.blob", 0xdead_beef_u64));
    std::fs::write(&orphan, vec![0xABu8; 4096]).unwrap();
    // plus a blob temp torn mid-write (crash before its rename)
    let torn_blob = dir.join("blobs").join(".tmp-cafebabe-12345");
    std::fs::write(&torn_blob, b"partial payload").unwrap();
    // plus a torn manifest temp (crash inside persist_manifest before
    // the rename) — MANIFEST.json itself stays intact
    let torn_manifest = dir.join(".MANIFEST.tmp-99999");
    std::fs::write(&torn_manifest, b"{\"version\":1,\"entries\":[trunca").unwrap();

    let (warm_out, warm_h) = run(&b, &reqs, Some(&dir));
    assert!(!orphan.exists(), "reopen must sweep the orphan blob");
    assert!(!torn_blob.exists(), "reopen must sweep the torn blob temp");
    assert!(!torn_manifest.exists(), "reopen must sweep the torn manifest temp");
    // exact byte accounting: what the ledger claims is on disk is
    // exactly what enumeration finds — the crash leftovers neither
    // count nor linger
    let on_disk: u64 = std::fs::read_dir(dir.join("blobs"))
        .unwrap()
        .map(|e| e.unwrap().metadata().unwrap().len())
        .sum();
    assert_eq!(warm_h.store_bytes_on_disk as u64, on_disk);
    assert_eq!(warm_h.store_bytes_on_disk, cold_h.store_bytes_on_disk);
    assert_eq!(warm_h.integrity_failures, 0, "leftovers are garbage, not corruption");
    assert_eq!(outputs(&warm_out), outputs(&cold_out));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deleted_blob_is_a_clean_miss_not_a_panic() {
    let b = deep_bundle();
    let reqs = testkit::tiny_trace(&b, 12, 19);
    let dir = tmp("gone");

    let (cold_out, _) = run(&b, &reqs, Some(&dir));
    // delete the blobs out from under the manifest
    for blob in blob_files(&dir) {
        std::fs::remove_file(&blob).unwrap();
    }

    let (warm_out, warm_h) = run(&b, &reqs, Some(&dir));
    assert!(warm_h.store_misses > 0, "vanished blobs are misses");
    assert!(warm_h.refabrications > 0);
    assert_eq!(
        warm_h.integrity_failures, 0,
        "a missing file is a miss, not a corruption"
    );
    assert_eq!(outputs(&warm_out), outputs(&cold_out));

    let _ = std::fs::remove_dir_all(&dir);
}
