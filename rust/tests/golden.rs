//! Artifact-backed golden tests (opt-in layer): load the real switch8
//! bundle and check the Rust serving stack against the Python goldens
//! emitted at build time (`artifacts/switch8/golden.json`).
//!
//! These tests are skipped (with a visible message) when either
//! prerequisite is missing:
//!   * the artifacts — build them with `make artifacts`
//!   * the PJRT execution backend — build with `--features pjrt` after
//!     vendoring the `xla` crate (see DESIGN.md)
//!
//! The always-on hermetic twin of this suite lives in
//! `tests/integration.rs` / `tests/pipeline.rs` over the synthetic
//! testkit bundle.

use std::path::PathBuf;
use std::sync::Arc;

use sida_moe::coordinator::HashBuilder;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::runtime::ModelBundle;
use sida_moe::util::json::Json;

fn artifacts_root() -> Option<PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!(
            "SKIP: golden tests need the PJRT backend — vendor the xla crate, \
             add it to rust/Cargo.toml, then `cargo test --features pjrt` \
             (DESIGN.md §5)"
        );
        return None;
    }
    let root = sida_moe::default_artifacts_root();
    if root.join("switch8").join("model.json").is_file() {
        Some(root)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn bundle() -> Option<Arc<ModelBundle>> {
    let root = artifacts_root()?;
    Some(Arc::new(ModelBundle::load_named(&root, "switch8").expect("load bundle")))
}

fn golden(bundle: &ModelBundle) -> Json {
    let text =
        std::fs::read_to_string(bundle.engine.artifacts_dir().join("golden.json")).unwrap();
    Json::parse(&text).unwrap()
}

fn ids_of(sentence: &Json) -> Vec<Vec<i32>> {
    sentence
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            row.as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_i64().unwrap() as i32)
                .collect()
        })
        .collect()
}

#[test]
fn manifest_weights_and_topology_consistent() {
    let Some(b) = bundle() else { return };
    let topo = &b.topology;
    // every expert of every MoE layer is individually addressable
    for &blk in &topo.moe_blocks {
        for e in 0..topo.num_experts {
            let bytes = b.weights.expert_bytes(blk, e).unwrap();
            assert_eq!(bytes, topo.expert_param_bytes, "expert ({blk},{e})");
        }
    }
    let moe_from_manifest: usize = topo
        .moe_blocks
        .iter()
        .map(|&blk| b.weights.bytes_with_prefix(&format!("blocks.{blk}.expert.")))
        .sum();
    assert_eq!(moe_from_manifest, topo.moe_param_bytes);
}

#[test]
fn router_decisions_match_python_golden() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let prof = g.get("profiles").unwrap().get("sst2").unwrap();
    let ids = ids_of(prof.get("ids").unwrap());
    let want_idx = prof.get("router_idx").unwrap(); // [B][M][L]
    let staged = runner.stage_all_experts().unwrap();
    for (s, sent_ids) in ids.iter().enumerate() {
        let mut provider = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(sent_ids, None, &mut provider, ForwardOptions::default())
            .unwrap();
        let mask = ModelRunner::mask_of(sent_ids);
        for (m, routing) in out.routing.iter().enumerate() {
            let want: Vec<usize> = want_idx.as_arr().unwrap()[s].as_arr().unwrap()[m]
                .usize_vec()
                .unwrap();
            for (t, (&got, &want)) in routing.top1.iter().zip(want.iter()).enumerate() {
                if mask[t] > 0.0 {
                    assert_eq!(got, want, "sentence {s} layer {m} token {t}");
                }
            }
        }
    }
}

#[test]
fn hash_tables_match_python_golden() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    for profile in ["sst2", "mrpc", "multirc"] {
        let builder = HashBuilder::new(&b, profile).unwrap();
        let prof = g.get("profiles").unwrap().get(profile).unwrap();
        let ids = ids_of(prof.get("ids").unwrap());
        let want = prof.get("hash_top_idx").unwrap(); // [B][L][M][K]
        for (s, sent_ids) in ids.iter().enumerate() {
            let table = builder.build(s as u64, sent_ids).unwrap();
            let ws = &want.as_arr().unwrap()[s];
            for t in 0..table.seq_len {
                for m in 0..table.m {
                    for r in 0..table.k {
                        let w = ws.as_arr().unwrap()[t].as_arr().unwrap()[m]
                            .as_arr()
                            .unwrap()[r]
                            .as_usize()
                            .unwrap();
                        assert_eq!(
                            table.expert_at(t, m, r),
                            w,
                            "{profile} s{s} t{t} m{m} r{r}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn lm_logits_match_python_golden_slice() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let prof = g.get("profiles").unwrap().get("sst2").unwrap();
    let ids = ids_of(prof.get("ids").unwrap());
    let want_slice = prof.get("lm_logits_slice").unwrap(); // [B][4][8]
    let staged = runner.stage_all_experts().unwrap();
    let v = b.topology.vocab;
    for (s, sent_ids) in ids.iter().enumerate() {
        let mut provider = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(
                sent_ids,
                None,
                &mut provider,
                ForwardOptions { want_lm: true, want_cls: true, ..Default::default() },
            )
            .unwrap();
        let lm = out.lm_logits.unwrap();
        for t in 0..4 {
            for c in 0..8 {
                let want = want_slice.as_arr().unwrap()[s].as_arr().unwrap()[t]
                    .as_arr()
                    .unwrap()[c]
                    .as_f64()
                    .unwrap() as f32;
                let got = lm[t * v + c];
                assert!(
                    (got - want).abs() < 2e-2 + 0.01 * want.abs(),
                    "sentence {s} tok {t} vocab {c}: {got} vs {want}"
                );
            }
        }
        // classifier agreement
        let want_cls: Vec<f64> = prof.get("cls_logits").unwrap().as_arr().unwrap()[s]
            .f64_vec()
            .unwrap();
        let got_cls = out.cls_logits.unwrap();
        let got_arg = sida_moe::coordinator::argmax(&got_cls);
        let want_arg = want_cls
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(got_arg, want_arg, "sentence {s} classifier argmax");
    }
}

#[test]
fn lm_nll_matches_golden_mean() {
    let Some(b) = bundle() else { return };
    let g = golden(&b);
    let runner = ModelRunner::new(b.clone(), "sst2").unwrap();
    let prof = g.get("profiles").unwrap().get("sst2").unwrap();
    let ids = ids_of(prof.get("ids").unwrap());
    let want_mean = prof.get_f64("lm_mean_nll").unwrap();
    let staged = runner.stage_all_experts().unwrap();
    let mut total_nll = 0.0;
    let mut total_tok = 0.0;
    for sent_ids in &ids {
        let mut p = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(
                sent_ids,
                None,
                &mut p,
                ForwardOptions { want_lm: true, ..Default::default() },
            )
            .unwrap();
        let (nll, cnt) = runner.lm_nll(&out.lm_logits.unwrap(), sent_ids).unwrap();
        total_nll += nll;
        total_tok += cnt;
    }
    let got_mean = total_nll / total_tok;
    assert!(
        (got_mean - want_mean).abs() < 0.02 * want_mean.abs() + 0.02,
        "mean NLL {got_mean} vs golden {want_mean}"
    );
}
