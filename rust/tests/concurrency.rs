//! Concurrency tests: the shared expert cache under multi-threaded
//! hammering, pipeline stability across repeated runs and queue depths,
//! and parallel request serving through the shared server state — all on
//! the synthetic testkit bundle.

use std::sync::{Arc, Mutex};

use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::experts::{make_policy, ExpertCache, ExpertKey};
use sida_moe::memory::CostModel;
use sida_moe::runtime::stage_expert_parts;
use sida_moe::server::{ServerConfig, ServerState};
use sida_moe::testkit::{self, TINY_PROFILE};

#[test]
fn shared_cache_survives_concurrent_ensure_and_eviction() {
    let b = testkit::tiny_bundle();
    let block = b.topology.moe_blocks[0];
    let e = b.topology.num_experts;
    let real = b.weights.expert_bytes(block, 0).unwrap();
    // room for 3 experts: constant eviction pressure from 4 threads
    let cache = Arc::new(Mutex::new(ExpertCache::new(
        3 * real + 64,
        CostModel::physical(real),
        make_policy("fifo").unwrap(),
    )));

    let mut handles = Vec::new();
    for thread_id in 0..4u64 {
        let cache = cache.clone();
        let b = b.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = sida_moe::util::rng::Rng::new(thread_id);
            for _ in 0..200 {
                let expert = rng.usize_below(e);
                let key = ExpertKey::new(block, expert);
                let engine = b.engine.clone();
                let weights = b.weights.clone();
                let mut guard = cache.lock().unwrap();
                let (_resident, _hit, _secs) = guard
                    .ensure(key, real, thread_id % 2 == 0, || {
                        stage_expert_parts(&engine, &weights, block, expert)
                    })
                    .expect("ensure under pressure");
                guard.check_invariants().expect("invariants mid-flight");
                assert!(guard.used() <= guard.budget());
            }
        }));
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    let guard = cache.lock().unwrap();
    guard.check_invariants().unwrap();
    // whatever survived the storm is a real subset of the expert pool
    let keys = guard.resident_keys();
    assert_eq!(keys.len(), guard.resident_count());
    assert!(keys.iter().all(|k| k.block == block && k.expert < e));
    let stats = guard.stats();
    assert_eq!(stats.hits + stats.misses, 4 * 200);
    assert!(stats.evictions > 0, "eviction pressure never materialized");
}

#[test]
fn pinned_experts_survive_concurrent_eviction_pressure() {
    let b = testkit::tiny_bundle();
    let block = b.topology.moe_blocks[0];
    let e = b.topology.num_experts;
    let real = b.weights.expert_bytes(block, 0).unwrap();
    let cache = Arc::new(Mutex::new(ExpertCache::new(
        3 * real + 64,
        CostModel::physical(real),
        make_policy("lru").unwrap(),
    )));

    // resident + pinned expert 0
    {
        let engine = b.engine.clone();
        let weights = b.weights.clone();
        let mut guard = cache.lock().unwrap();
        guard
            .ensure(ExpertKey::new(block, 0), real, false, || {
                stage_expert_parts(&engine, &weights, block, 0)
            })
            .unwrap();
        guard.pin(ExpertKey::new(block, 0));
    }

    let mut handles = Vec::new();
    for thread_id in 1..4u64 {
        let cache = cache.clone();
        let b = b.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = sida_moe::util::rng::Rng::new(thread_id * 97);
            for _ in 0..100 {
                let expert = 1 + rng.usize_below(e - 1);
                let key = ExpertKey::new(block, expert);
                let engine = b.engine.clone();
                let weights = b.weights.clone();
                let mut guard = cache.lock().unwrap();
                guard
                    .ensure(key, real, false, || {
                        stage_expert_parts(&engine, &weights, block, expert)
                    })
                    .expect("ensure");
                assert!(
                    guard.contains(&ExpertKey::new(block, 0)),
                    "pinned expert was evicted"
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("worker panicked");
    }
    let guard = cache.lock().unwrap();
    assert!(guard.contains(&ExpertKey::new(block, 0)));
    guard.unpin(&ExpertKey::new(block, 0));
    guard.check_invariants().unwrap();
}

#[test]
fn pipeline_is_stable_across_seeds_and_queue_depths() {
    // regression harness for pipeline deadlocks/races: several
    // (seed, queue_depth, prefetch) combinations must all drain fully
    let b = testkit::tiny_bundle();
    for (seed, depth, prefetch) in
        [(0u64, 1usize, true), (1, 1, false), (2, 2, true), (3, 8, false), (4, 4, true)]
    {
        let reqs = testkit::tiny_trace(&b, 6, seed);
        let cfg = PipelineConfig { queue_depth: depth, prefetch, ..Default::default() };
        let p = Pipeline::new(b.clone(), TINY_PROFILE, cfg).unwrap();
        let out = p.serve(&reqs).unwrap();
        assert_eq!(
            out.stats.requests, 6,
            "seed {seed} depth {depth} prefetch {prefetch} lost requests"
        );
    }
}

#[test]
fn pipeline_reuse_serves_back_to_back_traces() {
    // one Pipeline (warm cache) serving several traces — the
    // bench_support warmup pattern — must keep stats coherent
    let b = testkit::tiny_bundle();
    let p = Pipeline::new(b.clone(), TINY_PROFILE, PipelineConfig::default()).unwrap();
    let warm = testkit::tiny_trace(&b, 4, 100);
    let _ = p.serve(&warm).unwrap();
    p.cache.reset_stats();
    let reqs = testkit::tiny_trace(&b, 8, 101);
    let out = p.serve(&reqs).unwrap();
    assert_eq!(out.stats.requests, 8);
    // warm cache: most lookups are hits now
    assert!(out.stats.cache_hits > 0);
    p.cache.check_invariants().unwrap();
}

#[test]
fn server_state_serves_concurrent_clients_deterministically() {
    let b = testkit::tiny_bundle();
    let state = Arc::new(ServerState::new(b, TINY_PROFILE, ServerConfig::default()).unwrap());
    // reference answer, single-threaded
    let (want_label, _) = state.serve_one(&[1, 40, 41, 42, 2]).unwrap();

    let mut handles = Vec::new();
    for _ in 0..4 {
        let state = state.clone();
        handles.push(std::thread::spawn(move || {
            let mut labels = Vec::new();
            for _ in 0..5 {
                let (label, secs) = state.serve_one(&[1, 40, 41, 42, 2]).unwrap();
                assert!(secs > 0.0);
                labels.push(label);
            }
            labels
        }));
    }
    for h in handles {
        for label in h.join().expect("client thread panicked") {
            assert_eq!(label, want_label, "same input must predict identically");
        }
    }
    use std::sync::atomic::Ordering;
    assert_eq!(state.served.load(Ordering::SeqCst), 21);
}
