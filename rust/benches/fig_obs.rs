//! fig_obs — observability overhead and trace well-formedness (ISSUE 9).
//!
//! Serves the same trace twice through a 4-device faulted cluster
//! pipeline — tracing OFF, then tracing ON — and gates CI on the
//! observability contract:
//!
//! * **bit-identity** — predictions, LM NLLs and the modeled ladder
//!   attribution are bitwise equal with the tracer enabled (tracing
//!   never touches the f32 compute path or the modeled cost ledger);
//! * **modeled overhead < 2%** — the modeled serving-time totals of the
//!   traced run stay within 2% of the untraced run (they are exactly
//!   equal today; the gate is the regression trip-wire);
//! * **valid Chrome trace** — the exported document round-trips through
//!   the JSON parser with a non-empty `traceEvents` array;
//! * **flows resolve** — every flow step/end (`ph:"t"/"f"`) carries an
//!   id with a matching flow start (`ph:"s"`), so Perfetto renders no
//!   dangling arrows.
//!
//! Hermetic (synthetic testkit bundle) — CI's bench-smoke job RUNS this
//! instead of SKIP-ing.  Emits `BENCH_obs.json`.

use std::collections::BTreeSet;
use std::time::Instant;

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{Pipeline, PipelineConfig, ServeOutcome};
use sida_moe::metrics::Table;
use sida_moe::obs::trace;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};

fn outputs(out: &ServeOutcome) -> Vec<(u64, Option<usize>, Option<f64>)> {
    let mut v: Vec<_> = out.per_request.iter().map(|r| (r.id, r.cls_pred, r.lm_nll)).collect();
    v.sort_by_key(|(id, ..)| *id);
    v
}

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_obs: span tracing overhead + Chrome trace well-formedness",
        "tracing must observe serving, never change it",
    );
    let bundle = testkit::bundle(&SynthSpec::default().two_moe_layers())?;
    let n = bs::n_requests(16);
    let requests = testkit::tiny_trace(&bundle, n, 7);
    let run = || -> anyhow::Result<(ServeOutcome, f64)> {
        let cfg = PipelineConfig {
            k_used: 2,
            devices: 4,
            replicate_top: 1,
            min_replicas: 2,
            fault_plan: "down:1@3..8".into(),
            want_lm: true,
            want_cls: true,
            ..Default::default()
        };
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
        let t0 = Instant::now();
        let out = pipeline.serve(&requests)?;
        Ok((out, t0.elapsed().as_secs_f64()))
    };

    trace::disable();
    let (plain, wall_off) = run()?;
    trace::enable(trace::DEFAULT_CAPACITY);
    let (traced, wall_on) = run()?;
    trace::disable();
    let events = trace::snapshot_events();

    // gate 1: bit-identical outputs and ladder attribution
    let identical = outputs(&plain) == outputs(&traced)
        && plain.stats.hierarchy.ladder_secs().to_bits()
            == traced.stats.hierarchy.ladder_secs().to_bits();

    // gate 2: modeled serving time within 2% (+ tiny absolute slack)
    let modeled_off = plain.stats.modeled_transfer_secs;
    let modeled_on = traced.stats.modeled_transfer_secs;
    let overhead = (modeled_on - modeled_off).abs() / modeled_off.max(1e-12);
    let overhead_ok = (modeled_on - modeled_off).abs() <= 0.02 * modeled_off + 1e-9;

    // gate 3: the export round-trips as a Chrome trace-event document
    let doc = Json::parse(&trace::export_json().to_string());
    let trace_events = doc
        .as_ref()
        .ok()
        .and_then(|d| d.get("traceEvents").ok())
        .and_then(|a| a.as_arr().ok().map(|a| a.len()))
        .unwrap_or(0);
    let valid_json = trace_events > 0;

    // gate 4: every flow step/end id resolves to a flow start
    let starts: BTreeSet<u64> =
        events.iter().filter(|e| e.ph == 's').map(|e| e.id).collect();
    let dangling = events
        .iter()
        .filter(|e| (e.ph == 't' || e.ph == 'f') && !starts.contains(&e.id))
        .count();
    let flows_ok = !starts.is_empty() && dangling == 0;

    let span_count = events.iter().filter(|e| e.ph == 'X').count();
    let mut t = Table::new(
        "fig_obs — tracing off vs on, same faulted 4-device trace",
        &["tracer", "wall s", "modeled transfer s", "events", "spans", "flow starts"],
    );
    t.row(vec![
        "off".into(),
        format!("{wall_off:.3}"),
        format!("{modeled_off:.6}"),
        "0".into(),
        "0".into(),
        "0".into(),
    ]);
    t.row(vec![
        "on".into(),
        format!("{wall_on:.3}"),
        format!("{modeled_on:.6}"),
        events.len().to_string(),
        span_count.to_string(),
        starts.len().to_string(),
    ]);
    t.print();
    t.save_csv(&bs::csv_path("fig_obs"))?;

    println!(
        "obs check: outputs bit-identical with tracing on: {}; modeled overhead \
         {:.4}% (< 2%): {}; trace valid Chrome JSON ({} events): {}; {} dangling \
         flow ids: {}",
        if identical { "PASS" } else { "FAIL" },
        overhead * 100.0,
        if overhead_ok { "PASS" } else { "FAIL" },
        trace_events,
        if valid_json { "PASS" } else { "FAIL" },
        dangling,
        if flows_ok { "PASS" } else { "FAIL" }
    );

    let mut j = bs::BenchJson::new("obs");
    j.push(obj(vec![
        ("requests", num(traced.stats.requests as f64)),
        ("wall_secs_traced_off", num(wall_off)),
        ("wall_secs_traced_on", num(wall_on)),
        ("modeled_transfer_secs_off", num(modeled_off)),
        ("modeled_transfer_secs_on", num(modeled_on)),
        ("modeled_overhead_frac", num(overhead)),
        ("trace_events", num(events.len() as f64)),
        ("trace_spans", num(span_count as f64)),
        ("trace_flow_starts", num(starts.len() as f64)),
        ("trace_dropped", num(trace::dropped() as f64)),
        ("outputs_bit_identical", Json::Bool(identical)),
        ("modeled_overhead_under_2pct", Json::Bool(overhead_ok)),
        ("trace_valid_chrome_json", Json::Bool(valid_json)),
        ("flow_ids_resolve", Json::Bool(flows_ok)),
        ("dataset", s(TINY_PROFILE)),
    ]));
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    if !(identical && overhead_ok && valid_json && flows_ok) {
        std::process::exit(1);
    }
    Ok(())
}
