//! Figure 3 — MoE overhead breakdown on SST2.
//!
//! Paper: expert selection + invocation + communication consume up to
//! 72% of total inference time on Switch-base-256, growing with expert
//! count, because the default implementation invokes *every* expert
//! (Remark 1: at B=1, invocation count dictates inference time).
//! We serve with the Standard method and report the phase breakdown.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 3: MoE overhead breakdown (Standard, SST2)",
        "MoE overhead up to 72% of inference time at E=256, growing with E",
    );
    let n = bs::n_requests(8);
    let mut t = Table::new(
        "Fig 3 — time breakdown per forward (Standard)",
        &[
            "model", "ideal (dense) %", "selection %", "expert invocation %",
            "MoE overhead %", "invocations/req",
        ],
    );
    for name in bs::ALL_MODELS {
        let b = bs::load(name)?;
        let spec = bs::RunSpec::new("sst2", n);
        let out = bs::run_method(b, Method::Standard, &spec)?;
        let ph = &out.stats.phases;
        let total = ph.total();
        t.row(vec![
            name.to_string(),
            format!("{:.1}", 100.0 * ph.dense_secs / total),
            format!("{:.1}", 100.0 * ph.selection_secs / total),
            format!("{:.1}", 100.0 * ph.expert_secs / total),
            format!("{:.1}", 100.0 * ph.moe_overhead() / total),
            format!("{:.0}", ph.expert_invocations as f64 / out.stats.requests as f64),
        ]);
    }
    t.print();
    t.save_csv(&bs::csv_path("fig3_moe_overhead"))?;
    println!("paper shape check: overhead % must grow monotonically with E");
    Ok(())
}
