//! Table 4 — downstream performance preservation (fidelity).
//!
//! Paper: SiDA keeps 97.5-99% of fine-tuned quality on Switch-base-8 and
//! 92.6-93% on Switch-base-128 across SST2/MRPC/MultiRC.  Our stand-in
//! classification task is topic id (DESIGN.md §2); we report accuracy of
//! router-routed vs hash-routed serving and fidelity = hash/router.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;

fn accuracy(outcome: &sida_moe::coordinator::ServeOutcome, labels: &[usize]) -> f64 {
    let mut sorted = outcome.per_request.clone();
    sorted.sort_by_key(|r| r.id);
    let correct = sorted
        .iter()
        .zip(labels.iter())
        .filter(|(r, &l)| r.cls_pred == Some(l))
        .count();
    correct as f64 / labels.len().max(1) as f64
}

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Tab 4: downstream fidelity (classification)",
        "fidelity 97.5-99% (E=8), 92.6-93% (E=128)",
    );
    let n = bs::n_requests(16);
    let mut t = Table::new(
        "Tab 4 — classification accuracy, router vs hash routing",
        &["model", "dataset", "router acc", "sida acc", "fidelity %"],
    );
    for name in bs::ACCURACY_MODELS {
        let b = bs::load(name)?;
        for dataset in bs::ALL_DATASETS {
            let reqs = bs::trace_for(&b, dataset, n, 0);
            let labels: Vec<usize> = reqs.iter().map(|r| r.label).collect();
            let spec = bs::RunSpec::new(dataset, n).cls(true).sleep(false);
            let router_out = bs::run_method(b.clone(), Method::TutelLike, &spec)?;
            let sida_out = bs::run_method(b.clone(), Method::Sida, &spec)?;
            let ra = accuracy(&router_out, &labels);
            let sa = accuracy(&sida_out, &labels);
            t.row(vec![
                name.to_string(),
                dataset.to_string(),
                format!("{:.3}", ra),
                format!("{:.3}", sa),
                format!("{:.1}", 100.0 * sa / ra.max(1e-9)),
            ]);
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("tab4_fidelity"))?;
    println!("note: the synthetic topic task saturates (acc ~1.0), so fidelity");
    println!("is expected near 100% — the informative quality metric is Tab 3 ppl");
    Ok(())
}
