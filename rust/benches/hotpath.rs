//! Hot-path microbenchmark — the profiling harness behind
//! EXPERIMENTS.md §Perf (L3).
//!
//! Reports per-artifact dispatch statistics over a SiDA serving run
//! (calls, total time, mean), the isolated costs of the per-request
//! stages (hash build, expert invocation, end-to-end forward), a
//! per-stage breakdown of the expert path (gather / expert compute /
//! scatter / transfer exposed-vs-overlapped), and a sequential-vs-
//! pooled comparison under a tight budget (with a `--prefetch-depth`
//! 1-vs-3 arm isolating the cross-layer bandwidth scheduler).  Emits
//! `BENCH_hotpath.json` (see `bench_support::BenchJson`) so the
//! numbers form a diffable perf trajectory across PRs.

use std::sync::Arc;
use std::time::Instant;

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::coordinator::HashBuilder;
use sida_moe::metrics::{ServeStats, Table};
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::runtime::stage_expert_parts;
use sida_moe::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "hotpath: per-stage microbenchmarks",
        "(internal perf harness, not a paper figure)",
    );
    let model = std::env::var("HOTPATH_MODEL").unwrap_or_else(|_| "switch128".to_string());
    let b = bs::load(&model)?;
    let runner = Arc::new(ModelRunner::new(b.clone(), "sst2")?);
    let builder = HashBuilder::new(&b, "sst2")?;
    let reqs = bs::trace_for(&b, "sst2", bs::n_requests(8), 3);

    // --- stage costs ----------------------------------------------------
    let mut t = Table::new("stage micro-costs", &["stage", "mean", "calls"]);
    // hash build (warm: first dispatch pays one-time PJRT setup)
    builder.build(0, &reqs[0].ids)?;
    let t0 = Instant::now();
    for req in &reqs {
        builder.build(req.id, &req.ids)?;
    }
    t.row(vec![
        "hash build (warm)".into(),
        format!("{:.3}ms", t0.elapsed().as_secs_f64() * 1e3 / reqs.len() as f64),
        reqs.len().to_string(),
    ]);
    // expert staging (H2D)
    let t0 = Instant::now();
    let iters = 32;
    for i in 0..iters {
        let _ = stage_expert_parts(
            &b.engine,
            &b.weights,
            b.topology.moe_blocks[0],
            i % b.topology.num_experts,
        )?;
    }
    t.row(vec![
        "expert stage (4 bufs)".into(),
        format!("{:.3}ms", t0.elapsed().as_secs_f64() * 1e3 / iters as f64),
        iters.to_string(),
    ]);
    // single expert invocation per bucket
    let staged = runner.stage_all_experts()?;
    for &bucket in &b.topology.buckets.clone() {
        if bucket > runner.seq_len * 2 {
            continue;
        }
        let (ids, _, _) = {
            let mut gen = sida_moe::workload::TraceGenerator::new(
                sida_moe::workload::Profile::named("sst2").unwrap(),
                b.topology.vocab,
                1,
            );
            gen.sentence()
        };
        let mut provider = ExpertProvider::AllResident(&staged);
        // warm
        let _ = runner.forward(&ids, None, &mut provider, ForwardOptions::default())?;
        let t0 = Instant::now();
        let iters = 8;
        for _ in 0..iters {
            let mut provider = ExpertProvider::AllResident(&staged);
            let _ = runner.forward(&ids, None, &mut provider, ForwardOptions::default())?;
        }
        t.row(vec![
            format!("full fwd (adaptive, warm, bucket<= {bucket})"),
            format!("{:.3}ms", t0.elapsed().as_secs_f64() * 1e3 / iters as f64),
            iters.to_string(),
        ]);
        break; // one representative row; buckets covered below via stats
    }
    t.print();

    // --- per-artifact dispatch stats over a serving run ------------------
    let spec = bs::RunSpec::new("sst2", bs::n_requests(8)).sleep(false);
    let _ = bs::run_method(b.clone(), Method::Sida, &spec)?;
    let mut stats = b.engine.all_stats();
    stats.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
    let mut t2 = Table::new(
        "per-artifact dispatch stats (SiDA serving run)",
        &["artifact", "calls", "total (ms)", "mean (us)"],
    );
    for (name, s) in stats.iter().take(12) {
        if s.calls == 0 {
            continue;
        }
        t2.row(vec![
            name.clone(),
            s.calls.to_string(),
            format!("{:.2}", s.total_secs * 1e3),
            format!("{:.1}", s.total_secs * 1e6 / s.calls as f64),
        ]);
    }
    t2.print();
    t2.save_csv(&bs::csv_path("hotpath"))?;

    // --- sequential vs pooled + layer-ahead overlap ----------------------
    // Same trace, tight device budget (so the serial path pays real
    // exposed transfer every request), virtual transfer cost:
    //   serial = pool 1, no prefetch (blocking on-demand fetches)
    //   pooled = auto pool, request-ahead + layer-ahead prefetch
    let n = bs::n_requests(8);
    let sim = bs::sim_expert_bytes(&b)?;
    let tight = 6 * sim;
    let serial = bs::run_method(
        b.clone(),
        Method::Sida,
        &bs::RunSpec::new("sst2", n).sleep(false).budget(tight).pool(1).prefetch_on(false),
    )?;
    let pooled = bs::run_method(
        b.clone(),
        Method::Sida,
        &bs::RunSpec::new("sst2", n).sleep(false).budget(tight).pool(0),
    )?;
    // depth-scheduled arm: the same pooled configuration at a tight
    // host-RAM window (2 experts, so misses are SSD-ladder-deep) and a
    // 16x-reference host link (staging occupancy fits the per-layer
    // drain, so each fetch's *deadline* binds its overlap credit),
    // with the cross-layer scheduler clamped to the one-layer-ahead
    // baseline (`--prefetch-depth 1`) vs the default depth 3 — the
    // pair isolates what deadline-aware deep staging buys in exposed
    // transfer at fixed budgets.
    let depth_spec = |d: usize| {
        bs::RunSpec::new("sst2", n)
            .sleep(false)
            .budget(tight)
            .pool(0)
            .ram_budget(2 * sim + 1024)
            .host_bw(16.0 * 16.0e9)
            .prefetch_depth(d)
    };
    let depth1 = bs::run_method(b.clone(), Method::Sida, &depth_spec(1))?;
    let depth3 = bs::run_method(b.clone(), Method::Sida, &depth_spec(3))?;
    let mut t3 = Table::new(
        "expert-path per-stage breakdown (ms/request)",
        &[
            "mode", "gather", "expert compute", "expert wall", "scatter", "gate stall",
            "transfer exposed", "transfer overlapped", "modeled/req",
        ],
    );
    let breakdown_row = |mode: &str, st: &ServeStats| -> Vec<String> {
        let per = |secs: f64| format!("{:.3}", secs * 1e3 / st.requests.max(1) as f64);
        vec![
            mode.to_string(),
            per(st.phases.gather_secs),
            per(st.phases.expert_secs),
            per(st.phases.expert_wall_secs),
            per(st.phases.scatter_secs),
            per(st.phases.stall_secs),
            per(st.exposed_transfer_secs()),
            per(st.overlapped_transfer_secs),
            format!("{:.3}", bs::modeled_request_ms(st)),
        ]
    };
    t3.row(breakdown_row("serial (pool 1, no prefetch)", &serial.stats));
    t3.row(breakdown_row("pooled + layer-ahead", &pooled.stats));
    t3.row(breakdown_row("tight RAM, depth 1 (one-layer-ahead)", &depth1.stats));
    t3.row(breakdown_row("tight RAM, depth 3 (cross-layer EDF)", &depth3.stats));
    t3.print();
    let serial_ms = bs::modeled_request_ms(&serial.stats);
    let pooled_ms = bs::modeled_request_ms(&pooled.stats);
    let speedup = serial_ms / pooled_ms.max(1e-9);
    println!(
        "sequential-vs-pooled modeled latency: {serial_ms:.3}ms -> {pooled_ms:.3}ms \
         ({speedup:.2}x) — strictly lower: {}",
        if pooled_ms < serial_ms { "PASS" } else { "FAIL" }
    );

    let breakdown_json = |mode: &str, st: &ServeStats| -> Json {
        let per = |secs: f64| num(secs * 1e3 / st.requests.max(1) as f64);
        obj(vec![
            ("mode", s(mode)),
            ("requests", num(st.requests as f64)),
            ("gather_ms_per_req", per(st.phases.gather_secs)),
            ("expert_compute_ms_per_req", per(st.phases.expert_secs)),
            ("expert_wall_ms_per_req", per(st.phases.expert_wall_secs)),
            ("scatter_ms_per_req", per(st.phases.scatter_secs)),
            ("gate_stall_ms_per_req", per(st.phases.stall_secs)),
            ("transfer_exposed_ms_per_req", per(st.exposed_transfer_secs())),
            ("transfer_overlapped_ms_per_req", per(st.overlapped_transfer_secs)),
            ("modeled_request_ms", num(bs::modeled_request_ms(st))),
            ("blocking_misses", num(st.blocking_misses as f64)),
        ])
    };
    let depth1_exposed =
        depth1.stats.exposed_transfer_secs() * 1e3 / depth1.stats.requests.max(1) as f64;
    let depth3_exposed =
        depth3.stats.exposed_transfer_secs() * 1e3 / depth3.stats.requests.max(1) as f64;
    println!(
        "depth scheduling exposed transfer (tight RAM): {depth1_exposed:.3}ms/req \
         at depth 1 -> {depth3_exposed:.3}ms/req at depth 3"
    );
    let mut j = bs::BenchJson::new("hotpath");
    j.push(breakdown_json("serial", &serial.stats));
    j.push(breakdown_json("pooled_layer_ahead", &pooled.stats));
    j.push(breakdown_json("tight_ram_depth1_one_layer_ahead", &depth1.stats));
    j.push(breakdown_json("tight_ram_depth3_cross_layer", &depth3.stats));
    j.push(obj(vec![
        ("metric", s("sequential_vs_pooled_modeled_speedup")),
        ("speedup", num(speedup)),
        ("strictly_lower", Json::Bool(pooled_ms < serial_ms)),
    ]));
    j.push(obj(vec![
        ("metric", s("depth_scheduling_exposed_transfer_ms_per_req")),
        ("depth1", num(depth1_exposed)),
        ("depth3", num(depth3_exposed)),
    ]));
    j.push_table(&t2);
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    Ok(())
}
