//! Hot-path microbenchmark — the profiling harness behind
//! EXPERIMENTS.md §Perf (L3).
//!
//! Reports per-artifact dispatch statistics over a SiDA serving run
//! (calls, total time, mean) plus the isolated costs of the three
//! per-request stages: hash build, expert invocation (per bucket), and
//! end-to-end forward.  Re-run after each optimization to record the
//! before/after deltas.

use std::sync::Arc;
use std::time::Instant;

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::coordinator::HashBuilder;
use sida_moe::metrics::Table;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::runtime::stage_expert_parts;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "hotpath: per-stage microbenchmarks",
        "(internal perf harness, not a paper figure)",
    );
    let model = std::env::var("HOTPATH_MODEL").unwrap_or_else(|_| "switch128".to_string());
    let b = bs::load(&model)?;
    let runner = Arc::new(ModelRunner::new(b.clone(), "sst2")?);
    let builder = HashBuilder::new(&b, "sst2")?;
    let reqs = bs::trace_for(&b, "sst2", bs::n_requests(8), 3);

    // --- stage costs ----------------------------------------------------
    let mut t = Table::new("stage micro-costs", &["stage", "mean", "calls"]);
    // hash build (warm: first dispatch pays one-time PJRT setup)
    builder.build(0, &reqs[0].ids)?;
    let t0 = Instant::now();
    for req in &reqs {
        builder.build(req.id, &req.ids)?;
    }
    t.row(vec![
        "hash build (warm)".into(),
        format!("{:.3}ms", t0.elapsed().as_secs_f64() * 1e3 / reqs.len() as f64),
        reqs.len().to_string(),
    ]);
    // expert staging (H2D)
    let t0 = Instant::now();
    let iters = 32;
    for i in 0..iters {
        let _ = stage_expert_parts(
            &b.engine,
            &b.weights,
            b.topology.moe_blocks[0],
            i % b.topology.num_experts,
        )?;
    }
    t.row(vec![
        "expert stage (4 bufs)".into(),
        format!("{:.3}ms", t0.elapsed().as_secs_f64() * 1e3 / iters as f64),
        iters.to_string(),
    ]);
    // single expert invocation per bucket
    let staged = runner.stage_all_experts()?;
    for &bucket in &b.topology.buckets.clone() {
        if bucket > runner.seq_len * 2 {
            continue;
        }
        let (ids, _, _) = {
            let mut gen = sida_moe::workload::TraceGenerator::new(
                sida_moe::workload::Profile::named("sst2").unwrap(),
                b.topology.vocab,
                1,
            );
            gen.sentence()
        };
        let mut provider = ExpertProvider::AllResident(&staged);
        // warm
        let _ = runner.forward(&ids, None, &mut provider, ForwardOptions::default())?;
        let t0 = Instant::now();
        let iters = 8;
        for _ in 0..iters {
            let mut provider = ExpertProvider::AllResident(&staged);
            let _ = runner.forward(&ids, None, &mut provider, ForwardOptions::default())?;
        }
        t.row(vec![
            format!("full fwd (adaptive, warm, bucket<= {bucket})"),
            format!("{:.3}ms", t0.elapsed().as_secs_f64() * 1e3 / iters as f64),
            iters.to_string(),
        ]);
        break; // one representative row; buckets covered below via stats
    }
    t.print();

    // --- per-artifact dispatch stats over a serving run ------------------
    let spec = bs::RunSpec::new("sst2", bs::n_requests(8)).sleep(false);
    let _ = bs::run_method(b.clone(), Method::Sida, &spec)?;
    let mut stats = b.engine.all_stats();
    stats.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
    let mut t2 = Table::new(
        "per-artifact dispatch stats (SiDA serving run)",
        &["artifact", "calls", "total (ms)", "mean (us)"],
    );
    for (name, s) in stats.iter().take(12) {
        if s.calls == 0 {
            continue;
        }
        t2.row(vec![
            name.clone(),
            s.calls.to_string(),
            format!("{:.2}", s.total_secs * 1e3),
            format!("{:.1}", s.total_secs * 1e6 / s.calls as f64),
        ]);
    }
    t2.print();
    t2.save_csv(&bs::csv_path("hotpath"))?;
    Ok(())
}
