//! fig_cluster — multi-device expert-parallel serving: throughput and
//! per-device GPU-memory saving vs device count.
//!
//! Serves the same trace across 1, 2 and 4 modeled devices at a fixed
//! replication factor and reports, per device count: throughput, the
//! worst single device's placement footprint (`per_device_expert_bytes`
//! — the expert memory one accelerator must provision) and runtime peak
//! residency, load imbalance, and the modeled cross-device activation
//! traffic.  The shape under test: partitioning the expert pool shrinks
//! per-device expert memory as the fleet grows (homes ≈ ⌈E/N⌉ per layer
//! + R replicas), which is what makes big-E MoE models servable on
//! small devices at all.
//!
//! Unlike the artifact-backed figures this bench is **hermetic**: it
//! runs on the synthetic testkit bundle (two MoE layers), so CI's
//! bench-smoke job exercises the full cluster path instead of
//! SKIP-ing.  Emits `BENCH_cluster.json`.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::metrics::Table;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_cluster: multi-device expert parallelism",
        "per-device expert memory shrinks as devices grow; outputs bit-identical",
    );
    let bundle = testkit::bundle(&SynthSpec::default().two_moe_layers())?;
    let topo = &bundle.topology;
    let n = bs::n_requests(24);
    let warmup = testkit::tiny_trace(&bundle, 4, 0xA5A5);
    let requests = testkit::tiny_trace(&bundle, n, 7);

    let real_expert = bundle.weights.expert_bytes(topo.moe_blocks[0], 0)?;
    let sim_expert =
        sida_moe::memory::CostModel::paper_scale(real_expert).sim_bytes(real_expert);
    let replicate_top = 1usize;

    let mut t = Table::new(
        "fig_cluster — throughput and per-device memory vs device count",
        &[
            "devices", "tput (req/s)", "per-dev experts", "per-dev sim MB",
            "peak sim MB", "imbalance", "x-dev MB",
        ],
    );
    let mut j = bs::BenchJson::new("cluster");
    let mut assigned_bytes_by_n: Vec<(usize, usize)> = Vec::new();
    for devices in [1usize, 2, 4] {
        let cfg = PipelineConfig {
            budget_sim_bytes: 64 * sim_expert, // generous: placement, not thrash
            devices,
            replicate_top,
            want_cls: true,
            ..Default::default()
        };
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
        let _ = pipeline.serve(&warmup)?;
        pipeline.reset_serving_stats();
        let out = pipeline.serve(&requests)?;
        let stats = &out.stats;

        // the worst device's placement footprint: ⌈E/N⌉ homes per layer
        // plus replicas for N > 1; the whole pool on the one device
        // otherwise
        let (assigned, imbalance, cross_mb, interconnect_secs) = match &stats.cluster {
            Some(cl) => (
                cl.max_device_assigned(),
                cl.load_imbalance().unwrap_or(1.0),
                cl.cross_device_bytes as f64 / 1e6,
                cl.interconnect_secs,
            ),
            None => (topo.moe_blocks.len() * topo.num_experts, 1.0, 0.0, 0.0),
        };
        let assigned_bytes = assigned * sim_expert;
        assigned_bytes_by_n.push((devices, assigned_bytes));
        t.row(vec![
            devices.to_string(),
            format!("{:.2}", stats.throughput()),
            assigned.to_string(),
            format!("{:.1}", assigned_bytes as f64 / 1e6),
            format!("{:.1}", stats.peak_device_bytes as f64 / 1e6),
            format!("{imbalance:.2}x"),
            format!("{cross_mb:.2}"),
        ]);
        j.push(obj(vec![
            ("devices", num(devices as f64)),
            ("throughput_rps", num(stats.throughput())),
            ("replicate_top", num(replicate_top as f64)),
            // the fleet-aggregate §6 ladder (cache-driven, per device)
            ("ladder_secs", num(stats.hierarchy.ladder_secs())),
            ("ssd_promote_secs", num(stats.hierarchy.ssd_promote_secs)),
            ("ram_tier_bytes", num(stats.hierarchy.ram_bytes as f64)),
            ("per_device_expert_bytes", num(assigned_bytes as f64)),
            ("per_device_assigned_experts", num(assigned as f64)),
            ("max_device_peak_bytes", num(stats.peak_device_bytes as f64)),
            ("load_imbalance", num(imbalance)),
            ("cross_device_bytes", num(cross_mb * 1e6)),
            ("interconnect_secs", num(interconnect_secs)),
            ("requests", num(stats.requests as f64)),
            ("cache_hit_rate", num(stats.hit_rate().unwrap_or(0.0))),
            ("dataset", s(TINY_PROFILE)),
        ]));
    }
    t.print();
    t.save_csv(&bs::csv_path("fig_cluster"))?;

    let strictly_decreasing = assigned_bytes_by_n
        .windows(2)
        .all(|w| w[1].1 < w[0].1);
    println!(
        "cluster check: per-device resident expert bytes strictly decreasing \
         with device count at fixed replication (R={replicate_top}): {}",
        if strictly_decreasing { "PASS" } else { "FAIL" }
    );
    j.push(obj(vec![
        ("per_device_bytes_strictly_decreasing", Json::Bool(strictly_decreasing)),
    ]));
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    if !strictly_decreasing {
        std::process::exit(1);
    }
    Ok(())
}
