//! Figure 10 — Inference latency of SiDA vs baselines.
//!
//! Paper: SiDA reduces latency to ~25% of baselines on SST2/MRPC and
//! ~60% on MultiRC for the large models (down to 28% on
//! Switch-base-256); improvements grow as sentences shorten.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::metrics::report::fmt_secs;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 10: latency vs baselines",
        "SiDA latency down to 25-28% of baselines on large models",
    );
    let n = bs::n_requests(10);
    let methods = [
        Method::Standard,
        Method::DeepspeedLike,
        Method::TutelLike,
        Method::Sida,
    ];
    let mut t = Table::new(
        "Fig 10 — p50 latency",
        &[
            "dataset", "model", "standard", "deepspeed", "tutel", "sida",
            "sida b8", "sida / standard",
        ],
    );
    for dataset in bs::ALL_DATASETS {
        for name in bs::ALL_MODELS {
            let b = bs::load(name)?;
            let mut p50 = Vec::new();
            for m in methods {
                let spec = bs::RunSpec::new(dataset, n);
                let mut out = bs::run_method(b.clone(), m, &spec)?;
                p50.push(out.stats.latency.p50());
            }
            // cross-request batched mode: per-request latency is the
            // shared batch forward (amortized expert traffic, but each
            // request waits for its whole batch)
            let mut batched =
                bs::run_method(b, Method::Sida, &bs::RunSpec::new(dataset, n).batch(8))?;
            t.row(vec![
                dataset.to_string(),
                name.to_string(),
                fmt_secs(p50[0]),
                fmt_secs(p50[1]),
                fmt_secs(p50[2]),
                fmt_secs(p50[3]),
                fmt_secs(batched.stats.latency.p50()),
                format!("{:.0}%", 100.0 * p50[3] / p50[0].max(1e-12)),
            ]);
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig10_latency"))?;
    let mut j = bs::BenchJson::new("fig10_latency");
    j.push_table(&t);
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    println!("paper shape check: SiDA/Standard ratio shrinks as E grows");
    println!("batched mode trades per-request latency for shared expert traffic (see fig9b)");
    Ok(())
}
