//! Figure 2 — Effective GPU memory utilization vs sentence length.
//!
//! Paper: effective utilization (bytes of parameters actually used in a
//! forward / bytes resident) drops to ~5% on Switch-base-256 for short
//! SST2 sentences; ineffective memory is ~46-50GB even for the longest
//! sentences.  Standard serving keeps the whole model resident, so
//! effective utilization = (dense bytes + activated expert bytes) /
//! total bytes.

use std::collections::BTreeMap;

use sida_moe::bench_support as bs;
use sida_moe::memory::CostModel;
use sida_moe::metrics::Table;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 2: effective memory utilization (Standard residency)",
        "down to ~5% utilization on Switch-base-256; ~46-50GB ineffective",
    );
    let n = bs::n_requests(24);
    let mut t = Table::new(
        "Fig 2 — effective memory utilization vs sentence length",
        &[
            "model", "len bucket", "effective util %", "ineffective sim GB",
        ],
    );
    for name in bs::ALL_MODELS {
        let b = bs::load(name)?;
        let topo = &b.topology;
        let cost = CostModel::paper_scale(topo.expert_param_bytes);
        let dense_bytes = topo.total_param_bytes - topo.moe_param_bytes;
        let expert_bytes = topo.expert_param_bytes;
        let total_sim = cost.sim_bytes(topo.total_param_bytes) as f64;
        for dataset in ["sst2", "multirc"] {
            let runner = ModelRunner::new(b.clone(), dataset)?;
            let reqs = bs::trace_for(&b, dataset, n, 11);
            let mut buckets: BTreeMap<usize, (usize, f64)> = BTreeMap::new();
            for req in &reqs {
                let mut provider = ExpertProvider::HostLiterals;
                let out = runner.forward(&req.ids, None, &mut provider,
                    ForwardOptions::default())?;
                let mask = ModelRunner::mask_of(&req.ids);
                let active_experts: usize =
                    out.routing.iter().map(|r| r.active_experts(&mask).len()).sum();
                let effective = dense_bytes + active_experts * expert_bytes;
                let util = cost.sim_bytes(effective) as f64 / total_sim;
                let bucket = (req.n_tokens / 32) * 32;
                let e = buckets.entry(bucket).or_insert((0, 0.0));
                e.0 += 1;
                e.1 += util;
            }
            for (bucket, (count, sum_util)) in buckets {
                let util = sum_util / count as f64;
                t.row(vec![
                    name.to_string(),
                    format!("{}-{}", bucket, bucket + 31),
                    format!("{:.1}", 100.0 * util),
                    format!("{:.2}", total_sim * (1.0 - util) / 1e9),
                ]);
            }
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig2_effective_memory"))?;
    Ok(())
}
