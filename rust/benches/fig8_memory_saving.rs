//! Figure 8 — GPU memory reduction by SiDA.
//!
//! Paper: >80% reduction on SST2 (E=256), >60% (E=128); MRPC saves
//! 6.28/19.84 GB; MultiRC (200-500 tokens) still saves >40%/20% —
//! 4.52 GB (E=128) and 9.92 GB (E=256).  Reduction = 1 - SiDA peak
//! device bytes / Standard full residency, at paper-scale simulated
//! bytes.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::memory::CostModel;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 8: GPU memory reduction (SiDA vs Standard residency)",
        ">80% on SST2 (E=256); MultiRC still >20-40% on large models",
    );
    let n = bs::n_requests(12);
    let mut t = Table::new(
        "Fig 8 — SiDA memory reduction",
        &[
            "model", "dataset", "standard sim GB", "sida peak sim GB",
            "saved sim GB", "reduction %", "ram tier GB", "ssd tier GB",
        ],
    );
    for name in bs::ALL_MODELS {
        let b = bs::load(name)?;
        let cost = CostModel::paper_scale(b.topology.expert_param_bytes);
        let full = cost.sim_bytes(b.topology.total_param_bytes) as f64;
        let dense = cost
            .sim_bytes(b.topology.total_param_bytes - b.topology.moe_param_bytes)
            as f64;
        for dataset in bs::ALL_DATASETS {
            let spec = bs::RunSpec::new(dataset, n).sleep(false);
            let out = bs::run_method(b.clone(), Method::Sida, &spec)?;
            // SiDA device footprint = dense weights (always resident) +
            // peak expert residency
            let sida = dense + out.stats.peak_device_bytes as f64;
            let saved = (full - sida).max(0.0);
            // where the saved bytes actually sit: the §6 ladder's RAM
            // window and SSD backing (cache-driven residency ledger)
            let h = &out.stats.hierarchy;
            t.row(vec![
                name.to_string(),
                dataset.to_string(),
                format!("{:.2}", full / 1e9),
                format!("{:.2}", sida / 1e9),
                format!("{:.2}", saved / 1e9),
                format!("{:.1}", 100.0 * saved / full),
                format!("{:.2}", h.ram_bytes as f64 / 1e9),
                format!("{:.2}", h.ssd_bytes as f64 / 1e9),
            ]);
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig8_memory_saving"))?;
    println!("paper shape check: reduction grows with E, shrinks with sentence length");
    Ok(())
}
