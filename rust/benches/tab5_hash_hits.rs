//! Table 5 — hash-hit rate (expert-activation prediction accuracy).
//!
//! Paper: top-3 hit rates 97.4-99.0% (E=8) and 90.5-98.8% (E=128) across
//! SST2/MRPC/MultiRC.  We measure in Rust: run the true router over a
//! held-out trace, build hash tables with the hash artifact, and count
//! how often the router's expert appears in the hash's top-k.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::HashBuilder;
use sida_moe::metrics::Table;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Tab 5: hash-hit rate (top-1 / top-3)",
        "top-3 hits 97.4-99.0% (E=8), 90.5-98.8% (E=128)",
    );
    let n = bs::n_requests(12);
    let mut t = Table::new(
        "Tab 5 — hash-hit rates",
        &["model", "dataset", "tokens", "top-1 %", "top-3 %", "top-4 %"],
    );
    for name in bs::ACCURACY_MODELS {
        let b = bs::load(name)?;
        for dataset in bs::ALL_DATASETS {
            let runner = ModelRunner::new(b.clone(), dataset)?;
            let builder = HashBuilder::new(&b, dataset)?;
            let reqs = bs::trace_for(&b, dataset, n, 99);
            let mut hits = [0u64; 3]; // top1, top3, top4
            let mut total = 0u64;
            for req in &reqs {
                let mut provider = ExpertProvider::HostLiterals;
                let out =
                    runner.forward(&req.ids, None, &mut provider, ForwardOptions::default())?;
                let table = builder.build(req.id, &req.ids)?;
                let mask = ModelRunner::mask_of(&req.ids);
                for (m, routing) in out.routing.iter().enumerate() {
                    for tok in 0..runner.seq_len {
                        if mask[tok] == 0.0 {
                            continue;
                        }
                        let truth = routing.top1[tok];
                        total += 1;
                        for (slot, k) in [(0usize, 1usize), (1, 3), (2, 4)] {
                            let hit = (0..k.min(table.k))
                                .any(|r| table.expert_at(tok, m, r) == truth);
                            if hit {
                                hits[slot] += 1;
                            }
                        }
                    }
                }
            }
            t.row(vec![
                name.to_string(),
                dataset.to_string(),
                total.to_string(),
                format!("{:.1}", 100.0 * hits[0] as f64 / total.max(1) as f64),
                format!("{:.1}", 100.0 * hits[1] as f64 / total.max(1) as f64),
                format!("{:.1}", 100.0 * hits[2] as f64 / total.max(1) as f64),
            ]);
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("tab5_hash_hits"))?;
    println!("paper shape check: top-3 >> top-1; rates drop with E and length");
    Ok(())
}
