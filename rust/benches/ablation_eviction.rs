//! Ablation — eviction policy under tight budgets.
//!
//! The paper fixes FIFO "for fair comparison with baselines, although
//! other strategies could also be effective" (§4.3 footnote).  This
//! bench quantifies that footnote: SiDA with FIFO/LRU/LFU/Clock at
//! budgets around one MoE layer's footprint.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::memory::CostModel;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Ablation: eviction policy x budget",
        "paper footnote 1: FIFO chosen for fairness; alternatives viable",
    );
    let n = bs::n_requests(10);
    let mut t = Table::new(
        "eviction ablation — SiDA on switch128/sst2",
        &[
            "budget (layer frac)", "policy", "ram policy", "hit rate %", "evictions",
            "transfer (GB)", "ssd promote (s)", "throughput (req/s)",
        ],
    );
    let b = bs::load("switch128")?;
    let cost = CostModel::paper_scale(b.topology.expert_param_bytes);
    let layer_bytes = cost.sim_bytes(b.topology.expert_param_bytes * b.topology.num_experts);
    for frac in [0.125, 0.25, 0.5] {
        let budget = ((layer_bytes as f64) * frac) as usize;
        for policy in ["fifo", "lru", "lfu", "clock"] {
            // the RAM window of the §6 ladder is policy-pluggable too
            // (--ram-policy; fifo vs lfu — in a victim tier recency is
            // insertion order, so lru would duplicate fifo); sized at
            // one device budget so the eviction choice decides what
            // stays a cheap PCIe hop away
            for ram_policy in ["fifo", "lfu"] {
                let spec = bs::RunSpec::new("sst2", n)
                    .budget(budget)
                    .policy_name(policy)
                    .ram_budget(budget)
                    .ram_policy_name(ram_policy);
                let out = bs::run_method(b.clone(), Method::Sida, &spec)?;
                let s = &out.stats;
                t.row(vec![
                    format!("{frac}"),
                    policy.to_string(),
                    ram_policy.to_string(),
                    sida_moe::metrics::report::fmt_rate(s.hit_rate()),
                    s.evictions.to_string(),
                    format!("{:.2}", s.transferred_bytes as f64 / 1e9),
                    format!("{:.3}", s.hierarchy.ssd_promote_secs),
                    format!("{:.2}", s.throughput()),
                ]);
            }
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("ablation_eviction"))?;
    Ok(())
}
