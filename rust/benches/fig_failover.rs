//! fig_failover — fault-tolerant cluster serving: kill 1 of 4 devices
//! mid-trace, verify serving degrades instead of dying.
//!
//! Serves the same trace twice on a 4-device fleet: once fault-free and
//! once under a deterministic fault plan that downs device 1 for the
//! middle half of the trace (batch ticks n/4 .. 3n/4).  The checks are
//! the ISSUE 8 acceptance criteria, and the bench exits 1 if any
//! fails:
//!
//! * **bit-identity** — per-request outputs under the fault schedule
//!   are exactly the fault-free outputs (failover moves work, never
//!   changes what it computes);
//! * **availability** — every offered request is served (>= 99%
//!   required; this path delivers 100% because lost lanes retry on
//!   survivors and the evacuated experts fail over);
//! * **accounting** — the outage is visible: nonzero failovers,
//!   exactly one device failure and one recovery, measured downtime;
//! * **recovery** — a post-recovery epoch (stats reset, trace
//!   re-served on the same pipeline) rebalances to within 10% of the
//!   fault-free run's load imbalance.
//!
//! Hermetic (synthetic two-MoE-layer bundle), so CI's bench-smoke job
//! exercises the failover path instead of SKIP-ing.  Emits
//! `BENCH_failover.json`.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{Pipeline, PipelineConfig, ServeOutcome};
use sida_moe::metrics::Table;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};

fn outputs(out: &ServeOutcome) -> Vec<(u64, Option<usize>)> {
    let mut v: Vec<_> = out.per_request.iter().map(|r| (r.id, r.cls_pred)).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_failover: device failure + recovery mid-trace",
        "outputs bit-identical, availability 100%, balance recovers",
    );
    let bundle = testkit::bundle(&SynthSpec::default().two_moe_layers())?;
    let n = bs::n_requests(24);
    let warmup = testkit::tiny_trace(&bundle, 4, 0xA5A5);
    let requests = testkit::tiny_trace(&bundle, n, 7);
    let devices = 4usize;
    // device 1 dies a quarter of the way into the measured trace and
    // recovers at three quarters (batch-1 serving: one fault tick per
    // request; the unmeasured warmup also ticks, hence the offset)
    let w = warmup.len() as u64;
    let plan = format!(
        "down:1@{}..{}",
        w + (n as u64 / 4).max(1),
        w + (3 * n as u64 / 4).max(2)
    );

    let run = |fault_plan: &str| -> anyhow::Result<(Pipeline, ServeOutcome)> {
        let cfg = PipelineConfig {
            devices,
            replicate_top: 1,
            min_replicas: 2,
            fault_plan: fault_plan.into(),
            want_cls: true,
            ..Default::default()
        };
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
        let _ = pipeline.serve(&warmup)?;
        pipeline.reset_serving_stats();
        let out = pipeline.serve(&requests)?;
        Ok((pipeline, out))
    };

    let (_clean_pipeline, clean) = run("")?;
    let (faulted_pipeline, faulted) = run(&plan)?;
    let clean_cl = clean.stats.cluster.clone().expect("cluster stats");
    let faulted_cl = faulted.stats.cluster.clone().expect("cluster stats");

    // post-recovery epoch: the fleet is whole again (every fault tick
    // has passed); a fresh measurement window must rebalance
    faulted_pipeline.reset_serving_stats();
    let recovered = faulted_pipeline.serve(&requests)?;
    let recovered_cl = recovered.stats.cluster.clone().expect("cluster stats");

    let availability = faulted.stats.requests as f64 / n as f64;
    let clean_imb = clean_cl.load_imbalance().unwrap_or(1.0);
    let recovered_imb = recovered_cl.load_imbalance().unwrap_or(1.0);

    let mut t = Table::new(
        &format!("fig_failover — 4 devices, fault plan {plan}"),
        &["run", "served", "failovers", "retries", "downtime s", "imbalance"],
    );
    for (name, out, cl) in [
        ("fault-free", &clean, &clean_cl),
        ("faulted", &faulted, &faulted_cl),
        ("post-recovery", &recovered, &recovered_cl),
    ] {
        t.row(vec![
            name.into(),
            out.stats.requests.to_string(),
            format!("{} ({} promoted)", cl.failovers, cl.failover_promotions),
            cl.retries.to_string(),
            format!("{:.3}", cl.downtime_secs),
            format!("{:.2}x", cl.load_imbalance().unwrap_or(1.0)),
        ]);
    }
    t.print();
    t.save_csv(&bs::csv_path("fig_failover"))?;

    let bit_identical =
        outputs(&faulted) == outputs(&clean) && outputs(&recovered) == outputs(&clean);
    let available = availability >= 0.99;
    let accounted = faulted_cl.failovers > 0
        && faulted_cl.device_failures == 1
        && faulted_cl.recoveries == 1
        && faulted_cl.downtime_secs > 0.0;
    let rebalanced = recovered_imb <= clean_imb * 1.10 + 1e-9;
    let checks = [
        ("outputs bit-identical to the fault-free run", bit_identical),
        ("availability >= 99%", available),
        ("failover + downtime accounted", accounted),
        ("post-recovery imbalance within 10% of fault-free", rebalanced),
    ];
    for (what, ok) in checks {
        println!("failover check: {what}: {}", if ok { "PASS" } else { "FAIL" });
    }

    let mut j = bs::BenchJson::new("failover");
    j.push(obj(vec![
        ("devices", num(devices as f64)),
        ("fault_plan", s(&plan)),
        ("requests", num(n as f64)),
        ("availability", num(availability)),
        ("throughput_rps_clean", num(clean.stats.throughput())),
        ("throughput_rps_faulted", num(faulted.stats.throughput())),
        ("failovers", num(faulted_cl.failovers as f64)),
        ("failover_promotions", num(faulted_cl.failover_promotions as f64)),
        ("retries", num(faulted_cl.retries as f64)),
        ("device_failures", num(faulted_cl.device_failures as f64)),
        ("recoveries", num(faulted_cl.recoveries as f64)),
        ("downtime_secs", num(faulted_cl.downtime_secs)),
        ("imbalance_clean", num(clean_imb)),
        ("imbalance_faulted", num(faulted_cl.load_imbalance().unwrap_or(1.0))),
        ("imbalance_post_recovery", num(recovered_imb)),
        ("bit_identical", Json::Bool(bit_identical)),
        ("dataset", s(TINY_PROFILE)),
    ]));
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    if checks.iter().any(|(_, ok)| !ok) {
        std::process::exit(1);
    }
    Ok(())
}
