//! fig_prefetch — cross-layer prefetch bandwidth scheduling vs the
//! one-layer-ahead baseline (ISSUE 10 headline).
//!
//! Serves the same trace twice at fixed tight device and `--ram-budget`
//! windows: once at `--prefetch-depth 1` (the PR 5 baseline — every
//! fetch staged exactly one layer ahead, one layer window of deadline)
//! and once at depth 3 (the cross-layer scheduler: SSD-deep experts
//! staged 2–3 layers ahead of their compute with correspondingly later
//! deadlines, EDF-admitted into the shared bandwidth window).  The CI
//! gates this bench enforces:
//!
//! * **exposed transfer seconds strictly drop** with depth scheduling —
//!   the deeper deadlines buy SSD promotions hideable window the
//!   one-layer-ahead model could never credit;
//! * **outputs are bit-identical** across depths — scheduling reorders
//!   and defers non-blocking staging only, never what compute sees;
//! * the ladder attribution identity (`ladder_secs() ==
//!   modeled_transfer_secs`) holds in both cells.
//!
//! Hermetic (synthetic testkit bundle) — CI's bench-smoke job RUNS this
//! instead of SKIP-ing.  Emits `BENCH_prefetch.json`.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::metrics::Table;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_prefetch: cross-layer prefetch scheduling vs one-layer-ahead",
        "exposed transfer strictly drops at fixed budgets; outputs bit-identical",
    );
    let bundle = testkit::bundle(&SynthSpec::default().two_moe_layers())?;
    let n = bs::n_requests(16);
    let requests = testkit::tiny_trace(&bundle, n, 7);

    let sim_expert = bs::sim_expert_bytes(&bundle)?;
    // Fixed budgets picking the scheduler's operating regime: the device
    // tier holds 8 of the 16 experts (a full request's two-layer union —
    // so deep staging never evicts the layer compute is on), the
    // host-RAM window only 2, so cross-request expert drift keeps a
    // steady share of promotions SSD-deep — exactly the ladder traffic
    // deep staging exists to hide.  The modeled host link runs at 16x
    // the reference PCIe rate: staging occupancy then stays inside the
    // per-layer drain, so the binding constraint on overlap credit is
    // each fetch's *deadline* — what `--prefetch-depth` moves — rather
    // than raw link saturation (where no schedule could help and both
    // depths would tie).
    let device_budget = 8 * sim_expert + 1024;
    let ram_budget = 2 * sim_expert + 1024;
    let host_bw = 16.0 * 16.0e9;

    let mut t = Table::new(
        "fig_prefetch — staging depth at fixed budgets",
        &[
            "depth", "exposed s", "overlapped s", "modeled s",
            "admitted", "deferred", "backlog s", "window util",
        ],
    );
    let mut j = bs::BenchJson::new("prefetch");
    let mut cells = Vec::new();
    for depth in [1usize, 3] {
        let cfg = PipelineConfig {
            k_used: 2,
            budget_sim_bytes: device_budget,
            ram_budget_bytes: ram_budget,
            prefetch_depth: depth,
            host_bw,
            want_lm: true,
            want_cls: true,
            // one worker lane: identical invocation order across cells
            pool_threads: 1,
            ..Default::default()
        };
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
        let out = pipeline.serve(&requests)?;
        let st = &out.stats;
        // the ladder attribution identity survives scheduling
        let drift = (st.ladder_secs() - st.modeled_transfer_secs).abs();
        anyhow::ensure!(
            drift <= 1e-9 * st.modeled_transfer_secs.max(1.0),
            "depth {depth}: ladder seconds {} drifted from modeled transfer {}",
            st.ladder_secs(),
            st.modeled_transfer_secs
        );
        t.row(vec![
            depth.to_string(),
            format!("{:.4}", st.exposed_transfer_secs()),
            format!("{:.4}", st.overlapped_transfer_secs),
            format!("{:.4}", st.modeled_transfer_secs),
            st.prefetch_admitted.to_string(),
            st.prefetch_deferred.to_string(),
            format!("{:.4}", st.prefetch_backlog_secs),
            st.prefetch_window_utilization
                .map_or_else(|| "-".into(), |u| format!("{:.0}%", 100.0 * u)),
        ]);
        j.push(obj(vec![
            ("prefetch_depth", num(depth as f64)),
            ("device_budget_bytes", num(device_budget as f64)),
            ("ram_budget_bytes", num(ram_budget as f64)),
            ("host_bw_bytes_per_sec", num(host_bw)),
            ("exposed_transfer_secs", num(st.exposed_transfer_secs())),
            ("overlapped_transfer_secs", num(st.overlapped_transfer_secs)),
            ("modeled_transfer_secs", num(st.modeled_transfer_secs)),
            ("prefetch_admitted", num(st.prefetch_admitted as f64)),
            ("prefetch_deferred", num(st.prefetch_deferred as f64)),
            ("prefetch_backlog_secs", num(st.prefetch_backlog_secs)),
            (
                "prefetch_window_utilization",
                st.prefetch_window_utilization.map(num).unwrap_or(Json::Null),
            ),
            ("requests", num(st.requests as f64)),
            ("dataset", s(TINY_PROFILE)),
        ]));
        let outputs: Vec<(Option<usize>, Option<f64>)> =
            out.per_request.iter().map(|r| (r.cls_pred, r.lm_nll)).collect();
        cells.push((depth, st.exposed_transfer_secs(), outputs));
    }
    t.print();
    t.save_csv(&bs::csv_path("fig_prefetch"))?;

    // the gates
    let (_, exposed_base, ref out_base) = cells[0];
    let (_, exposed_sched, ref out_sched) = cells[1];
    let strict_drop = exposed_sched < exposed_base - 1e-12;
    let bit_identical = out_base == out_sched;
    println!(
        "prefetch check: exposed transfer strictly drops with depth scheduling \
         ({exposed_base:.4}s -> {exposed_sched:.4}s): {}; outputs bit-identical \
         across depths: {}",
        if strict_drop { "PASS" } else { "FAIL" },
        if bit_identical { "PASS" } else { "FAIL" }
    );
    j.push(obj(vec![
        ("exposed_secs_depth1", num(exposed_base)),
        ("exposed_secs_depth3", num(exposed_sched)),
        ("exposed_strictly_drops", Json::Bool(strict_drop)),
        ("outputs_bit_identical", Json::Bool(bit_identical)),
    ]));
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    if !(strict_drop && bit_identical) {
        std::process::exit(1);
    }
    Ok(())
}
