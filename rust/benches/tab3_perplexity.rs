//! Table 3 — perplexity with the hash function replacing routers.
//!
//! Paper: pretrained ppl 6.68/4.93/4.86/4.59 vs SiDA ppl
//! 18.49/11.84/11.73/8.11 on C4 — degradation shrinks for larger models
//! ("stronger resistance to experts miss-classification").  We compute
//! both perplexities in Rust over a held-out trace on the long profile
//! (the C4 stand-in), router-routed vs hash-routed.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Tab 3: LM perplexity, router vs hash routing",
        "router ppl 4.59-6.68; hash ppl 8.11-18.49; gap shrinks with E",
    );
    let n = bs::n_requests(10);
    let mut t = Table::new(
        "Tab 3 — perplexity (held-out synthetic corpus)",
        &["model", "router ppl", "sida (hash) ppl", "ratio"],
    );
    for name in bs::ALL_MODELS {
        let b = bs::load(name)?;
        let ppl_of = |outcome: &sida_moe::coordinator::ServeOutcome| -> f64 {
            let (mut nll, mut tok) = (0.0, 0.0);
            for r in &outcome.per_request {
                nll += r.lm_nll.unwrap_or(0.0);
                tok += r.lm_tokens.unwrap_or(0.0);
            }
            (nll / tok.max(1.0)).exp()
        };
        // router path: any all-resident baseline computes true routing
        let spec = bs::RunSpec::new("multirc", n).lm(true).sleep(false);
        let router_out = bs::run_method(b.clone(), Method::TutelLike, &spec)?;
        let sida_out = bs::run_method(b.clone(), Method::Sida, &spec)?;
        let pr = ppl_of(&router_out);
        let ph = ppl_of(&sida_out);
        t.row(vec![
            name.to_string(),
            format!("{pr:.2}"),
            format!("{ph:.2}"),
            format!("{:.3}", ph / pr),
        ]);
    }
    t.print();
    t.save_csv(&bs::csv_path("tab3_perplexity"))?;
    println!("paper shape check: hash ppl >= router ppl; ratio shrinks as E grows");
    Ok(())
}
