//! Figures 6 + 7 — sparse cross-embedding dependency on expert activation.
//!
//! Fig 6 is the combinatorial model (Eq. 2): E[p-hat] = 1 - C(L-1-c, pL)
//! / C(L-1, pL) for candidate critical-token counts c.  Fig 7 measures
//! p-hat empirically: corrupt a random fraction p of the other tokens
//! (token corruption) or swap a fraction of positions (position
//! corruption) and record how often token i's expert assignment changes.
//! Reading the two together gives the best-fit c-hat, which the paper
//! finds in 1..4 — the justification for a lightweight hash function.

use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
use sida_moe::util::rng::Rng;

/// Eq. 2 of the paper.
fn expected_phat(l: usize, c: usize, p: f64) -> f64 {
    let k = (p * l as f64).floor() as usize;
    // 1 - C(L-1-c, k)/C(L-1, k) computed in log space
    if k + c > l - 1 {
        return 1.0;
    }
    let ln_c = |n: usize, r: usize| -> f64 {
        // ln C(n, r) via lgamma-free accumulation
        let mut s = 0.0;
        for i in 0..r {
            s += ((n - i) as f64).ln() - ((i + 1) as f64).ln();
        }
        s
    };
    1.0 - (ln_c(l - 1 - c, k) - ln_c(l - 1, k)).exp()
}

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 6+7: sparse cross-embedding dependency",
        "best-fit critical-token count c-hat in 1..4 (Switch-base-128, C4)",
    );
    let model = std::env::var("DEP_MODEL").unwrap_or_else(|_| "switch128".to_string());
    let b = bs::load(&model)?;
    // longest profile stands in for C4's L=512 (we cap at 256; DESIGN §2)
    let dataset = "multirc";
    let runner = ModelRunner::new(b.clone(), dataset)?;
    let n_sentences = bs::n_requests(4);
    let n_positions = bs::n_requests(8);
    let ps = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9];

    // --- Fig 6: the model curves --------------------------------------
    let mut t6 = Table::new(
        "Fig 6 — E[p-hat] under Eq. 2 (L=256)",
        &["c", "p=0.1", "p=0.3", "p=0.5", "p=0.7", "p=0.9"],
    );
    for c in [1usize, 2, 4, 8, 16] {
        t6.row(
            std::iter::once(c.to_string())
                .chain([0.1, 0.3, 0.5, 0.7, 0.9].iter().map(|&p| {
                    format!("{:.3}", expected_phat(256, c, p))
                }))
                .collect(),
        );
    }
    t6.print();
    t6.save_csv(&bs::csv_path("fig6_model"))?;

    // --- Fig 7: empirical corruption ----------------------------------
    let reqs = bs::trace_for(&b, dataset, n_sentences, 23);
    let mut rng = Rng::new(0xF16_7);
    let vocab = b.topology.vocab as u64;

    let router_experts = |ids: &[i32]| -> anyhow::Result<Vec<Vec<usize>>> {
        let mut provider = ExpertProvider::HostLiterals;
        let out = runner.forward(ids, None, &mut provider, ForwardOptions::default())?;
        Ok(out.routing.iter().map(|r| r.top1.clone()).collect())
    };

    let mut t7 = Table::new(
        "Fig 7 — empirical P(expert activation changes) vs corruption p",
        &["mode", "p", "p-hat", "best-fit c"],
    );
    for mode in ["token", "position"] {
        for &p in &ps {
            let mut changed = 0usize;
            let mut total = 0usize;
            for req in &reqs {
                let base = router_experts(&req.ids)?;
                let real = req.n_tokens;
                for _ in 0..n_positions {
                    // position i of interest (inside the real tokens)
                    let i = 1 + rng.usize_below(real.saturating_sub(2).max(1));
                    let mut ids = req.ids.clone();
                    let others: Vec<usize> =
                        (1..real - 1).filter(|&t| t != i).collect();
                    let n_corrupt =
                        ((p * others.len() as f64).floor() as usize).min(others.len());
                    let sel = rng.sample_indices(others.len(), n_corrupt);
                    match mode {
                        "token" => {
                            for &s in &sel {
                                let t = others[s];
                                // new token distinct from original and ids[i]
                                loop {
                                    let cand = 3 + rng.below(vocab - 3) as i32;
                                    if cand != req.ids[t] && cand != req.ids[i] {
                                        ids[t] = cand;
                                        break;
                                    }
                                }
                            }
                        }
                        _ => {
                            // swap selected positions pairwise
                            let mut chosen: Vec<usize> =
                                sel.iter().map(|&s| others[s]).collect();
                            rng.shuffle(&mut chosen);
                            for pair in chosen.chunks(2) {
                                if let [a, bpos] = pair {
                                    ids.swap(*a, *bpos);
                                }
                            }
                        }
                    }
                    let corrupted = router_experts(&ids)?;
                    // any MoE layer changing token i's expert counts
                    let delta = base
                        .iter()
                        .zip(corrupted.iter())
                        .any(|(b, c)| b[i] != c[i]);
                    if delta {
                        changed += 1;
                    }
                    total += 1;
                }
            }
            let phat = changed as f64 / total.max(1) as f64;
            // best-fit c under Eq. 2
            let best_c = (1..=32)
                .min_by(|&a, &bc| {
                    let ea = (expected_phat(256, a, p) - phat).abs();
                    let eb = (expected_phat(256, bc, p) - phat).abs();
                    ea.partial_cmp(&eb).unwrap()
                })
                .unwrap();
            t7.row(vec![
                mode.to_string(),
                format!("{p:.1}"),
                format!("{phat:.3}"),
                best_c.to_string(),
            ]);
        }
    }
    t7.print();
    t7.save_csv(&bs::csv_path("fig7_dependency"))?;
    println!("paper shape check: p-hat grows with p; best-fit c stays small (1-4)");
    Ok(())
}
