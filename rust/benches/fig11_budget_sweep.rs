//! Figure 11 — Throughput vs GPU memory budget.
//!
//! Paper: under constrained budgets SiDA's predicted-expert caching beats
//! the conventional model-parallel offloading ("Standard" in Fig 11 =
//! our Layerwise): SiDA's advantage is most pronounced at small budgets.
//! Reactive (fetch-on-miss, no prediction) is included as the ablation
//! the paper's Challenge 1 argues against.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::memory::CostModel;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 11: throughput vs device-memory budget",
        "SiDA wins at every budget; gap widens as budget shrinks",
    );
    let n = bs::n_requests(8);
    let mut t = Table::new(
        "Fig 11 — throughput (req/s) vs budget",
        &[
            "model", "dataset", "budget (sim GB)", "layerwise", "reactive", "sida",
            "sida/layerwise", "sida ladder s",
        ],
    );
    for name in ["switch128", "switch256"] {
        let b = bs::load(name)?;
        let cost = CostModel::paper_scale(b.topology.expert_param_bytes);
        let layer_bytes =
            cost.sim_bytes(b.topology.expert_param_bytes * b.topology.num_experts);
        // budgets as fractions of one full MoE layer
        for frac in [0.25, 0.5, 1.0, 2.0] {
            let budget = ((layer_bytes as f64) * frac) as usize;
            for dataset in ["sst2", "multirc"] {
                let run = |m: Method| -> anyhow::Result<sida_moe::coordinator::ServeOutcome> {
                    let spec = bs::RunSpec::new(dataset, n).budget(budget);
                    bs::run_method(b.clone(), m, &spec)
                };
                let lw = run(Method::Layerwise)?.stats.throughput();
                let re = run(Method::Reactive)?.stats.throughput();
                let sida_out = run(Method::Sida)?;
                let sida = sida_out.stats.throughput();
                t.row(vec![
                    name.to_string(),
                    dataset.to_string(),
                    format!("{:.2}", budget as f64 / 1e9),
                    format!("{lw:.2}"),
                    format!("{re:.2}"),
                    format!("{sida:.2}"),
                    format!("{:.2}x", sida / lw.max(1e-9)),
                    // tier-aware miss cost: the §6 ladder seconds the
                    // constrained budget exposed (cache-driven ledger)
                    format!("{:.3}", sida_out.stats.ladder_secs()),
                ]);
            }
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig11_budget_sweep"))?;
    println!("paper shape check: sida/layerwise ratio grows as the budget shrinks");
    Ok(())
}
