//! fig_slo — SLO-aware open-loop serving: tail latency vs offered load.
//!
//! Sweeps a Poisson offered rate from well below to 2x the measured
//! saturation rate of the pipeline, with half the requests marked
//! interactive under a deadline, and replays each trace open-loop
//! through admission control + deadline shedding.  The shape under
//! test: without SLO machinery an open-loop queue past saturation grows
//! without bound and so does p99; with admission control and shedding,
//! the latency of *admitted* interactive requests stays bounded near
//! the deadline no matter how far past saturation the offered load
//! goes — overload shows up in the shed/reject counters instead of the
//! tail.
//!
//! Like `fig_cluster` this bench is **hermetic**: it runs on the
//! synthetic testkit bundle, so CI's bench-smoke job exercises the SLO
//! path instead of SKIP-ing.  Emits `BENCH_slo.json` and exits
//! non-zero when the bound fails:
//!
//! * at 2x saturation the admitted-interactive p99 must stay within
//!   5x the unloaded baseline (with shedding/admission active), and
//! * at 0.25x saturation nothing may be shed or SLO-rejected.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{replay_open_loop, Pipeline, PipelineConfig};
use sida_moe::metrics::report::fmt_secs;
use sida_moe::metrics::Table;
use sida_moe::testkit::{self, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};
use sida_moe::workload::{ArrivalProcess, ClassMix};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_slo: SLO-bounded tail latency under overload",
        "admission control + shedding keep admitted p99 bounded past saturation",
    );
    let bundle = testkit::tiny_bundle();
    let n = bs::n_requests(64);
    // generous queue bound: overload must be absorbed by the SLO
    // machinery (admission control + shedding), not by capacity drops
    let queue_cap = 4096;

    let cfg = PipelineConfig { want_cls: true, ..Default::default() };
    let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
    let warmup = testkit::tiny_trace(&bundle, 4, 0xA5A5);
    let _ = pipeline.serve(&warmup)?;
    pipeline.reset_serving_stats();

    // unloaded baseline: closed-loop batch-1 service latency (no
    // queueing).  The 10 ms floor absorbs CI scheduling noise — on the
    // tiny bundle raw service can be well under a millisecond, and the
    // deadline/bound arithmetic below must not hinge on sub-ms jitter.
    let mut unloaded = pipeline.serve(&testkit::tiny_trace(&bundle, n.min(32), 7))?;
    let base_secs = unloaded.stats.latency.p99().max(0.010);
    let mean_service = unloaded.stats.latency.mean().max(1e-6);
    let saturation_rate = 1.0 / mean_service;
    let deadline_secs = 3.0 * base_secs;
    let bound_secs = 5.0 * base_secs;
    println!(
        "baseline: p99 {} (floored base {}) | saturation ~{:.0} req/s | deadline {} | bound {}",
        fmt_secs(unloaded.stats.latency.p99()),
        fmt_secs(base_secs),
        saturation_rate,
        fmt_secs(deadline_secs),
        fmt_secs(bound_secs),
    );

    let mix = ClassMix { interactive_frac: 0.5, deadline_secs };
    let mut t = Table::new(
        "fig_slo — open-loop tail latency vs offered load",
        &[
            "load (x sat)", "offered", "served", "rej", "slo-rej", "shed",
            "int p99", "int p99.9", "slo att",
        ],
    );
    let mut j = bs::BenchJson::new("slo");
    let mut low_load_clean = true;
    let mut overload_bounded = true;
    let mut overload_shedding_active = false;
    for (i, mult) in [0.25f64, 0.5, 1.0, 2.0].into_iter().enumerate() {
        let rate = mult * saturation_rate;
        // the overload row must run long enough for the backlog to push
        // queue delay past the deadline (backlog grows ~1 per service
        // time at 2x saturation), otherwise a short trace never trips
        // the SLO machinery it is supposed to demonstrate
        let n_row = if mult >= 2.0 {
            n.max(((5.0 * deadline_secs / mean_service).ceil() as usize).min(20_000))
        } else {
            n
        };
        let trace = testkit::tiny_trace_classed(
            &bundle,
            n_row,
            11 + i as u64,
            ArrivalProcess::Poisson { rate },
            mix,
        );
        pipeline.reset_serving_stats();
        let report = replay_open_loop(&pipeline, &trace, queue_cap)?;
        let mut stats = report.outcome.stats;
        let int_p99 = stats.latency_interactive.p99();
        let int_p999 = stats.latency_interactive.p999();
        let attainment = stats.slo_attainment().unwrap_or(1.0);
        let dropped = report.shed + report.rejected + report.rejected_slo;
        if mult <= 0.25 && dropped > 0 {
            low_load_clean = false;
        }
        if mult >= 2.0 {
            overload_shedding_active = dropped > 0;
            if !stats.latency_interactive.is_empty() && int_p99 > bound_secs {
                overload_bounded = false;
            }
        }
        t.row(vec![
            format!("{mult:.2}"),
            trace.len().to_string(),
            stats.requests.to_string(),
            report.rejected.to_string(),
            report.rejected_slo.to_string(),
            report.shed.to_string(),
            fmt_secs(int_p99),
            fmt_secs(int_p999),
            format!("{:.0}%", 100.0 * attainment),
        ]);
        j.push(obj(vec![
            ("load_multiplier", num(mult)),
            ("offered_rate_rps", num(rate)),
            ("offered", num(trace.len() as f64)),
            ("served", num(stats.requests as f64)),
            ("rejected_capacity", num(report.rejected as f64)),
            ("rejected_slo", num(report.rejected_slo as f64)),
            ("shed", num(report.shed as f64)),
            ("interactive_p99_secs", num(int_p99)),
            ("interactive_p999_secs", num(int_p999)),
            ("batch_p99_secs", num(stats.latency_batch.p99())),
            ("mean_queueing_secs", num(report.mean_queueing_secs)),
            ("slo_attainment", num(attainment)),
            ("dataset", s(TINY_PROFILE)),
        ]));
    }
    t.print();
    t.save_csv(&bs::csv_path("fig_slo"))?;

    let bounded = overload_bounded && overload_shedding_active;
    println!(
        "slo check: no shedding at 0.25x load: {}",
        if low_load_clean { "PASS" } else { "FAIL" }
    );
    println!(
        "slo check: admitted-interactive p99 within {} at 2x saturation with \
         shedding/admission active: {}",
        fmt_secs(bound_secs),
        if bounded { "PASS" } else { "FAIL" }
    );
    j.push(obj(vec![
        ("deadline_secs", num(deadline_secs)),
        ("bound_secs", num(bound_secs)),
        ("saturation_rate_rps", num(saturation_rate)),
        ("low_load_clean", Json::Bool(low_load_clean)),
        ("overload_bounded", Json::Bool(overload_bounded)),
        ("overload_shedding_active", Json::Bool(overload_shedding_active)),
    ]));
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    if !(low_load_clean && bounded) {
        std::process::exit(1);
    }
    Ok(())
}
