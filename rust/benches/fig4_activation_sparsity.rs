//! Figure 4 — Expert Activation in Switch Transformers (SST2).
//!
//! Paper: sentence-level sparsity persists — Switch-base-256 activates
//! <20% of experts, Switch-base-128 <40%; even the longest sentences
//! leave >70-80% of experts idle.  We run the true router over generated
//! sentences, bucket by sentence length, and report the idle-expert
//! ratio per model.

use std::collections::BTreeMap;

use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;
use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 4: sentence-level expert activation sparsity",
        "idle ratio >80% (E=256), >70% (E=128) even for the longest sentences",
    );
    let n = bs::n_requests(24);
    let mut t = Table::new(
        "Fig 4 — idle expert ratio by sentence length (router-measured)",
        &["model", "len bucket", "sentences", "active experts (mean)", "idle ratio"],
    );
    for name in bs::ALL_MODELS {
        let b = bs::load(name)?;
        let e_total = b.topology.num_experts as f64;
        // span short + long sentences: sst2 and multirc profiles
        for dataset in ["sst2", "multirc"] {
            let runner = ModelRunner::new(b.clone(), dataset)?;
            let reqs = bs::trace_for(&b, dataset, n, 7);
            let mut buckets: BTreeMap<usize, (usize, f64)> = BTreeMap::new();
            for req in &reqs {
                let mut provider = ExpertProvider::HostLiterals;
                let out = runner.forward(&req.ids, None, &mut provider,
                    ForwardOptions::default())?;
                let mask = ModelRunner::mask_of(&req.ids);
                let active: f64 = out
                    .routing
                    .iter()
                    .map(|r| r.active_experts(&mask).len() as f64)
                    .sum::<f64>()
                    / out.routing.len() as f64;
                let bucket = (req.n_tokens / 32) * 32;
                let entry = buckets.entry(bucket).or_insert((0, 0.0));
                entry.0 += 1;
                entry.1 += active;
            }
            for (bucket, (count, sum_active)) in buckets {
                let mean_active = sum_active / count as f64;
                t.row(vec![
                    name.to_string(),
                    format!("{}-{}", bucket, bucket + 31),
                    count.to_string(),
                    format!("{mean_active:.1}"),
                    format!("{:.1}%", 100.0 * (1.0 - mean_active / e_total)),
                ]);
            }
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig4_activation_sparsity"))?;
    Ok(())
}
