//! Figure 9 — Throughput of SiDA vs Standard / DeepSpeed / Tutel,
//! plus the cross-request batching comparison (batch=8 vs batch-1).
//!
//! Paper: SiDA exceeds the baseline average by 2.60x / 3.93x on SST2,
//! 2.52x / 3.83x on MRPC, 1.26x / 1.57x on MultiRC for Switch-base-128 /
//! Switch-base-256 (smaller models roughly comparable).  The second
//! table runs SiDA under a tight device budget in both modes: batched
//! serving must move strictly fewer expert H2D bytes per request (each
//! activated expert is fetched once per batch, not once per request)
//! and issue fewer expert invocations per request.

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;
use sida_moe::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 9: throughput vs baselines",
        "SiDA 2.60x/3.93x over baseline average on SST2 at E=128/256",
    );
    let n = bs::n_requests(10);
    let methods = [
        Method::Standard,
        Method::DeepspeedLike,
        Method::TutelLike,
        Method::Sida,
    ];
    let mut t = Table::new(
        "Fig 9 — throughput (req/s)",
        &[
            "dataset", "model", "standard", "deepspeed", "tutel", "sida",
            "sida / baseline-avg",
        ],
    );
    for dataset in bs::ALL_DATASETS {
        for name in bs::ALL_MODELS {
            let b = bs::load(name)?;
            let mut tput = Vec::new();
            for m in methods {
                let spec = bs::RunSpec::new(dataset, n);
                let out = bs::run_method(b.clone(), m, &spec)?;
                tput.push(out.stats.throughput());
            }
            let base_avg = (tput[0] + tput[1] + tput[2]) / 3.0;
            t.row(vec![
                dataset.to_string(),
                name.to_string(),
                format!("{:.2}", tput[0]),
                format!("{:.2}", tput[1]),
                format!("{:.2}", tput[2]),
                format!("{:.2}", tput[3]),
                format!("{:.2}x", tput[3] / base_avg.max(1e-9)),
            ]);
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig9_throughput"))?;
    println!("paper shape check: SiDA speedup grows with E; largest on short sentences");

    // ---- Fig 9b: cross-request batching (SiDA batch=8 vs batch-1) ----
    // A tight device budget makes batch-1 serving re-fetch experts per
    // request; batched serving charges the batch-union once per batch.
    let mut t2 = Table::new(
        "Fig 9b — SiDA cross-request batching under a tight budget",
        &[
            "dataset", "model", "tput b1", "tput b8", "H2D/req b1", "H2D/req b8",
            "invoc/req b1", "invoc/req b8",
        ],
    );
    let mut all_fewer = true;
    for dataset in bs::ALL_DATASETS {
        let name = "switch128";
        let b = bs::load(name)?;
        // room for a handful of experts: far below one full MoE layer
        let tight = 12 * bs::sim_expert_bytes(&b)?;
        let b1 = bs::run_method(
            b.clone(),
            Method::Sida,
            &bs::RunSpec::new(dataset, n).budget(tight).batch(1),
        )?;
        let b8 = bs::run_method(
            b,
            Method::Sida,
            &bs::RunSpec::new(dataset, n).budget(tight).batch(8),
        )?;
        let h2d_1 = b1.stats.transferred_bytes_per_request();
        let h2d_8 = b8.stats.transferred_bytes_per_request();
        let inv_1 = b1.stats.phases.expert_invocations as f64 / b1.stats.requests.max(1) as f64;
        let inv_8 = b8.stats.phases.expert_invocations as f64 / b8.stats.requests.max(1) as f64;
        all_fewer &= h2d_8 < h2d_1 && inv_8 < inv_1;
        t2.row(vec![
            dataset.to_string(),
            name.to_string(),
            format!("{:.2}", b1.stats.throughput()),
            format!("{:.2}", b8.stats.throughput()),
            format!("{:.1}MB", h2d_1 / 1e6),
            format!("{:.1}MB", h2d_8 / 1e6),
            format!("{inv_1:.1}"),
            format!("{inv_8:.1}"),
        ]);
    }
    t2.print();
    t2.save_csv(&bs::csv_path("fig9b_batched"))?;
    println!(
        "batched-mode check: H2D transfers AND expert invocations per request \
         strictly fewer in batch=8 mode: {}",
        if all_fewer { "PASS" } else { "FAIL" }
    );

    // ---- Fig 9c: pooled expert execution + layer-ahead overlap -------
    // Same trace, tight budget, virtual transfer cost.  The serial path
    // (pool 1, no prefetch) pays every expert fetch on the critical
    // path; the pooled path overlaps fetches with compute layer-ahead
    // and fans expert invocations across the worker pool — its modeled
    // per-request latency (exposed transfer + compute) must be
    // strictly lower.
    let mut t3 = Table::new(
        "Fig 9c — serial vs pooled+overlap modeled latency",
        &["dataset", "serial (ms/req)", "pooled (ms/req)", "speedup", "strictly lower"],
    );
    let mut j = bs::BenchJson::new("fig9_throughput");
    let mut all_lower = true;
    let b128 = bs::load("switch128")?;
    let tight = 12 * bs::sim_expert_bytes(&b128)?;
    for dataset in bs::ALL_DATASETS {
        let serial = bs::run_method(
            b128.clone(),
            Method::Sida,
            &bs::RunSpec::new(dataset, n).sleep(false).budget(tight).pool(1).prefetch_on(false),
        )?;
        let pooled = bs::run_method(
            b128.clone(),
            Method::Sida,
            &bs::RunSpec::new(dataset, n).sleep(false).budget(tight).pool(0),
        )?;
        let serial_ms = bs::modeled_request_ms(&serial.stats);
        let pooled_ms = bs::modeled_request_ms(&pooled.stats);
        let lower = pooled_ms < serial_ms;
        all_lower &= lower;
        t3.row(vec![
            dataset.to_string(),
            format!("{serial_ms:.3}"),
            format!("{pooled_ms:.3}"),
            format!("{:.2}x", serial_ms / pooled_ms.max(1e-9)),
            if lower { "PASS".into() } else { "FAIL".into() },
        ]);
        j.push(obj(vec![
            ("dataset", s(dataset)),
            ("serial_modeled_request_ms", num(serial_ms)),
            ("pooled_overlap_modeled_request_ms", num(pooled_ms)),
            ("serial_exposed_transfer_secs", num(serial.stats.exposed_transfer_secs())),
            ("pooled_exposed_transfer_secs", num(pooled.stats.exposed_transfer_secs())),
            ("pooled_overlapped_transfer_secs", num(pooled.stats.overlapped_transfer_secs)),
            ("strictly_lower", Json::Bool(lower)),
        ]));
    }
    t3.print();
    t3.save_csv(&bs::csv_path("fig9c_overlap"))?;
    println!(
        "overlap check: pooled+layer-ahead modeled per-request latency strictly \
         lower than serial on every dataset: {}",
        if all_lower { "PASS" } else { "FAIL" }
    );
    j.push_table(&t);
    j.push_table(&t2);
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    Ok(())
}
