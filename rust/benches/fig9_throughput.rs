//! Figure 9 — Throughput of SiDA vs Standard / DeepSpeed / Tutel.
//!
//! Paper: SiDA exceeds the baseline average by 2.60x / 3.93x on SST2,
//! 2.52x / 3.83x on MRPC, 1.26x / 1.57x on MultiRC for Switch-base-128 /
//! Switch-base-256 (smaller models roughly comparable).

use sida_moe::baselines::Method;
use sida_moe::bench_support as bs;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Fig 9: throughput vs baselines",
        "SiDA 2.60x/3.93x over baseline average on SST2 at E=128/256",
    );
    let n = bs::n_requests(10);
    let methods = [
        Method::Standard,
        Method::DeepspeedLike,
        Method::TutelLike,
        Method::Sida,
    ];
    let mut t = Table::new(
        "Fig 9 — throughput (req/s)",
        &[
            "dataset", "model", "standard", "deepspeed", "tutel", "sida",
            "sida / baseline-avg",
        ],
    );
    for dataset in bs::ALL_DATASETS {
        for name in bs::ALL_MODELS {
            let b = bs::load(name)?;
            let mut tput = Vec::new();
            for m in methods {
                let spec = bs::RunSpec::new(dataset, n);
                let out = bs::run_method(b.clone(), m, &spec)?;
                tput.push(out.stats.throughput());
            }
            let base_avg = (tput[0] + tput[1] + tput[2]) / 3.0;
            t.row(vec![
                dataset.to_string(),
                name.to_string(),
                format!("{:.2}", tput[0]),
                format!("{:.2}", tput[1]),
                format!("{:.2}", tput[2]),
                format!("{:.2}", tput[3]),
                format!("{:.2}x", tput[3] / base_avg.max(1e-9)),
            ]);
        }
    }
    t.print();
    t.save_csv(&bs::csv_path("fig9_throughput"))?;
    println!("paper shape check: SiDA speedup grows with E; largest on short sentences");
    Ok(())
}
