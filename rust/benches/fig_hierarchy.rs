//! fig_hierarchy — the §6 GPU→RAM→SSD ladder under a shrinking host-RAM
//! window.
//!
//! Serves the same trace at a fixed (tight) device budget while the
//! modeled `--ram-budget` sweeps from "holds every expert" down to
//! zero.  Device-tier evictions demote their policy-chosen victims into
//! the RAM window; what the window cannot hold falls to SSD, and every
//! re-fetch of an SSD-deep expert pays the NVMe+PCIe ladder (~9x a
//! RAM-resident promote).  The shape under test — and the CI gate this
//! bench enforces — is the ladder's defining monotonicity: **SSD-tier
//! promotion seconds must not decrease as the RAM budget shrinks**, and
//! must be strictly larger with no RAM window than with a full one.
//!
//! Determinism discipline: prefetch off + a single worker lane, so the
//! fetch/eviction history is identical across cells and the inclusion
//! property of the FIFO RAM window makes the gate exact, not
//! statistical.  Hermetic (synthetic testkit bundle) — CI's bench-smoke
//! job RUNS this instead of SKIP-ing.  Emits `BENCH_hierarchy.json`.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{Pipeline, PipelineConfig};
use sida_moe::metrics::Table;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_hierarchy: tiered memory (GPU -> RAM -> SSD) vs --ram-budget",
        "SSD exposure grows monotonically as the host-RAM window shrinks (paper §6)",
    );
    let bundle = testkit::bundle(&SynthSpec::default().two_moe_layers())?;
    let topo = &bundle.topology;
    let n = bs::n_requests(16);
    let requests = testkit::tiny_trace(&bundle, n, 7);

    let sim_expert = bs::sim_expert_bytes(&bundle)?;
    let total_experts = topo.moe_blocks.len() * topo.num_experts;
    // tight device tier: room for 4 experts out of the full pool, so the
    // ladder below actually carries traffic
    let device_budget = 4 * sim_expert + 1024;

    let mut t = Table::new(
        "fig_hierarchy — ladder exposure vs RAM budget (device budget fixed)",
        &[
            "ram budget (experts)", "ssd promote s", "ram promote s",
            "demote ram/ssd", "ram used MB", "ssd used MB", "hit rate",
        ],
    );
    let mut j = bs::BenchJson::new("hierarchy");
    // experts the RAM window holds: everything -> nothing
    let ram_experts = [total_experts, 4, 2, 1, 0];
    let mut ssd_secs_by_cell: Vec<(usize, f64)> = Vec::new();
    for &re in &ram_experts {
        let cfg = PipelineConfig {
            k_used: 2,
            budget_sim_bytes: device_budget,
            ram_budget_bytes: re * sim_expert + if re > 0 { 1024 } else { 0 },
            want_cls: true,
            // determinism: every fetch on the inference thread, one lane
            prefetch: false,
            pool_threads: 1,
            ..Default::default()
        };
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
        let out = pipeline.serve(&requests)?;
        let st = &out.stats;
        let h = &st.hierarchy;
        // one merged timeline: the ladder attribution IS the modeled
        // transfer total (no parallel promote clock)
        let drift = (h.ladder_secs() - st.modeled_transfer_secs).abs();
        anyhow::ensure!(
            drift <= 1e-9 * st.modeled_transfer_secs.max(1.0),
            "ladder seconds {} drifted from modeled transfer {}",
            h.ladder_secs(),
            st.modeled_transfer_secs
        );
        ssd_secs_by_cell.push((re, h.ssd_promote_secs));
        t.row(vec![
            re.to_string(),
            format!("{:.4}", h.ssd_promote_secs),
            format!("{:.4}", h.ram_promote_secs),
            format!("{}/{}", h.demotions_to_ram, h.demotions_to_ssd),
            format!("{:.1}", h.ram_bytes as f64 / 1e6),
            format!("{:.1}", h.ssd_bytes as f64 / 1e6),
            sida_moe::metrics::report::fmt_rate(st.hit_rate()),
        ]);
        j.push(obj(vec![
            ("ram_budget_experts", num(re as f64)),
            ("ram_budget_bytes", num((re * sim_expert) as f64)),
            ("device_budget_bytes", num(device_budget as f64)),
            ("ssd_promote_secs", num(h.ssd_promote_secs)),
            ("ram_promote_secs", num(h.ram_promote_secs)),
            ("ladder_secs", num(h.ladder_secs())),
            ("promotions_from_ssd", num(h.promotions_from_ssd as f64)),
            ("promotions_from_ram", num(h.promotions_from_ram as f64)),
            ("demotions_to_ram", num(h.demotions_to_ram as f64)),
            ("demotions_to_ssd", num(h.demotions_to_ssd as f64)),
            // measured wall-clock timeline of the on-disk store (zero
            // here: this bench runs store-less; fig_store exercises it)
            ("measured_ssd_read_secs", num(h.measured_ssd_read_secs)),
            ("measured_ssd_write_secs", num(h.measured_ssd_write_secs)),
            ("store_bytes_on_disk", num(h.store_bytes_on_disk as f64)),
            ("integrity_failures", num(h.integrity_failures as f64)),
            ("requests", num(st.requests as f64)),
            ("dataset", s(TINY_PROFILE)),
        ]));
    }
    t.print();
    t.save_csv(&bs::csv_path("fig_hierarchy"))?;

    // the gate: SSD promote seconds never decrease as RAM shrinks, and
    // strictly grow from the full window to none
    let monotone = ssd_secs_by_cell.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-12);
    let strict = ssd_secs_by_cell.last().unwrap().1
        > ssd_secs_by_cell.first().unwrap().1 + 1e-12;
    println!(
        "hierarchy check: SSD promote seconds monotone non-decreasing as \
         --ram-budget shrinks: {}; strictly larger at ram=0 than full RAM: {}",
        if monotone { "PASS" } else { "FAIL" },
        if strict { "PASS" } else { "FAIL" }
    );
    j.push(obj(vec![
        ("ssd_secs_monotone_in_shrinking_ram", Json::Bool(monotone)),
        ("ssd_secs_strictly_grow_without_ram", Json::Bool(strict)),
    ]));

    // scheduled arm: at one fixed tight-RAM cell (window = 2 experts,
    // device tier = a full request's two-layer union, host link at 16x
    // reference so the deadline — not raw saturation — binds the
    // overlap credit), turn prefetch on and compare the one-layer-ahead
    // baseline (`--prefetch-depth 1`) against the cross-layer bandwidth
    // scheduler (depth 3).  The deeper deadlines let SSD-ladder
    // promotions start 2-3 layers ahead of their compute, so the same
    // modeled seconds hide behind compute instead of stalling it.  The
    // strict-drop CI gate for this arm lives in fig_prefetch; here the
    // exposed seconds ride along in the JSON for the trajectory plots.
    let mut exposed_by_depth: Vec<(usize, f64)> = Vec::new();
    for depth in [1usize, 3] {
        let cfg = PipelineConfig {
            k_used: 2,
            budget_sim_bytes: 8 * sim_expert + 1024,
            ram_budget_bytes: 2 * sim_expert + 1024,
            prefetch_depth: depth,
            host_bw: 16.0 * 16.0e9,
            want_cls: true,
            pool_threads: 1,
            ..Default::default()
        };
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg)?;
        let out = pipeline.serve(&requests)?;
        let st = &out.stats;
        exposed_by_depth.push((depth, st.exposed_transfer_secs()));
        j.push(obj(vec![
            ("arm", s("scheduled")),
            ("prefetch_depth", num(depth as f64)),
            ("ram_budget_experts", num(2.0)),
            ("device_budget_bytes", num((8 * sim_expert + 1024) as f64)),
            ("host_bw_bytes_per_sec", num(16.0 * 16.0e9)),
            ("exposed_transfer_secs", num(st.exposed_transfer_secs())),
            ("overlapped_transfer_secs", num(st.overlapped_transfer_secs)),
            ("modeled_transfer_secs", num(st.modeled_transfer_secs)),
            ("prefetch_admitted", num(st.prefetch_admitted as f64)),
            ("prefetch_deferred", num(st.prefetch_deferred as f64)),
            ("dataset", s(TINY_PROFILE)),
        ]));
    }
    println!(
        "scheduled arm (ram=2 experts): exposed transfer {:.4}s at depth 1 \
         -> {:.4}s at depth 3",
        exposed_by_depth[0].1, exposed_by_depth[1].1
    );
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    if !(monotone && strict) {
        std::process::exit(1);
    }
    Ok(())
}
