//! Table 2 — Memory Occupation of Switch Transformers.
//!
//! Paper: MoE parameters dominate memory (78.03% for Switch-base-8 up to
//! 99.07% for Switch-base-256).  We print both the physical bytes of the
//! repro models and the paper-scale simulated bytes (CostModel maps each
//! tiny expert to a Switch-base expert), whose absolute GB line up with
//! the paper's rows.

use sida_moe::bench_support as bs;
use sida_moe::memory::CostModel;
use sida_moe::metrics::Table;

fn main() -> anyhow::Result<()> {
    bs::banner(
        "Tab 2: memory occupation",
        "MoE share of model bytes: 78.03 / 96.42 / 98.17 / 99.07 % for E=8/64/128/256",
    );
    let mut t = Table::new(
        "Tab 2 — memory occupation",
        &[
            "model", "phys model (MB)", "phys MoE (MB)", "sim model (GB)", "sim MoE (GB)",
            "MoE %", "paper %",
        ],
    );
    let paper_pct = [78.03, 96.42, 98.17, 99.07];
    for (i, name) in bs::ALL_MODELS.iter().enumerate() {
        let b = bs::load(name)?;
        let topo = &b.topology;
        let cost = CostModel::paper_scale(topo.expert_param_bytes);
        let moe = topo.moe_param_bytes;
        let total = topo.total_param_bytes;
        let sim_moe = cost.sim_bytes(moe);
        let sim_total = cost.sim_bytes(total);
        t.row(vec![
            name.to_string(),
            format!("{:.1}", total as f64 / 1e6),
            format!("{:.1}", moe as f64 / 1e6),
            format!("{:.2}", sim_total as f64 / 1e9),
            format!("{:.2}", sim_moe as f64 / 1e9),
            format!("{:.2}", 100.0 * moe as f64 / total as f64),
            format!("{:.2}", paper_pct[i]),
        ]);
    }
    t.print();
    t.save_csv(&bs::csv_path("tab2_memory"))?;
    Ok(())
}
