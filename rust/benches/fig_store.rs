//! fig_store — the on-disk expert store: restart-warm serving with
//! integrity checking (DESIGN.md §2.6).
//!
//! Two phases over the same trace at a tight device budget with no
//! host-RAM window (every eviction falls to SSD, so the store carries
//! real traffic):
//!
//!  * **cold** — a fresh store directory.  Every expert is fabricated
//!    from the bundle once and written through to disk; SSD promotions
//!    miss the store (nothing is on disk yet) and count as
//!    refabrications.
//!  * **warm** — a second pipeline reopens the same directory.  The
//!    manifest pre-seeds the ledger's SSD tier, so promotions do real
//!    file reads with hash verification instead of refabricating.
//!
//! The CI gates: the warm phase must hit the store (`store_hits > 0`)
//! with **zero** refabrications and **zero** integrity failures, and its
//! classification outputs must be bit-identical to the cold phase (a
//! verified blob stages the same bytes the bundle would).  Emits
//! `BENCH_store.json` with both the modeled SSD timeline
//! (`ssd_promote_secs`) and the measured one
//! (`measured_ssd_read_secs` / `measured_ssd_write_secs`).  Hermetic:
//! synthetic testkit bundle + a TempDir store, removed on exit.

use sida_moe::bench_support as bs;
use sida_moe::coordinator::{Pipeline, PipelineConfig, ServeOutcome};
use sida_moe::metrics::Table;
use sida_moe::testkit::{self, SynthSpec, TINY_PROFILE};
use sida_moe::util::json::{num, obj, s, Json};

fn preds(out: &ServeOutcome) -> Vec<(u64, Option<usize>)> {
    let mut v: Vec<_> = out.per_request.iter().map(|r| (r.id, r.cls_pred)).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

fn main() -> anyhow::Result<()> {
    bs::banner(
        "fig_store: on-disk expert store — restart-warm serving",
        "SSD-tier experts are real files; a reopened store serves warm with \
         verified reads and no refabrication (paper §6)",
    );
    let bundle = testkit::bundle(&SynthSpec::default().two_moe_layers())?;
    let n = bs::n_requests(16);
    let requests = testkit::tiny_trace(&bundle, n, 7);
    let sim_expert = bs::sim_expert_bytes(&bundle)?;

    let dir = std::env::temp_dir().join(format!("sida_fig_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = || PipelineConfig {
        k_used: 2,
        // tight device tier + no RAM window: evictions fall straight to
        // SSD, so the store sees both writes and promotion reads
        budget_sim_bytes: 4 * sim_expert + 1024,
        ram_budget_bytes: 0,
        want_cls: true,
        // determinism: every fetch on the inference thread, one lane
        prefetch: false,
        pool_threads: 1,
        store_dir: dir.display().to_string(),
        ..Default::default()
    };

    let mut t = Table::new(
        "fig_store — cold populate vs restart-warm reopen (same trace)",
        &[
            "phase", "store hits", "refab", "bad blobs", "bytes on disk",
            "ssd promote s (modeled)", "ssd read/write s (measured)",
        ],
    );
    let mut j = bs::BenchJson::new("store");
    let mut phase_stats = Vec::new();
    for phase in ["cold", "warm"] {
        // each phase builds its pipeline from scratch: the warm one only
        // knows about the cold phase through the reopened directory
        let pipeline = Pipeline::new(bundle.clone(), TINY_PROFILE, cfg())?;
        let out = pipeline.serve(&requests)?;
        let h = out.stats.hierarchy.clone();
        t.row(vec![
            phase.into(),
            h.store_hits.to_string(),
            h.refabrications.to_string(),
            h.integrity_failures.to_string(),
            h.store_bytes_on_disk.to_string(),
            format!("{:.4}", h.ssd_promote_secs),
            format!("{:.6}/{:.6}", h.measured_ssd_read_secs, h.measured_ssd_write_secs),
        ]);
        j.push(obj(vec![
            ("phase", s(phase)),
            ("store_hits", num(h.store_hits as f64)),
            ("store_misses", num(h.store_misses as f64)),
            ("store_writes", num(h.store_writes as f64)),
            ("refabrications", num(h.refabrications as f64)),
            ("integrity_failures", num(h.integrity_failures as f64)),
            ("store_bytes_on_disk", num(h.store_bytes_on_disk as f64)),
            ("ssd_promote_secs", num(h.ssd_promote_secs)),
            ("measured_ssd_read_secs", num(h.measured_ssd_read_secs)),
            ("measured_ssd_write_secs", num(h.measured_ssd_write_secs)),
            ("promotions_from_ssd", num(h.promotions_from_ssd as f64)),
            ("requests", num(out.stats.requests as f64)),
            ("dataset", s(TINY_PROFILE)),
        ]));
        phase_stats.push((h, preds(&out)));
    }
    t.print();
    t.save_csv(&bs::csv_path("fig_store"))?;

    let (cold, cold_preds) = &phase_stats[0];
    let (warm, warm_preds) = &phase_stats[1];
    // the gates: a reopened store serves warm (real verified reads, no
    // refabrication) and changes nothing about what the model computes
    let warm_hits = warm.store_hits > 0 && warm.promotions_from_ssd > 0;
    let no_refab = warm.refabrications == 0;
    let intact = cold.integrity_failures == 0 && warm.integrity_failures == 0;
    let identical = cold_preds == warm_preds && !cold_preds.is_empty();
    println!(
        "store check: reopened store warm-hits: {}; warm refabrications == 0: {}; \
         integrity failures == 0: {}; cold/warm outputs bit-identical: {}",
        if warm_hits { "PASS" } else { "FAIL" },
        if no_refab { "PASS" } else { "FAIL" },
        if intact { "PASS" } else { "FAIL" },
        if identical { "PASS" } else { "FAIL" }
    );
    j.push(obj(vec![
        ("warm_store_hits_nonzero", Json::Bool(warm_hits)),
        ("warm_zero_refabrications", Json::Bool(no_refab)),
        ("zero_integrity_failures", Json::Bool(intact)),
        ("cold_warm_outputs_identical", Json::Bool(identical)),
    ]));
    let path = j.save()?;
    println!("perf-trajectory JSON: {}", path.display());
    let _ = std::fs::remove_dir_all(&dir);
    if !(warm_hits && no_refab && intact && identical) {
        std::process::exit(1);
    }
    Ok(())
}
