//! `sida-moe` — CLI entrypoint for the SiDA-MoE serving system.
//!
//! Subcommands:
//!   serve      run a serving trace (SiDA or a baseline) and print a report
//!   server     start the TCP line-protocol front-end
//!   inspect    show a model's topology + memory breakdown (Tab 2 row)
//!   hash       build + print a hash table for one generated sentence
//!
//! Examples:
//!   sida-moe serve --model switch128 --dataset sst2 --method sida
//!   sida-moe serve --model switch64 --dataset mrpc --method standard
//!   sida-moe server --model switch8 --addr 127.0.0.1:7700
//!   sida-moe inspect --model switch256

use std::sync::Arc;

use anyhow::Result;

use sida_moe::baselines::{run_baseline, BaselineConfig, Method};
use sida_moe::config::ServeConfig;
use sida_moe::coordinator::{replay_open_loop, HashBuilder, Pipeline, PipelineConfig};
use sida_moe::metrics::report::{fmt_bytes, fmt_secs};
use sida_moe::metrics::Table;
use sida_moe::runtime::ModelBundle;
use sida_moe::server::{run_server, ServerConfig, ServerState};
use sida_moe::util::cli::Cli;
use sida_moe::workload::{ArrivalProcess, ClassMix, Profile, TraceGenerator};

fn main() {
    sida_moe::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let tail = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let result = match sub {
        "serve" => cmd_serve(tail),
        "server" => cmd_server(tail),
        "inspect" => cmd_inspect(tail),
        "hash" => cmd_hash(tail),
        "validate" => cmd_validate(tail),
        _ => {
            eprintln!(
                "sida-moe — SiDA-MoE serving system (MLSys 2024 reproduction)\n\n\
                 subcommands:\n  serve    run a serving trace and print a report\n  \
                 server   start the TCP front-end\n  inspect  model topology + memory breakdown\n  \
                 hash     build a hash table for one sentence\n  \
                 validate check all artifacts load and shapes agree\n\n\
                 run `sida-moe <subcommand> --help` for options"
            );
            std::process::exit(if sub == "help" { 0 } else { 2 });
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Load an artifact bundle by name, or fabricate the in-memory synthetic
/// bundle when `name` is "synthetic" — the latter needs no artifacts and
/// no PJRT backend, so every subcommand works out of the box:
///
///   sida-moe serve --model synthetic --dataset tiny
fn load_bundle(artifacts_root: &std::path::Path, name: &str) -> Result<Arc<ModelBundle>> {
    if name == "synthetic" {
        return sida_moe::testkit::bundle(&sida_moe::testkit::SynthSpec::default());
    }
    Ok(Arc::new(ModelBundle::load_named(artifacts_root, name)?))
}

fn serve_cli() -> Cli {
    Cli::new("sida-moe serve", "run one serving trace")
        .opt("config", "JSON config file", "")
        .opt("model", "model config (switch8|switch64|switch128|switch256|synthetic)", "switch8")
        .opt("dataset", "dataset profile (sst2|mrpc|multirc)", "sst2")
        .opt("method", "sida|standard|deepspeed|tutel|layerwise|reactive", "sida")
        .opt("budget-gb", "simulated device budget (GB)", "8")
        .opt("policy", "eviction policy (fifo|lru|lfu|clock)", "fifo")
        .opt("ram-budget", "host-RAM tier budget (GB); evictions demote here", "64")
        .opt("ram-policy", "RAM-tier eviction policy (fifo|lru|lfu|clock)", "fifo")
        .opt("store-dir", "on-disk expert store dir (reopen to serve restart-warm)", "")
        .opt("ssd-budget", "on-disk store budget (GB, 0 = unbounded)", "0")
        .opt("k-used", "hash experts per token (0 = paper default)", "0")
        .opt("batch", "requests per forward pass (1 = paper batch-1; >1 batches cross-request)", "1")
        .opt("prefetch-depth", "MoE layers the warmer may stage ahead (1 = baseline)", "3")
        .opt("host-bw", "modeled host staging bandwidth (bytes/s, 0 = reference PCIe)", "0")
        .opt("pool", "worker threads for expert execution (0 = auto, 1 = sequential)", "0")
        .opt("devices", "modeled devices for expert parallelism (budget is per device)", "1")
        .opt("replicate-top", "hottest experts per MoE layer replicated across devices", "1")
        .opt("min-replicas", "availability floor: holders per predicted-hot expert", "1")
        .opt("fault-plan", "fault schedule, e.g. down:1@8..24,degrade:2@4..9x3", "")
        .opt("arrivals", "arrival process (closed|poisson|bursty|diurnal)", "closed")
        .opt("rate", "mean offered rate for open-loop arrivals (req/s)", "50")
        .opt("interactive-frac", "fraction of requests on the interactive SLO lane", "0")
        .opt("slo-deadline", "interactive completion deadline (ms)", "100")
        .opt("queue-cap", "open-loop admission queue bound", "256")
        .opt("requests", "number of requests", "32")
        .opt("seed", "workload seed", "0")
        .opt("artifacts", "artifacts root", "")
        .opt("trace-out", "write a Chrome trace-event JSON of the run (load in Perfetto)", "")
        .opt("metrics-interval", "periodic metrics snapshot to stderr (seconds, 0 = off)", "0")
        .flag("real-sleep", "sleep modeled transfer time on the critical path")
        .flag("no-prefetch", "disable the SiDA prefetch stage")
        .flag("lm", "also compute LM NLL per request")
}

fn load_serve_config(tail: &[String]) -> Result<ServeConfig> {
    let args = serve_cli().parse_tail(tail);
    let mut cfg = match args.get("config") {
        Some("") | None => ServeConfig::default(),
        Some(path) => ServeConfig::load(std::path::Path::new(path))?,
    };
    cfg.apply_args(&args);
    if args.get("k-used") == Some("0") {
        cfg.k_used = ServeConfig::paper_k_for(&cfg.dataset);
    }
    if cfg.artifacts.is_empty() || cfg.artifacts == "artifacts" {
        cfg.artifacts = sida_moe::default_artifacts_root().display().to_string();
    }
    Ok(cfg)
}

/// Workload profile by name, including the synthetic bundle's `tiny`.
fn profile_named(name: &str) -> Result<Profile> {
    if name == sida_moe::testkit::TINY_PROFILE {
        return Ok(sida_moe::testkit::tiny_profile());
    }
    Profile::named(name)
}

/// Periodic metrics reporter: publish the pipeline's live counters into
/// the global registry and print a one-line snapshot to stderr every
/// `interval_secs`.  Polls a stop flag at 50ms so shutdown is prompt.
fn spawn_metrics_reporter(
    pipeline: &Arc<Pipeline>,
    stop: &Arc<std::sync::atomic::AtomicBool>,
    interval_secs: f64,
) -> Option<std::thread::JoinHandle<()>> {
    if interval_secs <= 0.0 {
        return None;
    }
    let pipeline = Arc::clone(pipeline);
    let stop = Arc::clone(stop);
    Some(std::thread::spawn(move || {
        let reg = sida_moe::obs::Registry::global();
        let tick = std::time::Duration::from_millis(50);
        let mut elapsed = 0.0;
        while !stop.load(std::sync::atomic::Ordering::Relaxed) {
            std::thread::sleep(tick);
            elapsed += tick.as_secs_f64();
            if elapsed + 1e-9 >= interval_secs {
                elapsed = 0.0;
                pipeline.publish_live_metrics(reg);
                eprintln!("{}", sida_moe::obs::publish::snapshot_line(reg));
            }
        }
    }))
}

fn cmd_serve(tail: &[String]) -> Result<()> {
    let cfg = load_serve_config(tail)?;
    if !cfg.trace_out.is_empty() {
        sida_moe::obs::trace::enable(sida_moe::obs::trace::DEFAULT_CAPACITY);
    }
    let bundle = load_bundle(std::path::Path::new(&cfg.artifacts), &cfg.model)?;
    let profile = profile_named(&cfg.dataset)?;
    let mut gen = TraceGenerator::new(profile, bundle.topology.vocab, cfg.seed);
    let arrivals = ArrivalProcess::parse(&cfg.arrivals, cfg.arrival_rate)?;
    let open_loop = !matches!(arrivals, ArrivalProcess::ClosedLoop);
    let mix = ClassMix {
        interactive_frac: cfg.interactive_frac,
        deadline_secs: cfg.slo_deadline_ms / 1e3,
    };
    let requests = gen.trace_classed(cfg.n_requests, arrivals, mix);
    let method = Method::parse(&cfg.method)?;

    println!(
        "serving {} x {} with {} ({} requests, budget {}, arrivals {})",
        cfg.model,
        cfg.dataset,
        cfg.method,
        cfg.n_requests,
        fmt_bytes(cfg.budget_bytes()),
        cfg.arrivals,
    );
    let outcome = match method {
        Method::Sida => {
            let pcfg = PipelineConfig {
                k_used: cfg.k_used,
                budget_sim_bytes: cfg.budget_bytes(),
                policy: cfg.policy.clone(),
                ram_budget_bytes: cfg.ram_budget_bytes(),
                ram_policy: cfg.ram_policy.clone(),
                store_dir: cfg.store_dir.clone(),
                ssd_budget_bytes: cfg.ssd_budget_bytes(),
                real_sleep: cfg.real_sleep,
                prefetch: cfg.prefetch,
                prefetch_depth: cfg.prefetch_depth,
                host_bw: cfg.host_bw,
                queue_depth: 8,
                max_batch: cfg.max_batch,
                pool_threads: cfg.pool_threads,
                devices: cfg.devices,
                replicate_top: cfg.replicate_top,
                min_replicas: cfg.min_replicas,
                fault_plan: cfg.fault_plan.clone(),
                want_lm: cfg.want_lm,
                want_cls: cfg.want_cls,
            };
            let pipeline = Arc::new(Pipeline::new(bundle, &cfg.dataset, pcfg)?);
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let reporter =
                spawn_metrics_reporter(&pipeline, &stop, cfg.metrics_interval_secs);
            let outcome = if open_loop {
                let report = replay_open_loop(&pipeline, &requests, cfg.queue_cap)?;
                println!(
                    "open-loop: mean queueing {:.2} ms | rejected {} (capacity) + {} (slo) | shed {}",
                    report.mean_queueing_secs * 1e3,
                    report.rejected,
                    report.rejected_slo,
                    report.shed,
                );
                report.outcome
            } else {
                pipeline.serve(&requests)?
            };
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            if let Some(h) = reporter {
                let _ = h.join();
            }
            outcome
        }
        m => {
            anyhow::ensure!(
                !open_loop,
                "open-loop arrivals ('{}') are only supported with --method sida",
                cfg.arrivals
            );
            let bcfg = BaselineConfig {
                budget_sim_bytes: cfg.budget_bytes(),
                ram_budget_sim_bytes: cfg.ram_budget_bytes(),
                ram_policy: cfg.ram_policy.clone(),
                real_sleep: cfg.real_sleep,
                want_lm: cfg.want_lm,
                want_cls: cfg.want_cls,
            };
            run_baseline(bundle, &cfg.dataset, m, &requests, &bcfg)?
        }
    };

    let mut stats = outcome.stats;
    let mut t = Table::new(
        "serve report",
        &["metric", "value"],
    );
    t.row(vec!["requests".into(), stats.requests.to_string()]);
    if stats.batches > 0 {
        // only the sida pipeline tracks forward-pass batching; baselines
        // would misleadingly report 0
        t.row(vec![
            "batches".into(),
            format!(
                "{} (mean size {:.1})",
                stats.batches,
                stats.mean_batch_size().unwrap_or(0.0)
            ),
        ]);
    }
    t.row(vec!["wall".into(), fmt_secs(stats.wall_secs)]);
    t.row(vec![
        "throughput".into(),
        format!("{:.2} req/s", stats.throughput()),
    ]);
    t.row(vec!["latency p50".into(), fmt_secs(stats.latency.p50())]);
    t.row(vec!["latency p95".into(), fmt_secs(stats.latency.p95())]);
    t.row(vec!["latency p99".into(), fmt_secs(stats.latency.p99())]);
    t.row(vec!["latency p99.9".into(), fmt_secs(stats.latency.p999())]);
    if !stats.latency_interactive.is_empty() {
        t.row(vec![
            "interactive p50/p99/p99.9".into(),
            format!(
                "{} / {} / {}",
                fmt_secs(stats.latency_interactive.p50()),
                fmt_secs(stats.latency_interactive.p99()),
                fmt_secs(stats.latency_interactive.p999())
            ),
        ]);
    }
    if !stats.latency_batch.is_empty() && !stats.latency_interactive.is_empty() {
        t.row(vec![
            "batch-lane p50/p99/p99.9".into(),
            format!(
                "{} / {} / {}",
                fmt_secs(stats.latency_batch.p50()),
                fmt_secs(stats.latency_batch.p99()),
                fmt_secs(stats.latency_batch.p999())
            ),
        ]);
    }
    if stats.shed + stats.rejected + stats.rejected_slo > 0 {
        t.row(vec![
            "shed / rejected".into(),
            format!(
                "{} shed | {} capacity | {} slo",
                stats.shed, stats.rejected, stats.rejected_slo
            ),
        ]);
    }
    if let Some(att) = stats.slo_attainment() {
        t.row(vec!["slo attainment".into(), format!("{:.1}%", 100.0 * att)]);
    }
    t.row(vec![
        "expert invocations".into(),
        stats.phases.expert_invocations.to_string(),
    ]);
    t.row(vec![
        "moe overhead".into(),
        format!(
            "{:.1}%",
            100.0 * stats.phases.moe_overhead() / stats.phases.total().max(1e-12)
        ),
    ]);
    t.row(vec!["peak device".into(), fmt_bytes(stats.peak_device_bytes)]);
    t.row(vec![
        "cache hit rate".into(),
        sida_moe::metrics::report::fmt_rate(stats.hit_rate()),
    ]);
    let h = &stats.hierarchy;
    t.row(vec![
        "tier ladder".into(),
        format!(
            "ram {} | ssd {} | demote {}/{}",
            fmt_bytes(h.ram_bytes),
            fmt_bytes(h.ssd_bytes),
            h.demotions_to_ram,
            h.demotions_to_ssd
        ),
    ]);
    t.row(vec![
        "ladder secs".into(),
        format!(
            "{} (ram {} + ssd {})",
            fmt_secs(h.ladder_secs()),
            fmt_secs(h.ram_promote_secs),
            fmt_secs(h.ssd_promote_secs)
        ),
    ]);
    if h.store_hits + h.store_misses + h.store_writes > 0 || h.store_bytes_on_disk > 0 {
        t.row(vec![
            "on-disk store".into(),
            format!(
                "{} on disk | {} hits | {} refab | {} bad",
                fmt_bytes(h.store_bytes_on_disk),
                h.store_hits,
                h.refabrications,
                h.integrity_failures
            ),
        ]);
        t.row(vec![
            "measured ssd secs".into(),
            format!(
                "read {} | write {}",
                fmt_secs(h.measured_ssd_read_secs),
                fmt_secs(h.measured_ssd_write_secs)
            ),
        ]);
    }
    t.print();

    if let Some(cluster) = &stats.cluster {
        let mut ct = Table::new(
            "cluster report (per device)",
            &["device", "assigned experts", "peak mem", "rows", "hit rate"],
        );
        for d in &cluster.devices {
            ct.row(vec![
                d.device.to_string(),
                d.assigned_experts.to_string(),
                fmt_bytes(d.peak_bytes),
                d.rows.to_string(),
                sida_moe::metrics::report::fmt_rate(d.cache.hit_rate()),
            ]);
        }
        ct.row(vec![
            "imbalance".into(),
            format!("{:.2}x", cluster.load_imbalance().unwrap_or(1.0)),
            format!("x-dev {}", fmt_bytes(cluster.cross_device_bytes as usize)),
            format!("{:.3}s link", cluster.interconnect_secs),
            format!("{} replicas", cluster.replicated_entries),
        ]);
        if cluster.device_failures + cluster.failovers + cluster.dropped_fetches > 0 {
            ct.row(vec![
                "faults".into(),
                format!(
                    "{} down / {} back",
                    cluster.device_failures, cluster.recoveries
                ),
                format!(
                    "{} failover ({} promoted)",
                    cluster.failovers, cluster.failover_promotions
                ),
                format!("{} retries", cluster.retries),
                format!("{:.3}s downtime", cluster.downtime_secs),
            ]);
        }
        ct.print();
    }

    // final registry publish: the serve report above and a `cmd:metrics`
    // style exposition now read from the same snapshot
    let reg = sida_moe::obs::Registry::global();
    sida_moe::obs::publish::publish_serve_stats(reg, &stats);
    sida_moe::obs::publish::publish_trace_health(reg);
    if cfg.metrics_interval_secs > 0.0 {
        eprintln!("{}", sida_moe::obs::publish::snapshot_line(reg));
    }
    if !cfg.trace_out.is_empty() {
        sida_moe::obs::trace::write_to(&cfg.trace_out)?;
        println!(
            "trace: {} events ({} dropped) -> {} (open in Perfetto / chrome://tracing)",
            sida_moe::obs::trace::len(),
            sida_moe::obs::trace::dropped(),
            cfg.trace_out
        );
    }
    Ok(())
}

fn cmd_server(tail: &[String]) -> Result<()> {
    let cli = Cli::new("sida-moe server", "TCP line-protocol front-end")
        .opt("model", "model config", "switch8")
        .opt("dataset", "dataset profile (fixes seq len)", "sst2")
        .opt("budget-gb", "simulated device budget (GB)", "8")
        .opt("ram-budget", "modeled host-RAM tier budget (GB)", "64")
        .opt("ram-policy", "RAM-tier eviction policy (fifo|lru|lfu|clock)", "fifo")
        .opt("store-dir", "on-disk expert store dir (reopen to serve restart-warm)", "")
        .opt("ssd-budget", "on-disk store budget (GB, 0 = unbounded)", "0")
        .opt("batch", "max requests coalesced per forward pass", "8")
        .opt("prefetch-depth", "MoE layers the warmer may stage ahead (1 = baseline)", "3")
        .opt("host-bw", "modeled host staging bandwidth (bytes/s, 0 = reference PCIe)", "0")
        .opt("pool", "worker threads for expert execution (0 = auto)", "0")
        .opt("batch-delay-ms", "max time a request waits for its batch to fill", "5")
        .opt("queue-cap", "admission queue bound (overflow is rejected)", "256")
        .opt("devices", "modeled devices for expert parallelism (budget is per device)", "1")
        .opt("replicate-top", "hottest experts per MoE layer replicated across devices", "1")
        .opt("min-replicas", "availability floor: holders per predicted-hot expert", "1")
        .opt("fault-plan", "fault schedule, e.g. down:1@8..24,degrade:2@4..9x3", "")
        .opt("slo-deadline", "default interactive completion deadline (ms)", "100")
        .opt("conn-timeout", "socket read/write timeout (seconds, 0 = none)", "0")
        .opt("addr", "listen address", "127.0.0.1:7700")
        .opt("artifacts", "artifacts root", "")
        .opt("trace-out", "write a Chrome trace-event JSON on shutdown (load in Perfetto)", "")
        .opt("metrics-interval", "periodic metrics snapshot to stderr (seconds, 0 = off)", "0");
    let args = cli.parse_tail(tail);
    let root = match args.get("artifacts") {
        Some("") | None => sida_moe::default_artifacts_root(),
        Some(p) => p.into(),
    };
    let bundle = load_bundle(&root, &args.get_or("model", "switch8"))?;
    let k = ServeConfig::paper_k_for(args.get("dataset").unwrap_or("sst2"));
    let scfg = ServerConfig {
        budget_sim_bytes: (args.get_f64("budget-gb", 8.0) * 1e9) as usize,
        ram_budget_sim_bytes: (args.get_f64("ram-budget", 64.0) * 1e9) as usize,
        ram_policy: args.get_or("ram-policy", "fifo"),
        store_dir: args.get_or("store-dir", ""),
        ssd_budget_bytes: (args.get_f64("ssd-budget", 0.0) * 1e9) as usize,
        k_used: k,
        batch: sida_moe::coordinator::BatchPolicy {
            max_batch: args.get_usize("batch", 8).max(1),
            max_delay_secs: args.get_f64("batch-delay-ms", 5.0) / 1e3,
            capacity: args.get_usize("queue-cap", 256).max(1),
            ..Default::default()
        },
        prefetch_depth: args.get_usize("prefetch-depth", 3).max(1),
        host_bw: args.get_f64("host-bw", 0.0).max(0.0),
        pool_threads: args.get_usize("pool", 0),
        devices: args.get_usize("devices", 1).max(1),
        replicate_top: args.get_usize("replicate-top", 1),
        min_replicas: args.get_usize("min-replicas", 1).max(1),
        fault_plan: args.get_or("fault-plan", ""),
        default_deadline_secs: args.get_f64("slo-deadline", 100.0) / 1e3,
        conn_timeout_secs: args.get_f64("conn-timeout", 0.0).max(0.0),
        trace_out: args.get_or("trace-out", ""),
        metrics_interval_secs: args.get_f64("metrics-interval", 0.0).max(0.0),
    };
    if !scfg.trace_out.is_empty() {
        sida_moe::obs::trace::enable(sida_moe::obs::trace::DEFAULT_CAPACITY);
    }
    let state = Arc::new(ServerState::new(
        bundle,
        args.get("dataset").unwrap_or("sst2"),
        scfg,
    )?);
    run_server(state, args.get("addr").unwrap_or("127.0.0.1:7700"))
}

fn cmd_inspect(tail: &[String]) -> Result<()> {
    let cli = Cli::new("sida-moe inspect", "model topology + memory breakdown")
        .opt("model", "model config", "switch8")
        .opt("artifacts", "artifacts root", "");
    let args = cli.parse_tail(tail);
    let root = match args.get("artifacts") {
        Some("") | None => sida_moe::default_artifacts_root(),
        Some(p) => p.into(),
    };
    let bundle = load_bundle(&root, &args.get_or("model", "switch8"))?;
    let topo = &bundle.topology;
    println!("model {}", topo.name);
    println!("  vocab={} d_model={} d_ff={} heads={}", topo.vocab, topo.d_model, topo.d_ff, topo.n_heads);
    println!("  blocks={} moe_blocks={:?} experts/layer={}", topo.n_blocks, topo.moe_blocks, topo.num_experts);
    println!("  hash: hidden={} lstm_layers={} top_k={}", topo.hash.hidden, topo.hash.n_lstm_layers, topo.hash.top_k);
    let moe = topo.moe_param_bytes;
    let total = topo.total_param_bytes;
    println!(
        "  params: total {} | MoE {} ({:.2}%)",
        fmt_bytes(total),
        fmt_bytes(moe),
        100.0 * moe as f64 / total as f64
    );
    println!("  profiles: {:?}", topo.profiles);
    println!("  expert buckets: {:?}", topo.buckets);
    println!("  engine platform: {}", bundle.engine.platform());
    Ok(())
}

fn cmd_hash(tail: &[String]) -> Result<()> {
    let cli = Cli::new("sida-moe hash", "build a hash table for one sentence")
        .opt("model", "model config", "switch8")
        .opt("dataset", "dataset profile", "sst2")
        .opt("seed", "sentence seed", "0")
        .opt("artifacts", "artifacts root", "");
    let args = cli.parse_tail(tail);
    let root = match args.get("artifacts") {
        Some("") | None => sida_moe::default_artifacts_root(),
        Some(p) => p.into(),
    };
    let bundle = load_bundle(&root, &args.get_or("model", "switch8"))?;
    let dataset = args.get_or("dataset", "sst2");
    let profile = profile_named(&dataset)?;
    let mut gen = TraceGenerator::new(profile, bundle.topology.vocab, args.get_u64("seed", 0));
    let (ids, n_tokens, topic) = gen.sentence();
    let builder = HashBuilder::new(&bundle, &dataset)?;
    let table = builder.build(0, &ids)?;
    println!(
        "sentence: {n_tokens} tokens, topic {topic}; hash built in {:.3}ms",
        table.build_secs * 1e3
    );
    let mask = sida_moe::workload::pad_mask(&ids);
    for layer in 0..table.m {
        let active = table.predicted_experts(layer, 1, &mask);
        println!(
            "  MoE layer {layer}: {} / {} experts predicted active (idle {:.0}%) -> {:?}",
            active.len(),
            bundle.topology.num_experts,
            100.0 * table.idle_ratio(layer, bundle.topology.num_experts, &mask),
            &active[..active.len().min(16)]
        );
    }
    Ok(())
}

fn cmd_validate(tail: &[String]) -> Result<()> {
    let cli = Cli::new("sida-moe validate", "load every artifact, cross-check shapes")
        .opt("model", "model config or 'all'", "all")
        .opt("artifacts", "artifacts root", "");
    let args = cli.parse_tail(tail);
    let root = match args.get("artifacts") {
        Some("") | None => sida_moe::default_artifacts_root(),
        Some(p) => p.into(),
    };
    let models: Vec<String> = match args.get("model") {
        Some("all") | None => ["switch8", "switch64", "switch128", "switch256"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        Some(m) => vec![m.to_string()],
    };
    for name in models {
        let dir = root.join(&name);
        if !dir.join("model.json").is_file() {
            println!("{name}: MISSING (run `make artifacts`)");
            continue;
        }
        let bundle = ModelBundle::load_named(&root, &name)?;
        let topo = &bundle.topology;
        // compile every entry
        let mut entries: Vec<String> = Vec::new();
        for (_, &l) in &topo.profiles {
            for e in [
                "embed", "attn", "dense_ffn", "moe_ln", "router", "moe_combine",
                "lm_head", "cls_head", "lm_nll", "hash",
            ] {
                entries.push(format!("{e}_L{l}"));
            }
        }
        for &b in &topo.buckets {
            entries.push(format!("expert_T{b}"));
        }
        bundle.engine.preload(&entries)?;
        // weights: every expert addressable with consistent bytes
        for &blk in &topo.moe_blocks {
            for e in 0..topo.num_experts {
                let bytes = bundle.weights.expert_bytes(blk, e)?;
                anyhow::ensure!(
                    bytes == topo.expert_param_bytes,
                    "{name}: expert ({blk},{e}) bytes {bytes} != {}",
                    topo.expert_param_bytes
                );
            }
        }
        // hash weights match the topology's hidden size
        let h = topo.hash.hidden;
        let m = bundle.weights.meta("hash.lstm.0.wx")?;
        anyhow::ensure!(
            m.shape == vec![h, 4 * h],
            "{name}: hash lstm shape {:?} != [{h}, {}]",
            m.shape,
            4 * h
        );
        println!(
            "{name}: OK — {} entries compiled, {} experts x {} layers verified",
            entries.len(),
            topo.num_experts,
            topo.num_moe_layers()
        );
    }
    Ok(())
}
