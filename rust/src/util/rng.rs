//! Deterministic PRNG substrate (no `rand` crate in the vendored set).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! pairing; passes BigCrush in its reference form.  Used by workload
//! generators, the property-testing harness (`util::prop`) and any
//! stochastic policy ablation so every run is reproducible from a seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-thread / per-request rngs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with the given rate (Poisson inter-arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    /// Zipf-distributed rank in [0, n) with exponent `a` (inverse-CDF on a
    /// precomputed table is overkill at our n; rejection-free cumulative
    /// scan is fine for n <= a few hundred).
    pub fn zipf(&mut self, n: usize, a: f64) -> usize {
        debug_assert!(n > 0);
        let mut norm = 0.0;
        for k in 1..=n {
            norm += (k as f64).powf(-a);
        }
        let mut u = self.f64() * norm;
        for k in 1..=n {
            u -= (k as f64).powf(-a);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.3)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[9]);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        for _ in 0..100 {
            let s = r.sample_indices(20, 8);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(9);
        let mut b = a.fork(1);
        let mut c = a.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
