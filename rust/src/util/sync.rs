//! Synchronization primitives for the layer-ahead prefetch overlap
//! (coordinator pipeline ↔ model forward).
//!
//! [`LayerGate`] coordinates two threads working through the MoE layers
//! of one forward pass:
//!
//! * the **warmer** stages the predicted expert sets of the next
//!   *depth window* of layers (*j+1* .. *j+depth*, each fetch carrying
//!   a need-time deadline and a tier-derived lead — see
//!   `experts::bandwidth`) while the compute thread is busy with layer
//!   *j* (the paper's "dynamical loading ... following the pipeline
//!   parallelism mechanism", §3.1, refined from request granularity to
//!   layer granularity; `--prefetch-depth 1` is the classic
//!   one-layer-ahead baseline), and
//! * the **compute** thread gates each MoE layer on that layer's
//!   warm-up having finished, so every expert fetch happens on the
//!   prefetch timeline (non-blocking, overlapped) and cache hit/miss
//!   accounting stays deterministic — no racy blocking misses.
//!
//! Both sides publish progress under one mutex + condvar; either side
//! finishing (or dying) releases the other, so an error on one thread
//! can never deadlock the pair.

use std::sync::{Condvar, Mutex};
use std::time::Instant;

#[derive(Default)]
struct GateState {
    /// MoE layers fully warmed, as a prefix count (`warmed == j+1`
    /// means layers `0..=j` are staged)
    warmed: usize,
    /// MoE layer the compute thread has entered (None before the first)
    computing: Option<usize>,
    compute_done: bool,
    warm_done: bool,
}

/// See the module docs.  One gate instance serves one forward pass.
pub struct LayerGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

impl LayerGate {
    pub fn new() -> Self {
        LayerGate { state: Mutex::new(GateState::default()), cv: Condvar::new() }
    }

    /// Compute side: announce entry into MoE layer `layer` and wait
    /// until the warmer has staged it (or gave up).  Returns the
    /// seconds spent waiting — exposed warm-up stall on the critical
    /// path, charged to the transfer phase by the caller.
    pub fn begin_layer(&self, layer: usize) -> f64 {
        let t0 = Instant::now();
        let mut st = self.state.lock().unwrap();
        st.computing = Some(layer);
        self.cv.notify_all();
        while st.warmed <= layer && !st.warm_done {
            st = self.cv.wait(st).unwrap();
        }
        t0.elapsed().as_secs_f64()
    }

    /// Compute side: the forward pass ended (success or error).
    /// Releases a warmer waiting for compute progress.
    pub fn finish_compute(&self) {
        self.state.lock().unwrap().compute_done = true;
        self.cv.notify_all();
    }

    /// Warmer side: wait until compute has entered MoE layer >= `layer`.
    /// Returns `false` when the forward pass already finished (the
    /// warmer should stop).
    pub fn wait_compute_at_least(&self, layer: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.computing.map_or(false, |c| c >= layer) {
                return true;
            }
            if st.compute_done {
                return false;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Warmer side: layer `layer` is staged.
    pub fn mark_warmed(&self, layer: usize) {
        let mut st = self.state.lock().unwrap();
        st.warmed = st.warmed.max(layer + 1);
        self.cv.notify_all();
    }

    /// Warmer side: the warmer exited (all layers done, compute done,
    /// or an error).  Releases any compute wait — compute then fetches
    /// its experts blocking, which is slower but always correct.
    pub fn finish_warm(&self) {
        self.state.lock().unwrap().warm_done = true;
        self.cv.notify_all();
    }
}

impl Default for LayerGate {
    fn default() -> Self {
        LayerGate::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_sequences_warm_before_compute() {
        let gate = LayerGate::new();
        let order = Mutex::new(Vec::<String>::new());
        std::thread::scope(|s| {
            s.spawn(|| {
                // warmer: layer 0 immediately, layer 1 only once compute
                // has entered layer 0
                order.lock().unwrap().push("warm0".into());
                gate.mark_warmed(0);
                assert!(gate.wait_compute_at_least(0));
                order.lock().unwrap().push("warm1".into());
                gate.mark_warmed(1);
                gate.finish_warm();
            });
            let _ = gate.begin_layer(0);
            order.lock().unwrap().push("compute0".into());
            let _ = gate.begin_layer(1);
            order.lock().unwrap().push("compute1".into());
            gate.finish_compute();
        });
        let order = order.into_inner().unwrap();
        let pos = |tag: &str| order.iter().position(|x| x == tag).unwrap();
        assert!(pos("warm0") < pos("compute0"));
        assert!(pos("warm1") < pos("compute1"));
    }

    #[test]
    fn finished_warmer_releases_compute() {
        let gate = LayerGate::new();
        gate.finish_warm();
        // no layer ever warmed, but compute must not hang
        let waited = gate.begin_layer(3);
        assert!(waited >= 0.0);
    }

    #[test]
    fn finished_compute_releases_warmer() {
        let gate = LayerGate::new();
        gate.finish_compute();
        assert!(!gate.wait_compute_at_least(0));
    }
}
