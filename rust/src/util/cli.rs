//! Minimal CLI argument parser (no `clap` in the vendored crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with generated usage text.  Only what the `sida-moe`
//! binary, examples and bench harnesses need.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Cli { name, about, specs: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let kind = if spec.is_flag { "" } else { " <value>" };
            let def = match spec.default {
                Some(d) => format!(" (default: {d})"),
                None if spec.is_flag => String::new(),
                None => " (required)".to_string(),
            };
            s.push_str(&format!("  --{}{kind}\n      {}{def}\n", spec.name, spec.help));
        }
        s
    }

    /// Parse from an iterator of args (excluding argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    out.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{key} needs a value"))?,
                    };
                    out.values.insert(key, val);
                }
            } else {
                out.positional.push(arg);
            }
        }
        // apply defaults, check required
        for spec in &self.specs {
            if spec.is_flag {
                continue;
            }
            if !out.values.contains_key(spec.name) {
                match spec.default {
                    Some(d) => {
                        out.values.insert(spec.name.to_string(), d.to_string());
                    }
                    None => return Err(format!("missing required option --{}", spec.name)),
                }
            }
        }
        Ok(out)
    }

    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    /// Parse args that appear after a subcommand name.
    pub fn parse_tail(&self, tail: &[String]) -> Args {
        match self.parse_from(tail.iter().cloned()) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("test", "t")
            .opt("model", "model name", "switch8")
            .req("dataset", "dataset name")
            .flag("verbose", "verbosity")
    }

    fn v(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = cli().parse_from(v(&["--dataset", "sst2"])).unwrap();
        assert_eq!(a.get("model"), Some("switch8"));
        assert_eq!(a.get("dataset"), Some("sst2"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cli()
            .parse_from(v(&["--dataset=mrpc", "--model=switch256", "--verbose"]))
            .unwrap();
        assert_eq!(a.get("model"), Some("switch256"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cli().parse_from(v(&[])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse_from(v(&["--dataset", "x", "--nope", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cli().parse_from(v(&["serve", "--dataset", "x"])).unwrap();
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn numeric_getters() {
        let c = Cli::new("n", "n").opt("steps", "s", "10").opt("rate", "r", "1.5");
        let a = c.parse_from(v(&["--steps", "32"])).unwrap();
        assert_eq!(a.get_usize("steps", 0), 32);
        assert!((a.get_f64("rate", 0.0) - 1.5).abs() < 1e-9);
    }
}
