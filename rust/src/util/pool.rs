//! Hand-rolled scoped worker pool (the vendored crate set has no rayon
//! or crossbeam — DESIGN.md §5).
//!
//! [`WorkerPool::run`] executes one closure per item on up to
//! `threads` OS threads and returns the results **in item order**:
//! compute finishes in whatever order the scheduler produces, but the
//! caller always observes a deterministic, index-ordered result vector.
//! That order guarantee is what lets `model::forward` fan the
//! per-expert invocations of an MoE layer out across threads while
//! keeping its scatter-accumulation order — and therefore its f32
//! outputs — bit-identical to the sequential path.
//!
//! Built on [`std::thread::scope`], so job closures may borrow from the
//! caller's stack (weight maps, activation buffers) without cloning or
//! `Arc`-wrapping; a pool of size 1 (or a single item) degenerates to
//! an inline sequential loop with zero spawn overhead, which doubles as
//! the reference execution order in tests.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A fixed-width scoped worker pool.  Cheap to clone (it holds only its
/// width); threads are spawned per [`WorkerPool::run`] call and joined
/// before it returns, so no state leaks between calls.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// Pool of exactly `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    /// Width from the environment: `SIDA_POOL_THREADS` if set to a
    /// positive width, else the machine's available parallelism (capped
    /// at 16 — expert fan-out per layer rarely benefits beyond that).
    /// `SIDA_POOL_THREADS=0` means auto, matching every other pool knob.
    pub fn auto() -> Self {
        if let Ok(v) = std::env::var("SIDA_POOL_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return WorkerPool::new(n);
                }
            }
        }
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        WorkerPool::new(n.min(16))
    }

    /// `0` means auto-size — the convention config knobs use.
    pub fn from_config(threads: usize) -> Self {
        if threads == 0 {
            WorkerPool::auto()
        } else {
            WorkerPool::new(threads)
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` once per item, up to `threads` at a time, and return the
    /// results **in item order**.  `f` receives `(index, item)`.
    ///
    /// With one worker (or one item) this runs inline on the calling
    /// thread — no spawn, identical to a plain sequential loop.
    pub fn run<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = items.len();
        let workers = self.threads.min(n);
        if workers <= 1 {
            return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
        }
        // Claimable work items and index-addressed result slots: workers
        // race on `cursor`, but every result lands in its item's slot,
        // so completion order never leaks into the returned Vec.
        let work: Vec<Mutex<Option<T>>> =
            items.into_iter().map(|it| Mutex::new(Some(it))).collect();
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = work[i].lock().unwrap().take().expect("item claimed twice");
                    let out = f(i, item);
                    *slots[i].lock().unwrap() = Some(out);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker left an empty result slot"))
            .collect()
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_yields_empty_output() {
        let pool = WorkerPool::new(4);
        let out: Vec<u32> = pool.run(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn results_are_in_item_order_regardless_of_completion_order() {
        // later items finish first (larger sleep on early indices); the
        // output must still be index-ordered
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = (0..16).collect();
        let out = pool.run(items, |i, x| {
            assert_eq!(i, x);
            std::thread::sleep(std::time::Duration::from_micros(((16 - i) * 200) as u64));
            x * 10
        });
        assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_matches_parallel_pool() {
        let items: Vec<u64> = (0..32).collect();
        let seq = WorkerPool::new(1).run(items.clone(), |i, x| x.wrapping_mul(31) ^ i as u64);
        let par = WorkerPool::new(8).run(items, |i, x| x.wrapping_mul(31) ^ i as u64);
        assert_eq!(seq, par);
    }

    #[test]
    fn closures_may_borrow_caller_stack() {
        let weights: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let pool = WorkerPool::new(3);
        let out = pool.run((0..8).collect::<Vec<usize>>(), |_, i| weights[i] * 2.0);
        assert_eq!(out[7], 14.0);
    }

    #[test]
    fn from_config_zero_is_auto() {
        assert!(WorkerPool::from_config(0).threads() >= 1);
        assert_eq!(WorkerPool::from_config(3).threads(), 3);
        assert_eq!(WorkerPool::new(0).threads(), 1);
    }
}
