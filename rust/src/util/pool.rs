//! Hand-rolled persistent worker pool (the vendored crate set has no
//! rayon or crossbeam — DESIGN.md §5).
//!
//! [`WorkerPool::run`] executes one closure per item on up to
//! `threads` OS threads and returns the results **in item order**:
//! compute finishes in whatever order the scheduler produces, but the
//! caller always observes a deterministic, index-ordered result vector.
//! That order guarantee is what lets `model::forward` fan the
//! per-expert invocations of an MoE layer out across threads while
//! keeping its scatter-accumulation order — and therefore its f32
//! outputs — bit-identical to the sequential path.
//!
//! Workers are spawned **once** (lazily, on the first parallel `run`)
//! and live as long as the pool: per-layer jobs stop paying the
//! ~10-30us-per-thread spawn/join cost the previous scoped
//! implementation charged on every call, and thread-local state (the
//! `testkit::kernels` scratch arenas) stays warm across forwards, so
//! the allocation-free steady state holds on pool threads too.
//!
//! Job closures may still borrow from the caller's stack (weight maps,
//! activation buffers) without cloning or `Arc`-wrapping: `run` blocks
//! until every item completed before returning, so the borrow never
//! outlives the frame that owns the data — the same guarantee
//! `std::thread::scope` gives, enforced here by a completion count the
//! caller waits on (see the safety notes on the internal `TaskRef`).
//! A pool of
//! size 1 (or a single item) degenerates to an inline sequential loop
//! with zero handoff overhead, which doubles as the reference execution
//! order in tests.  Concurrent `run` calls on one pool (e.g. the TCP
//! server's direct `serve_one` API racing the batch worker) do not
//! queue: the loser of the handoff lock simply runs its items inline,
//! preserving liveness and determinism.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Type-erased reference to the per-call task body, shipped to the
/// persistent workers as a raw fat pointer.
///
/// # Safety
///
/// The pointee lives on the stack of the `run` call that published it.
/// Workers may dereference it only while claiming item indices below
/// the job's `n`; `run` does not return (and the frame does not die)
/// until `done == n`, and every claim of an index `< n` strictly
/// precedes that index's `done` increment — so no dereference can
/// outlive the frame.  Workers that wake late observe `cursor >= n`
/// and never touch the pointer.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize) + Sync));

unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

/// One published batch of work: workers claim indices off `cursor`,
/// run the type-erased task on each, and count completions in `done`.
struct Job {
    task: TaskRef,
    n: usize,
    cursor: AtomicUsize,
    done: AtomicUsize,
    /// monotone id so a worker never re-enters a job it already drained
    epoch: u64,
}

#[derive(Default)]
struct PoolState {
    job: Option<Arc<Job>>,
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// workers sleep here between jobs
    work_cv: Condvar,
    /// the `run` caller sleeps here until `done == n`
    done_cv: Condvar,
}

/// The pool's long-lived half: shared state plus the worker handles,
/// joined when the last [`WorkerPool`] clone drops.
struct Inner {
    threads: usize,
    shared: Arc<Shared>,
    /// spawned lazily on the first parallel `run`
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// serializes job publication; a caller that loses the race runs
    /// its items inline instead of queueing
    handoff: Mutex<()>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                match &st.job {
                    Some(job) if job.epoch > last_epoch => break job.clone(),
                    _ => st = shared.work_cv.wait(st).unwrap(),
                }
            }
        };
        last_epoch = job.epoch;
        loop {
            let i = job.cursor.fetch_add(1, Ordering::AcqRel);
            if i >= job.n {
                break;
            }
            // SAFETY: i < n, so the publishing `run` frame is still
            // blocked on `done` reaching n — the pointee is alive.
            unsafe { (*job.task.0)(i) };
            if job.done.fetch_add(1, Ordering::AcqRel) + 1 == job.n {
                // notify under the state lock so the caller's
                // check-then-wait cannot miss the wakeup
                let _guard = shared.state.lock().unwrap();
                shared.done_cv.notify_all();
            }
        }
    }
}

/// A fixed-width persistent worker pool.  Cheap to clone (clones share
/// the same worker threads); the threads are spawned on the first
/// parallel [`WorkerPool::run`] and joined when the last clone drops.
#[derive(Clone)]
pub struct WorkerPool {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool").field("threads", &self.inner.threads).finish()
    }
}

impl WorkerPool {
    /// Pool of exactly `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Self {
        WorkerPool {
            inner: Arc::new(Inner {
                threads: threads.max(1),
                shared: Arc::new(Shared {
                    state: Mutex::new(PoolState::default()),
                    work_cv: Condvar::new(),
                    done_cv: Condvar::new(),
                }),
                handles: Mutex::new(Vec::new()),
                handoff: Mutex::new(()),
            }),
        }
    }

    /// Width from the environment: `SIDA_POOL_THREADS` if set to a
    /// positive width, else the machine's available parallelism (capped
    /// at 16 — expert fan-out per layer rarely benefits beyond that).
    /// `SIDA_POOL_THREADS=0` means auto, matching every other pool knob.
    pub fn auto() -> Self {
        if let Ok(v) = std::env::var("SIDA_POOL_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return WorkerPool::new(n);
                }
            }
        }
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        WorkerPool::new(n.min(16))
    }

    /// `0` means auto-size — the convention config knobs use.
    pub fn from_config(threads: usize) -> Self {
        if threads == 0 {
            WorkerPool::auto()
        } else {
            WorkerPool::new(threads)
        }
    }

    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Spawn the persistent workers if they are not up yet.
    fn ensure_workers(&self) {
        let mut handles = self.inner.handles.lock().unwrap();
        if !handles.is_empty() {
            return;
        }
        for slot in 0..self.inner.threads {
            let shared = self.inner.shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sida-pool-{slot}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker"),
            );
        }
    }

    /// Run `f` once per item, up to `threads` at a time, and return the
    /// results **in item order**.  `f` receives `(index, item)`.
    ///
    /// With one worker (or one item) this runs inline on the calling
    /// thread — no handoff, identical to a plain sequential loop.  A
    /// panic inside `f` on a worker is re-raised here after the batch
    /// drains, so no work is silently lost and the workers stay usable.
    pub fn run<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = items.len();
        if self.inner.threads <= 1 || n <= 1 {
            return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
        }
        // the pool is a shared resource: if another `run` is in flight
        // (server `serve_one` racing the batch worker), fall back to
        // inline execution instead of queueing behind it
        let Ok(_handoff) = self.inner.handoff.try_lock() else {
            return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
        };
        self.ensure_workers();

        // Claimable work items and index-addressed result slots: workers
        // race on the job cursor, but every result lands in its item's
        // slot, so completion order never leaks into the returned Vec.
        let work: Vec<Mutex<Option<T>>> =
            items.into_iter().map(|it| Mutex::new(Some(it))).collect();
        let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let panicked: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
        let task = |i: usize| {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let item = work[i].lock().unwrap().take().expect("item claimed twice");
                let out = f(i, item);
                *slots[i].lock().unwrap() = Some(out);
            }));
            if let Err(payload) = result {
                let mut p = panicked.lock().unwrap();
                if p.is_none() {
                    *p = Some(payload);
                }
            }
        };

        let shared = &self.inner.shared;
        let job = {
            let mut st = shared.state.lock().unwrap();
            st.epoch += 1;
            let job = Arc::new(Job {
                // SAFETY: lifetime-erased borrow of `task`; see TaskRef.
                // `run` blocks below until done == n, so the borrow is
                // live for every dereference a worker can make.
                task: {
                    let short: *const (dyn Fn(usize) + Sync) = &task;
                    TaskRef(unsafe {
                        std::mem::transmute::<
                            *const (dyn Fn(usize) + Sync),
                            *const (dyn Fn(usize) + Sync + 'static),
                        >(short)
                    })
                },
                n,
                cursor: AtomicUsize::new(0),
                done: AtomicUsize::new(0),
                epoch: st.epoch,
            });
            st.job = Some(job.clone());
            shared.work_cv.notify_all();
            job
        };
        // wait for the batch to drain, then retire the job
        {
            let mut st = shared.state.lock().unwrap();
            while job.done.load(Ordering::Acquire) < n {
                st = shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
        if let Some(payload) = panicked.into_inner().unwrap() {
            std::panic::resume_unwind(payload);
        }
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker left an empty result slot"))
            .collect()
    }
}

impl Default for WorkerPool {
    fn default() -> Self {
        WorkerPool::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_yields_empty_output() {
        let pool = WorkerPool::new(4);
        let out: Vec<u32> = pool.run(Vec::<u32>::new(), |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn results_are_in_item_order_regardless_of_completion_order() {
        // later items finish first (larger sleep on early indices); the
        // output must still be index-ordered
        let pool = WorkerPool::new(4);
        let items: Vec<usize> = (0..16).collect();
        let out = pool.run(items, |i, x| {
            assert_eq!(i, x);
            std::thread::sleep(std::time::Duration::from_micros(((16 - i) * 200) as u64));
            x * 10
        });
        assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_matches_parallel_pool() {
        let items: Vec<u64> = (0..32).collect();
        let seq = WorkerPool::new(1).run(items.clone(), |i, x| x.wrapping_mul(31) ^ i as u64);
        let par = WorkerPool::new(8).run(items, |i, x| x.wrapping_mul(31) ^ i as u64);
        assert_eq!(seq, par);
    }

    #[test]
    fn closures_may_borrow_caller_stack() {
        let weights: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let pool = WorkerPool::new(3);
        let out = pool.run((0..8).collect::<Vec<usize>>(), |_, i| weights[i] * 2.0);
        assert_eq!(out[7], 14.0);
    }

    #[test]
    fn from_config_zero_is_auto() {
        assert!(WorkerPool::from_config(0).threads() >= 1);
        assert_eq!(WorkerPool::from_config(3).threads(), 3);
        assert_eq!(WorkerPool::new(0).threads(), 1);
    }

    #[test]
    fn workers_persist_across_runs() {
        // the same OS threads must serve consecutive run() calls — the
        // whole point of the persistent pool (warm thread-locals, no
        // per-call spawn).  Observed via thread ids.
        use std::collections::BTreeSet;
        let pool = WorkerPool::new(2);
        let ids_of = |pool: &WorkerPool| -> BTreeSet<String> {
            pool.run((0..8).collect::<Vec<usize>>(), |_, _| {
                std::thread::sleep(std::time::Duration::from_micros(200));
                format!("{:?}", std::thread::current().id())
            })
            .into_iter()
            .collect()
        };
        let first = ids_of(&pool);
        let second = ids_of(&pool);
        assert!(
            first.intersection(&second).next().is_some(),
            "no worker thread survived across runs: {first:?} vs {second:?}"
        );
    }

    #[test]
    fn clones_share_the_same_workers() {
        let pool = WorkerPool::new(2);
        let clone = pool.clone();
        let a: Vec<usize> = pool.run((0..4).collect(), |_, x| x);
        let b: Vec<usize> = clone.run((0..4).collect(), |_, x| x);
        assert_eq!(a, b);
        assert_eq!(pool.threads(), clone.threads());
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = WorkerPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run((0..8).collect::<Vec<usize>>(), |_, x| {
                if x == 5 {
                    panic!("boom on item {x}");
                }
                x
            })
        }));
        assert!(result.is_err(), "worker panic must reach the caller");
        // the pool must remain usable after a panicked batch
        let out: Vec<usize> = pool.run((0..4).collect(), |_, x| x + 1);
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn reentrant_run_falls_back_inline_without_deadlock() {
        // a second run() while one is in flight must not deadlock —
        // the loser of the handoff executes inline
        let pool = WorkerPool::new(2);
        let pool2 = pool.clone();
        let out = pool.run((0..4).collect::<Vec<usize>>(), move |_, x| {
            let inner: Vec<usize> = pool2.run((0..2).collect(), |_, y| y * 10);
            x + inner[1]
        });
        assert_eq!(out, vec![10, 11, 12, 13]);
    }
}
