//! Tiny property-based testing harness (no `proptest` in the vendored
//! crate set).
//!
//! Deliberately small: seeded case generation via [`crate::util::rng::Rng`]
//! plus greedy input shrinking for failing cases.  Properties are written
//! as closures from a generated value to `Result<(), String>`; on failure
//! the harness shrinks with user-supplied shrinkers and panics with the
//! minimal counterexample and its seed so the case can be replayed.

use crate::util::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 128, seed: 0xC0FFEE, max_shrink_steps: 500 }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Run `check` on `cases` values from `gen`.  On failure, shrink via
    /// `shrink` (yielding candidate simpler values) and panic with the
    /// minimal failing input's Debug rendering.
    pub fn check<T, G, S, C>(&self, name: &str, mut gen: G, shrink: S, check: C)
    where
        T: std::fmt::Debug + Clone,
        G: FnMut(&mut Rng) -> T,
        S: Fn(&T) -> Vec<T>,
        C: Fn(&T) -> Result<(), String>,
    {
        let mut rng = Rng::new(self.seed);
        for case in 0..self.cases {
            let mut input = gen(&mut rng);
            if let Err(mut msg) = check(&input) {
                // greedy shrink
                let mut steps = 0;
                'outer: while steps < self.max_shrink_steps {
                    for cand in shrink(&input) {
                        steps += 1;
                        if let Err(m2) = check(&cand) {
                            input = cand;
                            msg = m2;
                            continue 'outer;
                        }
                        if steps >= self.max_shrink_steps {
                            break;
                        }
                    }
                    break;
                }
                panic!(
                    "property '{name}' failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {msg}",
                    self.seed, input
                );
            }
        }
    }
}

/// Shrinker for vectors: drop halves, drop single elements, shrink tails.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    let n = v.len();
    if n == 0 {
        return out;
    }
    out.push(v[..n / 2].to_vec());
    out.push(v[n / 2..].to_vec());
    if n <= 16 {
        for i in 0..n {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Shrinker for unsigned scalars: 0, halves, decrements.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    let mut out = Vec::new();
    if x > 0 {
        out.push(0);
        out.push(x / 2);
        out.push(x - 1);
    }
    out.sort_unstable();
    out.dedup();
    out.retain(|&v| v != x);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new(64).check(
            "reverse twice is identity",
            |r| (0..r.usize_below(20)).map(|_| r.next_u64()).collect::<Vec<_>>(),
            |v| shrink_vec(v),
            |v| {
                let mut w = v.clone();
                w.reverse();
                w.reverse();
                if w == *v { Ok(()) } else { Err("mismatch".into()) }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'sum < 100'")]
    fn failing_property_panics_with_name() {
        Prop::new(64).check(
            "sum < 100",
            |r| (0..10).map(|_| r.usize_below(50)).collect::<Vec<usize>>(),
            |v| shrink_vec(v),
            |v| {
                if v.iter().sum::<usize>() < 100 {
                    Ok(())
                } else {
                    Err(format!("sum = {}", v.iter().sum::<usize>()))
                }
            },
        );
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // property: no element >= 1000. The shrinker should reduce the
        // vector to (nearly) a single offending element.
        let result = std::panic::catch_unwind(|| {
            Prop::new(256).check(
                "all < 1000",
                |r| (0..20).map(|_| r.usize_below(1200)).collect::<Vec<usize>>(),
                |v| shrink_vec(v),
                |v| {
                    if v.iter().all(|&x| x < 1000) {
                        Ok(())
                    } else {
                        Err("big element".into())
                    }
                },
            )
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("property should have failed"),
        };
        // the minimal input reported should be a short vector
        let inside = msg.split("input: ").nth(1).unwrap();
        let commas = inside.split(']').next().unwrap().matches(',').count();
        assert!(commas <= 4, "shrunk input still long: {msg}");
    }
}
