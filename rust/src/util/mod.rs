//! Hand-rolled substrates: JSON, CLI parsing, PRNG, property testing,
//! logging, the persistent worker pool, and the layer-gate sync
//! primitive.
//! The vendored crate set contains only the `xla` dependency closure
//! (no serde/clap/rand/proptest/criterion/tokio/rayon), so everything
//! the system needs beyond that is implemented here (DESIGN.md §3).

pub mod cli;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sync;
