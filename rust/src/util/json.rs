//! Minimal JSON parser + writer.
//!
//! The vendored crate set has no `serde`/`serde_json`, so this module is
//! the JSON substrate for manifests (`manifest.json`), topology
//! descriptors (`model.json`), goldens and metric reports.  It supports
//! the full JSON grammar needed by those files: objects, arrays, strings
//! (with escapes), numbers (f64), booleans, null.  Numbers are kept as
//! f64 — all our integer fields fit in 2^53.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub enum JsonError {
    Eof(usize),
    Unexpected(char, usize),
    BadNumber(usize),
    BadEscape(char, usize),
    Expected(&'static str, usize),
    Trailing(usize),
    Type { wanted: &'static str, got: &'static str },
    MissingKey(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Eof(i) => write!(f, "unexpected end of input at byte {i}"),
            JsonError::Unexpected(c, i) => {
                write!(f, "unexpected character '{c}' at byte {i}")
            }
            JsonError::BadNumber(i) => write!(f, "invalid number at byte {i}"),
            JsonError::BadEscape(c, i) => write!(f, "invalid escape '\\{c}' at byte {i}"),
            JsonError::Expected(what, i) => write!(f, "expected {what} at byte {i}"),
            JsonError::Trailing(i) => write!(f, "trailing garbage at byte {i}"),
            JsonError::Type { wanted, got } => {
                write!(f, "json type error: wanted {wanted}, got {got}")
            }
            JsonError::MissingKey(k) => write!(f, "missing key '{k}'"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(JsonError::Type { wanted: "number", got: other.type_name() }),
        }
    }

    pub fn as_u64(&self) -> Result<u64, JsonError> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64, JsonError> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(JsonError::Type { wanted: "bool", got: other.type_name() }),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(JsonError::Type { wanted: "string", got: other.type_name() }),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(JsonError::Type { wanted: "array", got: other.type_name() }),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(JsonError::Type { wanted: "object", got: other.type_name() }),
        }
    }

    /// Object field lookup, erroring with the key name when missing.
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::MissingKey(key.to_string()))
    }

    /// Optional field lookup.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)?.as_usize()
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)?.as_f64()
    }

    pub fn get_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)?.as_str()
    }

    /// Array of usize convenience (shapes, id lists).
    pub fn usize_vec(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn bump(&mut self) -> Result<u8, JsonError> {
        let c = self.peek()?;
        self.i += 1;
        Ok(c)
    }

    fn expect(&mut self, c: u8, what: &'static str) -> Result<(), JsonError> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(JsonError::Expected(what, self.i))
        }
    }

    fn lit(&mut self, s: &'static str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(JsonError::Expected(s, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{', "'{'")?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':', "':'")?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => return Err(JsonError::Unexpected(c as char, self.i - 1)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[', "'['")?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(out)),
                c => return Err(JsonError::Unexpected(c as char, self.i - 1)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"', "'\"'")?;
        let mut s = String::new();
        loop {
            let c = self.bump()?;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.bump()?;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                code = code * 16
                                    + (h as char)
                                        .to_digit(16)
                                        .ok_or(JsonError::BadEscape('u', self.i))?;
                            }
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bump()? != b'\\' || self.bump()? != b'u' {
                                    return Err(JsonError::BadEscape('u', self.i));
                                }
                                let mut lo = 0u32;
                                for _ in 0..4 {
                                    let h = self.bump()?;
                                    lo = lo * 16
                                        + (h as char)
                                            .to_digit(16)
                                            .ok_or(JsonError::BadEscape('u', self.i))?;
                                }
                                0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                code
                            };
                            s.push(char::from_u32(ch).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(JsonError::BadEscape(other as char, self.i)),
                    }
                }
                _ => {
                    // collect the raw utf-8 byte run
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] != b'"' && self.b[end] != b'\\' {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| JsonError::Unexpected('?', start))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Builder helpers for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":{"d":"e"}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").unwrap().get_str("d").unwrap(), "e");
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A 😀");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\":1").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"nested":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn integer_format() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn whitespace_everywhere() {
        let j = Json::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(j.get("k").unwrap().usize_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn missing_key_error_names_key() {
        let j = Json::parse("{}").unwrap();
        let err = j.get("absent").unwrap_err();
        assert!(err.to_string().contains("absent"));
    }
}
