//! Minimal `log` backend: stderr with level filtering from
//! `SIDA_LOG` (error|warn|info|debug|trace; default warn).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    max: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:5}] {}: {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent — later calls are no-ops).
/// The vendored `log` crate is built without its `std` feature, so the
/// logger is a leaked static rather than `set_boxed_logger`.
pub fn init() {
    let level = match std::env::var("SIDA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Warn,
    };
    let logger: &'static StderrLogger = Box::leak(Box::new(StderrLogger { max: level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logging smoke test");
    }
}
