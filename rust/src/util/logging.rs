//! Minimal `log` backend: stderr with monotonic timestamps and
//! per-module-target level filtering from `SIDA_LOG`.
//!
//! Spec grammar (comma-separated, order-independent):
//!
//! ```text
//! SIDA_LOG=<level>                    # default level for everything
//! SIDA_LOG=debug,cluster=trace        # default debug, cluster::* at trace
//! SIDA_LOG=warn,server=info,obs=off   # per-target overrides
//! ```
//!
//! A bare token is the default level; `target=level` raises or lowers
//! one module subtree, matched against any `::`-separated segment of
//! the record's target (the full module path, e.g.
//! `sida_moe::cluster::router` matches `cluster` and `router`).
//! Unrecognized tokens warn ONCE on stderr at init instead of being
//! silently swallowed.  Lines carry monotonic seconds since init:
//!
//! ```text
//! [   0.123s WARN  sida_moe::cluster::router] device 1 down
//! ```

use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

/// One parsed `SIDA_LOG` directive set.
struct Spec {
    default: LevelFilter,
    /// (target segment, level) overrides, first match wins
    targets: Vec<(String, LevelFilter)>,
}

fn parse_level(s: &str) -> Option<LevelFilter> {
    match s {
        "off" => Some(LevelFilter::Off),
        "error" => Some(LevelFilter::Error),
        "warn" => Some(LevelFilter::Warn),
        "info" => Some(LevelFilter::Info),
        "debug" => Some(LevelFilter::Debug),
        "trace" => Some(LevelFilter::Trace),
        _ => None,
    }
}

/// Parse a spec; the second return is the unrecognized tokens (warned
/// once at init).
fn parse_spec(raw: &str) -> (Spec, Vec<String>) {
    let mut spec = Spec { default: LevelFilter::Warn, targets: Vec::new() };
    let mut bad = Vec::new();
    for token in raw.split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        if let Some((target, level)) = token.split_once('=') {
            let (target, level) = (target.trim(), level.trim());
            match parse_level(level) {
                Some(l) if !target.is_empty() => spec.targets.push((target.to_string(), l)),
                _ => bad.push(token.to_string()),
            }
        } else {
            match parse_level(token) {
                Some(l) => spec.default = l,
                None => bad.push(token.to_string()),
            }
        }
    }
    (spec, bad)
}

impl Spec {
    /// The level filter in effect for a record target: the first
    /// override whose name matches a `::` segment of the target, else
    /// the default.
    fn filter_for(&self, target: &str) -> LevelFilter {
        for (name, level) in &self.targets {
            if target.split("::").any(|seg| seg == name) {
                return *level;
            }
        }
        self.default
    }

    /// The most verbose level any directive allows (drives
    /// `log::set_max_level` so disabled levels cost one comparison).
    fn max_filter(&self) -> LevelFilter {
        self.targets
            .iter()
            .map(|(_, l)| *l)
            .fold(self.default, |a, b| if b > a { b } else { a })
    }
}

struct StderrLogger {
    spec: Spec,
    t0: Instant,
}

/// `Level` and `LevelFilter` share discriminant numbering (Off=0,
/// Error=1 .. Trace=5); the vendored `log` has no cross-type ordering,
/// so compare the discriminants directly.
fn allows(filter: LevelFilter, level: Level) -> bool {
    level as usize <= filter as usize
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        allows(self.spec.filter_for(metadata.target()), metadata.level())
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:>8.3}s {:5} {}] {}",
                self.t0.elapsed().as_secs_f64(),
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent — later calls are no-ops).
/// The vendored `log` crate is built without its `std` feature, so the
/// logger is a leaked static rather than `set_boxed_logger`.
pub fn init() {
    let raw = std::env::var("SIDA_LOG").unwrap_or_default();
    let (spec, bad) = parse_spec(&raw);
    if !bad.is_empty() {
        eprintln!(
            "warning: unrecognized SIDA_LOG directive(s): {} \
             (grammar: level | target=level, levels off|error|warn|info|debug|trace)",
            bad.join(", ")
        );
    }
    let max = spec.max_filter();
    let logger: &'static StderrLogger =
        Box::leak(Box::new(StderrLogger { spec, t0: Instant::now() }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::warn!("logging smoke test");
    }

    #[test]
    fn default_spec_is_warn() {
        let (spec, bad) = parse_spec("");
        assert_eq!(spec.default, LevelFilter::Warn);
        assert!(bad.is_empty());
        assert_eq!(spec.filter_for("sida_moe::cluster::router"), LevelFilter::Warn);
    }

    #[test]
    fn bare_level_sets_default() {
        let (spec, bad) = parse_spec("debug");
        assert!(bad.is_empty());
        assert_eq!(spec.default, LevelFilter::Debug);
        assert_eq!(spec.max_filter(), LevelFilter::Debug);
    }

    #[test]
    fn target_overrides_match_module_segments() {
        let (spec, bad) = parse_spec("debug,cluster=trace,server=off");
        assert!(bad.is_empty());
        assert_eq!(spec.filter_for("sida_moe::cluster::router"), LevelFilter::Trace);
        assert_eq!(spec.filter_for("sida_moe::server"), LevelFilter::Off);
        assert_eq!(spec.filter_for("sida_moe::coordinator::pipeline"), LevelFilter::Debug);
        // max over all directives: trace (drives set_max_level)
        assert_eq!(spec.max_filter(), LevelFilter::Trace);
    }

    #[test]
    fn first_matching_override_wins() {
        let (spec, _) = parse_spec("warn,router=debug,cluster=error");
        // both segments match; the earlier directive takes precedence
        assert_eq!(spec.filter_for("sida_moe::cluster::router"), LevelFilter::Debug);
    }

    #[test]
    fn unrecognized_tokens_are_reported_not_swallowed() {
        let (spec, bad) = parse_spec("verbose,cluster=loud,info,=debug");
        assert_eq!(bad, vec!["verbose", "cluster=loud", "=debug"]);
        // the valid directive still applies
        assert_eq!(spec.default, LevelFilter::Info);
    }

    #[test]
    fn whitespace_tolerated() {
        let (spec, bad) = parse_spec(" debug , cluster = trace ");
        assert!(bad.is_empty());
        assert_eq!(spec.default, LevelFilter::Debug);
        assert_eq!(spec.filter_for("a::cluster::b"), LevelFilter::Trace);
    }
}
