//! Baseline serving methods (paper §4 Setup + Fig 11), all over the same
//! runtime/memory substrate so comparisons isolate the policy:
//!
//! | method        | routing | experts invoked      | expert residency      | dispatch capacity | weights fed from |
//! |---------------|---------|----------------------|-----------------------|-------------------|------------------|
//! | Standard      | router  | **all E** (§2.3)     | all on device         | fixed (full L)    | host literals    |
//! | DeepspeedLike | router  | all E                | all on device         | fixed (full L)    | staged buffers   |
//! | TutelLike     | router  | all E                | all on device         | adaptive bucket   | staged buffers   |
//! | Layerwise     | router  | all E                | streamed per layer    | fixed (full L)    | cache            |
//! | Reactive      | router  | non-empty only       | cached, fetch-on-miss | adaptive bucket   | cache            |
//! | (SiDA lives in coordinator::pipeline)                                                                       |
//!
//! All three Fig-9/10 baselines invoke every expert, per the paper §2.3:
//! "the default implementation ... invokes every expert, irrespective of
//! whether any tokens are assigned to it, to align with hardware" — that
//! invoke-all behaviour is exactly why Table 1 rates them "slow".  They
//! differ in the optimizations their systems actually bring: Standard
//! (HF transformers) re-feeds weights from host each call; DeepSpeed-
//! Inference adds optimized kernels over pre-staged weights at fixed
//! capacity; Tutel adds adaptive parallelism (the dispatch bucket adapts
//! to the real token count).  Layerwise is the "Standard" model-parallel
//! offloading of Fig 11: each MoE layer's full expert set is streamed
//! onto the device right before the layer runs.  Reactive offloads like
//! SiDA but without prediction: every miss blocks the critical path
//! after the router output — the naive scheme the paper's Challenge 1
//! dismisses (an extra ablation, not a paper baseline).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::pipeline::{argmax, RequestResult, ServeOutcome};
use crate::experts::{make_policy, ExpertCache, ExpertKey};
use crate::memory::CostModel;
use crate::metrics::ServeStats;
use crate::model::{ExpertProvider, ForwardOptions, ModelRunner};
use crate::runtime::ModelBundle;
use crate::workload::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Sida,
    Standard,
    DeepspeedLike,
    TutelLike,
    Layerwise,
    Reactive,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "sida" => Method::Sida,
            "standard" => Method::Standard,
            "deepspeed" => Method::DeepspeedLike,
            "tutel" => Method::TutelLike,
            "layerwise" => Method::Layerwise,
            "reactive" => Method::Reactive,
            other => anyhow::bail!(
                "unknown method '{other}' (sida|standard|deepspeed|tutel|layerwise|reactive)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Sida => "sida",
            Method::Standard => "standard",
            Method::DeepspeedLike => "deepspeed",
            Method::TutelLike => "tutel",
            Method::Layerwise => "layerwise",
            Method::Reactive => "reactive",
        }
    }

    pub fn all_baselines() -> [Method; 3] {
        [Method::Standard, Method::DeepspeedLike, Method::TutelLike]
    }
}

#[derive(Debug, Clone)]
pub struct BaselineConfig {
    /// simulated device budget (Layerwise / Reactive); all-resident
    /// methods ignore it and account the full MoE footprint
    pub budget_sim_bytes: usize,
    /// modeled host-RAM tier window below the device budget
    /// (`--ram-budget`; cached methods only) — same ladder semantics as
    /// the SiDA pipeline, so cross-method ladder comparisons share one
    /// memory model
    pub ram_budget_sim_bytes: usize,
    /// the RAM window's own eviction policy (`--ram-policy`)
    pub ram_policy: String,
    pub real_sleep: bool,
    pub want_lm: bool,
    pub want_cls: bool,
}

impl Default for BaselineConfig {
    fn default() -> Self {
        BaselineConfig {
            budget_sim_bytes: 8 << 30,
            ram_budget_sim_bytes: crate::memory::DEFAULT_RAM_BUDGET,
            ram_policy: "fifo".into(),
            real_sleep: false,
            want_lm: false,
            want_cls: false,
        }
    }
}

/// Serve a closed-loop trace with a router-driven baseline.
pub fn run_baseline(
    bundle: Arc<ModelBundle>,
    profile: &str,
    method: Method,
    requests: &[Request],
    cfg: &BaselineConfig,
) -> Result<ServeOutcome> {
    assert_ne!(method, Method::Sida, "SiDA is served by coordinator::Pipeline");
    let runner = ModelRunner::new(bundle.clone(), profile)?;
    let topo = bundle.topology.clone();
    let real_expert_bytes = bundle.weights.expert_bytes(topo.moe_blocks[0], 0)?;
    let cost = CostModel::paper_scale(real_expert_bytes).with_real_sleep(cfg.real_sleep);

    let opts = ForwardOptions {
        invoke_all: !matches!(method, Method::Reactive),
        fixed_bucket: matches!(
            method,
            Method::Standard | Method::DeepspeedLike | Method::Layerwise
        ),
        want_lm: cfg.want_lm,
        want_cls: cfg.want_cls,
    };

    // residency setup
    let all_resident;
    let mut cache;
    let full_moe_sim_bytes = cost.sim_bytes(topo.moe_param_bytes);
    let mut provider_kind: u8 = 0; // 0 = all-resident, 1 = cached, 2 = host literals
    match method {
        Method::Standard => {
            // HF-transformers-style: weights re-fed from host every call
            provider_kind = 2;
            all_resident = None;
            cache = None;
        }
        Method::DeepspeedLike | Method::TutelLike => {
            all_resident = Some(runner.stage_all_experts()?);
            cache = None;
        }
        Method::Layerwise | Method::Reactive => {
            provider_kind = 1;
            all_resident = None;
            cache = Some(ExpertCache::with_hierarchy(
                cfg.budget_sim_bytes,
                cost.clone(),
                make_policy("fifo")?,
                cfg.ram_budget_sim_bytes,
                make_policy(&cfg.ram_policy)?,
            ));
        }
        Method::Sida => unreachable!(),
    }

    let t_start = Instant::now();
    let mut stats = ServeStats::default();
    let mut per_request = Vec::new();

    for req in requests {
        let t0 = Instant::now();
        let out = if provider_kind == 0 {
            let mut provider = ExpertProvider::AllResident(all_resident.as_ref().unwrap());
            runner.forward(&req.ids, None, &mut provider, opts)?
        } else if provider_kind == 2 {
            let mut provider = ExpertProvider::HostLiterals;
            runner.forward(&req.ids, None, &mut provider, opts)?
        } else {
            let c = cache.as_mut().unwrap();
            if method == Method::Layerwise {
                // stream each MoE layer's full expert set before use;
                // with the budget below a layer's footprint this thrashes
                // (Fig 11's model-parallel "Standard")
                for &block in &topo.moe_blocks {
                    for expert in 0..topo.num_experts {
                        let key = ExpertKey::new(block, expert);
                        let real = bundle.weights.expert_bytes(block, expert)?;
                        let engine = bundle.engine.clone();
                        let weights = bundle.weights.clone();
                        // blocking: layer streaming sits on the critical path
                        let _ = c.ensure(key, real, true, || {
                            crate::runtime::stage_expert_parts(&engine, &weights, block, expert)
                        })?;
                    }
                }
            }
            let mut provider = ExpertProvider::Cached { cache: c, blocking: true };
            runner.forward(&req.ids, None, &mut provider, opts)?
        };
        let latency = t0.elapsed().as_secs_f64();
        stats.latency.record(latency);
        stats.phases.add(&out.times);
        stats.requests += 1;

        let cls_pred = out.cls_logits.as_ref().map(|v| argmax(v));
        let (lm_nll, lm_tokens) = match (&out.lm_logits, cfg.want_lm) {
            (Some(logits), true) => {
                let (nll, cnt) = runner.lm_nll(logits, &req.ids)?;
                (Some(nll), Some(cnt))
            }
            _ => (None, None),
        };
        per_request.push(RequestResult {
            id: req.id,
            latency_secs: latency,
            cls_pred,
            lm_nll,
            lm_tokens,
            n_tokens: req.n_tokens,
        });
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();

    match &cache {
        Some(c) => {
            let cs = c.stats();
            stats.cache_hits = cs.hits;
            stats.cache_misses = cs.misses;
            stats.blocking_misses = cs.blocking_misses;
            stats.evictions = cs.evictions;
            stats.transferred_bytes = cs.transferred_sim_bytes;
            stats.modeled_transfer_secs = cs.modeled_transfer_secs;
            stats.overlapped_transfer_secs = cs.overlapped_transfer_secs;
            stats.peak_device_bytes = c.peak();
            stats.budget_bytes = c.budget();
            stats.hierarchy = c.hierarchy_stats();
            // modeled transfer time is already inside phases.transfer_secs
        }
        None => {
            // all-resident methods pay the full MoE footprint
            stats.peak_device_bytes = full_moe_sim_bytes;
            stats.budget_bytes = full_moe_sim_bytes;
        }
    }
    Ok(ServeOutcome { stats, per_request })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::Sida,
            Method::Standard,
            Method::DeepspeedLike,
            Method::TutelLike,
            Method::Layerwise,
            Method::Reactive,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("foo").is_err());
    }
}
