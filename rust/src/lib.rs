//! # SiDA-MoE — Sparsity-Inspired Data-Aware serving for large MoE models
//!
//! Production-quality reproduction of *SiDA-MoE* (Du et al., MLSys 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — expert FFN,
//!   router, SparseMax attention, fused LSTM cell — verified against
//!   pure-jnp oracles and lowered (interpret mode) into the AOT HLO.
//! * **L2** (`python/compile/`): the Switch-style model and the SiDA
//!   hash function in JAX, trained at build time, exported as HLO text +
//!   a flat weight blob.  Python never runs at serving time.
//! * **L3** (this crate): the serving system — PJRT runtime, simulated
//!   GPU memory tier, expert cache with pluggable eviction, the
//!   hash-building/inference thread pipeline, baselines, workloads,
//!   metrics, config, and a TCP front-end.
//!
//! See DESIGN.md for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod baselines;
pub mod bench_support;
pub mod config;
pub mod coordinator;
pub mod experts;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;

/// Default artifacts root relative to the repo checkout.
pub fn default_artifacts_root() -> std::path::PathBuf {
    // honor SIDA_ARTIFACTS, else look for ./artifacts upward from cwd
    if let Ok(p) = std::env::var("SIDA_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
