//! # SiDA-MoE — Sparsity-Inspired Data-Aware serving for large MoE models
//!
//! Production-quality reproduction of *SiDA-MoE* (Du et al., MLSys 2024)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — expert FFN,
//!   router, SparseMax attention, fused LSTM cell — verified against
//!   pure-jnp oracles and lowered (interpret mode) into the AOT HLO.
//! * **L2** (`python/compile/`): the Switch-style model and the SiDA
//!   hash function in JAX, trained at build time, exported as HLO text +
//!   a flat weight blob.  Python never runs at serving time.
//! * **L3** (this crate): the serving system — pluggable execution
//!   backends (pure-Rust reference engine; PJRT behind the `pjrt`
//!   feature), simulated GPU memory tier, expert cache with pluggable
//!   eviction, the hash-building/inference thread pipeline with batch-1
//!   and cross-request batched modes, baselines, workloads, metrics,
//!   config, a TCP front-end over one shared pipeline, and the hermetic
//!   [`testkit`] that fabricates synthetic bundles for `cargo test`.
//!
//! ## Layout
//!
//! * [`runtime`] — `Literal` tensors, the [`runtime::Backend`] trait +
//!   `Engine` dispatch, weight store, topology; together a `ModelBundle`.
//! * [`model`] — `ModelRunner`: the forward pass over shape-specialized
//!   entries, batch-1 (`forward`) and cross-request batched
//!   (`forward_batch`); `ExpertProvider` abstracts who supplies expert
//!   weights.
//! * [`coordinator`] — the paper's Fig 5 system: hash-building thread,
//!   bounded queue, prefetch stage, inference thread (`Pipeline`), the
//!   open-loop scheduler, and the `BatchFormer` that coalesces requests
//!   across connections.
//! * [`experts`] — budgeted device-residency cache with pluggable
//!   eviction and the (batch-union) prefetch planner.
//! * [`cluster`] — multi-device expert parallelism: data-aware
//!   placement, hot-expert replication, per-device caches/ledgers, and
//!   the cluster router (`--devices N --replicate-top R`).
//! * [`server`] — TCP line-protocol front-end: connections feed one
//!   shared admission queue; a worker serves formed batches.
//! * [`obs`] — observability: the unified metrics registry, the
//!   per-request span tracer (`--trace-out`, Chrome trace-event JSON),
//!   and the Prometheus text exposition behind `cmd:metrics`.
//! * [`testkit`] — synthetic bundles + the pure-Rust reference backend;
//!   what makes `cargo test` hermetic.
//!
//! ## Quickstart
//!
//! Everything runs hermetically on the synthetic bundle:
//!
//! ```
//! use sida_moe::model::{ExpertProvider, ForwardOptions, ModelRunner};
//!
//! let bundle = sida_moe::testkit::tiny_bundle();
//! let runner = ModelRunner::new(bundle.clone(), sida_moe::testkit::TINY_PROFILE).unwrap();
//! let staged = runner.stage_all_experts().unwrap();
//! let ids = vec![1, 10, 20, 30, 2, 0, 0, 0]; // BOS, content, EOS, padding
//! let mut provider = ExpertProvider::AllResident(&staged);
//! let out = runner
//!     .forward(&ids, None, &mut provider, ForwardOptions::default())
//!     .unwrap();
//! assert_eq!(out.hidden.len(), ids.len() * bundle.topology.d_model);
//! ```
//!
//! From a shell: `sida-moe serve --model synthetic --dataset tiny`, or
//! `sida-moe server` for the TCP front-end — see README.md.
//!
//! See DESIGN.md for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod baselines;
pub mod bench_support;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experts;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod testkit;
pub mod util;
pub mod workload;

/// Default artifacts root relative to the repo checkout.
///
/// Honors `SIDA_ARTIFACTS`; otherwise walks upward from the current
/// directory looking for an `artifacts/` dir.  The walk is fenced at the
/// repo boundary — the first ancestor holding a `.git` or a workspace
/// `Cargo.toml` — so an unbuilt checkout reports where artifacts WOULD
/// live instead of escaping and silently picking up an unrelated
/// `artifacts/` directory higher in the filesystem.  (A bare package
/// manifest is not a fence: `cargo test` runs with cwd `rust/`, whose
/// `Cargo.toml` sits one level below the artifacts root.)
pub fn default_artifacts_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SIDA_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
    artifacts_root_from(&cwd)
}

fn is_repo_root(dir: &std::path::Path) -> bool {
    if dir.join(".git").exists() {
        return true;
    }
    let manifest = dir.join("Cargo.toml");
    if manifest.is_file() {
        if let Ok(text) = std::fs::read_to_string(&manifest) {
            return text.contains("[workspace]");
        }
    }
    false
}

fn artifacts_root_from(start: &std::path::Path) -> std::path::PathBuf {
    let mut dir = start.to_path_buf();
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if is_repo_root(&dir) {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::artifacts_root_from;
    use std::fs;

    fn scratch(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("sida_root_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn finds_artifacts_beside_cwd() {
        let root = scratch("beside");
        fs::create_dir_all(root.join("artifacts")).unwrap();
        assert_eq!(artifacts_root_from(&root), root.join("artifacts"));
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn walks_up_to_artifacts() {
        let root = scratch("up");
        fs::create_dir_all(root.join("artifacts")).unwrap();
        let nested = root.join("a").join("b");
        fs::create_dir_all(&nested).unwrap();
        assert_eq!(artifacts_root_from(&nested), root.join("artifacts"));
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn stops_at_repo_boundary_instead_of_escaping() {
        // outer/: artifacts/ (unrelated) — inner/: workspace Cargo.toml,
        // no artifacts.  The walk must stop at inner/ (the repo root)
        // instead of escaping to outer/.
        let outer = scratch("fence");
        fs::create_dir_all(outer.join("artifacts")).unwrap();
        let inner = outer.join("repo");
        fs::create_dir_all(&inner).unwrap();
        fs::write(inner.join("Cargo.toml"), "[workspace]\nmembers = []\n").unwrap();
        let nested = inner.join("rust").join("src");
        fs::create_dir_all(&nested).unwrap();
        assert_eq!(artifacts_root_from(&nested), inner.join("artifacts"));
        fs::remove_dir_all(&outer).ok();
    }

    #[test]
    fn git_dir_is_a_fence_too() {
        let outer = scratch("gitfence");
        fs::create_dir_all(outer.join("artifacts")).unwrap();
        let inner = outer.join("checkout");
        fs::create_dir_all(inner.join(".git")).unwrap();
        let nested = inner.join("src");
        fs::create_dir_all(&nested).unwrap();
        assert_eq!(artifacts_root_from(&nested), inner.join("artifacts"));
        fs::remove_dir_all(&outer).ok();
    }

    #[test]
    fn package_manifest_alone_does_not_fence() {
        // repo/: .git + artifacts/; repo/rust/: plain package Cargo.toml.
        // Walking from rust/ must pass the package manifest and find the
        // repo-root artifacts (the layout `cargo test` actually runs in).
        let root = scratch("pkg");
        fs::create_dir_all(root.join(".git")).unwrap();
        fs::create_dir_all(root.join("artifacts")).unwrap();
        let pkg = root.join("rust");
        fs::create_dir_all(&pkg).unwrap();
        fs::write(pkg.join("Cargo.toml"), "[package]\nname = \"x\"\n").unwrap();
        assert_eq!(artifacts_root_from(&pkg), root.join("artifacts"));
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn artifacts_beside_manifest_still_win() {
        let root = scratch("both");
        fs::write(root.join("Cargo.toml"), "[workspace]\n").unwrap();
        fs::create_dir_all(root.join("artifacts")).unwrap();
        assert_eq!(artifacts_root_from(&root), root.join("artifacts"));
        fs::remove_dir_all(&root).ok();
    }
}
