//! Hermetic test substrate: a complete in-memory `ModelBundle` — tiny
//! Switch-style topology, deterministically seeded weights, a pure-Rust
//! reference engine implementing the PJRT forward contract, and a hash
//! artifact whose router agreement is a knob.
//!
//! This is what lets `cargo test` exercise the full SiDA serving stack
//! (routing, caching, eviction, the two-thread pipeline, the TCP
//! front-end) with no Python build, no artifacts directory, and no
//! native XLA toolchain.  The artifact-backed path stays available as an
//! opt-in golden layer (`tests/golden.rs`, `--features pjrt`).
//!
//! ```no_run
//! let bundle = sida_moe::testkit::tiny_bundle();
//! let runner =
//!     sida_moe::model::ModelRunner::new(bundle, sida_moe::testkit::TINY_PROFILE).unwrap();
//! ```

pub mod kernels;
pub mod ref_engine;
pub mod synth;

pub use ref_engine::RefBackend;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::runtime::{Engine, ModelBundle, Topology};
use crate::runtime::topology::HashTopo;
use crate::workload::Profile;

/// The dataset-profile name every hermetic test uses (seq len 8).
pub const TINY_PROFILE: &str = "tiny";

/// Shape + behavior of a synthetic bundle.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub name: String,
    pub seed: u64,
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub moe_blocks: Vec<usize>,
    pub num_experts: usize,
    pub n_classes: usize,
    pub max_seq_len: usize,
    /// dataset profile name -> static sequence length
    pub profiles: BTreeMap<String, usize>,
    /// expert dispatch buckets (ascending)
    pub buckets: Vec<usize>,
    pub hash_hidden: usize,
    pub hash_top_k: usize,
    /// probability that a hash top-1 prediction agrees with the router
    /// (1.0 = perfect hash, the paper's fidelity upper bound)
    pub agreement: f64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        let mut profiles = BTreeMap::new();
        profiles.insert(TINY_PROFILE.to_string(), 8);
        profiles.insert("sst2".to_string(), 32);
        SynthSpec {
            name: "synth8x2".into(),
            seed: 42,
            vocab: 64,
            d_model: 16,
            d_ff: 32,
            n_heads: 2,
            n_blocks: 2,
            moe_blocks: vec![1],
            num_experts: 8,
            n_classes: 4,
            max_seq_len: 32,
            profiles,
            buckets: vec![2, 4, 8, 32],
            hash_hidden: 8,
            hash_top_k: 2,
            agreement: 1.0,
        }
    }
}

impl SynthSpec {
    pub fn agreement(mut self, a: f64) -> Self {
        self.agreement = a;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// A deeper variant with two MoE layers (M = 2), for tests that need
    /// cross-layer hash tables and prefetch plans.
    pub fn two_moe_layers(mut self) -> Self {
        self.n_blocks = 4;
        self.moe_blocks = vec![1, 3];
        self
    }

    /// Topology descriptor matching what `Topology::load` would read
    /// from a real `model.json`.
    pub fn topology(
        &self,
        expert_param_bytes: usize,
        moe_param_bytes: usize,
        total_param_bytes: usize,
    ) -> Topology {
        let mut buckets = self.buckets.clone();
        buckets.sort_unstable();
        Topology {
            name: self.name.clone(),
            vocab: self.vocab,
            d_model: self.d_model,
            d_ff: self.d_ff,
            n_heads: self.n_heads,
            n_blocks: self.n_blocks,
            moe_blocks: self.moe_blocks.clone(),
            num_experts: self.num_experts,
            n_classes: self.n_classes,
            max_seq_len: self.max_seq_len,
            hash: HashTopo {
                hidden: self.hash_hidden,
                n_lstm_layers: 2,
                top_k: self.hash_top_k,
            },
            profiles: self.profiles.clone(),
            buckets,
            expert_param_bytes,
            moe_param_bytes,
            total_param_bytes,
        }
    }
}

/// Fabricate a complete in-memory bundle from a spec.
pub fn bundle(spec: &SynthSpec) -> Result<Arc<ModelBundle>> {
    anyhow::ensure!(
        spec.d_model % spec.n_heads == 0,
        "d_model {} not divisible by n_heads {}",
        spec.d_model,
        spec.n_heads
    );
    anyhow::ensure!(
        spec.hash_top_k <= spec.num_experts,
        "hash_top_k {} exceeds expert pool {}",
        spec.hash_top_k,
        spec.num_experts
    );
    anyhow::ensure!(
        spec.moe_blocks.iter().all(|&b| b < spec.n_blocks),
        "moe_blocks {:?} outside n_blocks {}",
        spec.moe_blocks,
        spec.n_blocks
    );
    for (name, &len) in &spec.profiles {
        anyhow::ensure!(
            len <= spec.max_seq_len,
            "profile '{name}' seq len {len} exceeds max_seq_len {}",
            spec.max_seq_len
        );
    }
    let (store, expert_bytes, moe_bytes, total_bytes) = synth::build_weights(spec)?;
    let weights = Arc::new(store);
    let topology = Arc::new(spec.topology(expert_bytes, moe_bytes, total_bytes));
    let backend = Arc::new(RefBackend::new(
        topology.clone(),
        weights.clone(),
        spec.agreement,
        spec.seed,
    ));
    let engine = Arc::new(Engine::with_backend(backend, Path::new("<synthetic>")));
    Ok(Arc::new(ModelBundle { engine, weights, topology }))
}

/// The default tiny bundle (perfect hash).
pub fn tiny_bundle() -> Arc<ModelBundle> {
    bundle(&SynthSpec::default()).expect("synthetic bundle construction is infallible")
}

/// Tiny bundle with an imperfect hash function.
pub fn bundle_with_agreement(agreement: f64) -> Arc<ModelBundle> {
    bundle(&SynthSpec::default().agreement(agreement))
        .expect("synthetic bundle construction is infallible")
}

/// Workload profile matching the topology's `tiny` dataset profile.
pub fn tiny_profile() -> Profile {
    Profile {
        name: TINY_PROFILE.to_string(),
        seq_len: 8,
        min_len: 3,
        max_len: 6,
        n_topics: 4,
        zipf_a: 1.3,
        topic_frac: 0.75,
    }
}

/// A closed-loop trace over the tiny profile.
pub fn tiny_trace(bundle: &ModelBundle, n: usize, seed: u64) -> Vec<crate::workload::Request> {
    let mut gen = crate::workload::TraceGenerator::new(
        tiny_profile(),
        bundle.topology.vocab,
        seed,
    );
    gen.trace(n, crate::workload::ArrivalProcess::ClosedLoop)
}

/// [`tiny_trace`] with timed arrivals and an SLO class mix — open-loop
/// and admission-control tests.
pub fn tiny_trace_classed(
    bundle: &ModelBundle,
    n: usize,
    seed: u64,
    arrivals: crate::workload::ArrivalProcess,
    mix: crate::workload::ClassMix,
) -> Vec<crate::workload::Request> {
    let mut gen = crate::workload::TraceGenerator::new(
        tiny_profile(),
        bundle.topology.vocab,
        seed,
    );
    gen.trace_classed(n, arrivals, mix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ExpertProvider, ForwardOptions, ModelRunner};

    #[test]
    fn bundle_builds_and_loads_entries() {
        let b = tiny_bundle();
        assert_eq!(b.topology.num_experts, 8);
        assert_eq!(b.topology.seq_len(TINY_PROFILE).unwrap(), 8);
        assert_eq!(b.engine.platform(), "reference-cpu");
        // every serving entry the runner needs resolves
        let runner = ModelRunner::new(b.clone(), TINY_PROFILE).unwrap();
        assert_eq!(runner.seq_len, 8);
    }

    #[test]
    fn forward_is_deterministic() {
        let b = tiny_bundle();
        let runner = ModelRunner::new(b.clone(), TINY_PROFILE).unwrap();
        let ids = vec![1, 10, 20, 30, 2, 0, 0, 0];
        let staged = runner.stage_all_experts().unwrap();
        let mut p1 = ExpertProvider::AllResident(&staged);
        let o1 = runner
            .forward(&ids, None, &mut p1, ForwardOptions::default())
            .unwrap();
        let mut p2 = ExpertProvider::AllResident(&staged);
        let o2 = runner
            .forward(&ids, None, &mut p2, ForwardOptions::default())
            .unwrap();
        assert_eq!(o1.hidden, o2.hidden);
        assert!(!o1.routing.is_empty());
    }

    #[test]
    fn routing_varies_across_experts() {
        // the synthetic router must spread tokens over the pool, or the
        // cache/eviction tests would degenerate to a single expert
        let b = tiny_bundle();
        let runner = ModelRunner::new(b.clone(), TINY_PROFILE).unwrap();
        let staged = runner.stage_all_experts().unwrap();
        let mut used = std::collections::BTreeSet::new();
        for seed in 0..8 {
            for req in tiny_trace(&b, 4, seed) {
                let mut p = ExpertProvider::AllResident(&staged);
                let out = runner
                    .forward(&req.ids, None, &mut p, ForwardOptions::default())
                    .unwrap();
                for r in &out.routing {
                    for &e in &r.top1 {
                        used.insert(e);
                    }
                }
            }
        }
        assert!(used.len() >= 3, "router collapsed to {used:?}");
    }

    #[test]
    fn two_moe_layer_spec_builds() {
        let b = bundle(&SynthSpec::default().two_moe_layers()).unwrap();
        assert_eq!(b.topology.num_moe_layers(), 2);
        let runner = ModelRunner::new(b.clone(), TINY_PROFILE).unwrap();
        let ids = vec![1, 5, 6, 7, 2, 0, 0, 0];
        let staged = runner.stage_all_experts().unwrap();
        let mut p = ExpertProvider::AllResident(&staged);
        let out = runner
            .forward(&ids, None, &mut p, ForwardOptions::default())
            .unwrap();
        assert_eq!(out.routing.len(), 2);
    }
}
