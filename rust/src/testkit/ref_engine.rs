//! The pure-Rust reference backend: every serving entry point the PJRT
//! artifacts expose, implemented directly over host `Literal`s with the
//! exact semantics of `python/compile/model.py`'s `entry_*` functions.
//!
//! One backend instance serves one synthetic bundle.  Besides the plain
//! math entries, it implements the SiDA hash artifact (`hash_L*`) as an
//! *oracle with a configurable error rate*: it computes the true
//! router's top-k decisions by running the model forward internally,
//! then corrupts each token/layer top-1 prediction with probability
//! `1 - agreement` (deterministically, keyed on the sentence).  At
//! `agreement = 1.0` the hash tables are bit-identical to the router's
//! decisions, so the SiDA serving path must reproduce the dense
//! baseline's logits exactly — the paper's fidelity contract, made
//! testable without training an LSTM predictor.
//!
//! Numeric identity matters here: the oracle's internal forward reuses
//! the very same `layer_norm`/`matmul`/`ffn` functions the dispatched
//! entries run, with the same accumulation order, so "hash routing ==
//! router routing implies identical logits" holds bit-for-bit.
//!
//! The dense entries (`embed`, `attn`, `dense_ffn`, `moe_ln`,
//! `moe_combine`) accept a leading batch dimension `B >= 1` (the
//! backend reports `batched_entries`), computing each sequence/row with
//! exactly the `B = 1` arithmetic — which extends the bit-for-bit
//! contract to cross-request batched serving.

// index-explicit loops deliberately mirror the python einsum shapes; the
// entry signatures mirror the artifact argument lists
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::runtime::engine::Backend;
use crate::runtime::{Literal, Topology, WeightStore};
use crate::util::rng::Rng;

pub struct RefBackend {
    topo: Arc<Topology>,
    weights: Arc<WeightStore>,
    /// probability that a hash prediction's top-1 agrees with the router
    agreement: f64,
    seed: u64,
}

// ---------------------------------------------------------------------------
// shared math — lives in `super::kernels` (allocation-free `_into`
// variants over a per-thread scratch arena + allocating wrappers for
// the oracle); re-imported here so both dispatch and the oracle use the
// exact same, bit-identical arithmetic
// ---------------------------------------------------------------------------

use super::kernels::{
    self, add_bias, argmax, attention, ffn, layer_norm, matmul, softmax_inplace, with_arena,
};

/// Clamp a token id into the embedding table like `jnp.take` (clip
/// mode) does in the artifact path: negatives to 0, overflow to V-1.
/// Keeps hostile TCP input (ids >= vocab) from panicking the backend.
fn clip_id(id: i32, vocab: usize) -> usize {
    (id.max(0) as usize).min(vocab - 1)
}

/// FNV-1a over the id bytes — the per-sentence fingerprint that keys the
/// deterministic hash-corruption stream.
fn ids_fingerprint(ids: &[i32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &i in ids {
        for b in i.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

// ---------------------------------------------------------------------------

impl RefBackend {
    pub fn new(
        topo: Arc<Topology>,
        weights: Arc<WeightStore>,
        agreement: f64,
        seed: u64,
    ) -> Self {
        RefBackend { topo, weights, agreement, seed }
    }

    fn w(&self, name: &str) -> Result<&[f32]> {
        self.weights.f32_slice(name)
    }

    fn block_attn(&self, x: &[f32], mask: &[f32], l: usize, blk: usize) -> Result<Vec<f32>> {
        let d = self.topo.d_model;
        Ok(attention(
            x,
            mask,
            l,
            d,
            self.topo.n_heads,
            self.w(&format!("blocks.{blk}.ln1_g"))?,
            self.w(&format!("blocks.{blk}.ln1_b"))?,
            self.w(&format!("blocks.{blk}.wq"))?,
            self.w(&format!("blocks.{blk}.bq"))?,
            self.w(&format!("blocks.{blk}.wk"))?,
            self.w(&format!("blocks.{blk}.bk"))?,
            self.w(&format!("blocks.{blk}.wv"))?,
            self.w(&format!("blocks.{blk}.bv"))?,
            self.w(&format!("blocks.{blk}.wo"))?,
            self.w(&format!("blocks.{blk}.bo"))?,
        ))
    }

    /// The hash oracle: run the true model forward (top-1 routing at
    /// every MoE layer, exactly the arithmetic `ModelRunner` performs),
    /// record the router's top-k per token/layer, then corrupt top-1
    /// predictions at rate `1 - agreement`.
    fn oracle_hash(&self, ids: &[i32]) -> Result<(Vec<i32>, Vec<f32>)> {
        let topo = &self.topo;
        let l = ids.len();
        let d = topo.d_model;
        let e = topo.num_experts;
        let m = topo.num_moe_layers();
        let k = topo.hash.top_k;
        let mask = crate::workload::pad_mask(ids);

        // embed
        let tok = self.w("embed.tok")?;
        let pos = self.w("embed.pos")?;
        let mut x = vec![0f32; l * d];
        for t in 0..l {
            let id = clip_id(ids[t], topo.vocab);
            for j in 0..d {
                x[t * d + j] = tok[id * d + j] + pos[t * d + j];
            }
        }

        let mut idx_out = vec![0i32; l * m * k];
        let mut alpha_out = vec![0f32; l * m * k];

        for blk in 0..topo.n_blocks {
            x = self.block_attn(&x, &mask, l, blk)?;
            match topo.moe_layer_index(blk) {
                None => {
                    let xln = layer_norm(
                        &x,
                        l,
                        d,
                        self.w(&format!("blocks.{blk}.ln2_g"))?,
                        self.w(&format!("blocks.{blk}.ln2_b"))?,
                    );
                    let y = ffn(
                        &xln,
                        l,
                        d,
                        topo.d_ff,
                        self.w(&format!("blocks.{blk}.w1"))?,
                        self.w(&format!("blocks.{blk}.b1"))?,
                        self.w(&format!("blocks.{blk}.w2"))?,
                        self.w(&format!("blocks.{blk}.b2"))?,
                    );
                    for i in 0..l * d {
                        x[i] += y[i];
                    }
                }
                Some(layer) => {
                    let xln = layer_norm(
                        &x,
                        l,
                        d,
                        self.w(&format!("blocks.{blk}.ln2_g"))?,
                        self.w(&format!("blocks.{blk}.ln2_b"))?,
                    );
                    let wr = self.w(&format!("blocks.{blk}.wr"))?;
                    let logits = matmul(&xln, wr, l, d, e);
                    let mut y_acc = vec![0f32; l * d];
                    for t in 0..l {
                        let mut probs = logits[t * e..(t + 1) * e].to_vec();
                        let top1 = argmax(&probs);
                        softmax_inplace(&mut probs);
                        // top-k by repeated argmax (first-max tie break,
                        // matching jnp.argmax for rank 0)
                        let mut taken = vec![false; e];
                        for r in 0..k {
                            let mut best = usize::MAX;
                            for cand in 0..e {
                                if taken[cand] {
                                    continue;
                                }
                                if best == usize::MAX || probs[cand] > probs[best] {
                                    best = cand;
                                }
                            }
                            let best = if r == 0 { top1 } else { best };
                            taken[best] = true;
                            idx_out[(t * m + layer) * k + r] = best as i32;
                            alpha_out[(t * m + layer) * k + r] = probs[best];
                        }
                        // true top-1 layer output for masked tokens —
                        // same scatter arithmetic as ModelRunner
                        if mask[t] > 0.0 {
                            let alpha = probs[top1];
                            let names = WeightStore::expert_part_names(blk, top1);
                            let y = ffn(
                                &xln[t * d..(t + 1) * d],
                                1,
                                d,
                                self.topo.d_ff,
                                self.w(&names[0])?,
                                self.w(&names[1])?,
                                self.w(&names[2])?,
                                self.w(&names[3])?,
                            );
                            for j in 0..d {
                                y_acc[t * d + j] += alpha * y[j];
                            }
                        }
                    }
                    // residual combine with alpha = ones (the runner
                    // applies routing alphas during scatter)
                    for t in 0..l {
                        for j in 0..d {
                            x[t * d + j] += y_acc[t * d + j] * mask[t];
                        }
                    }
                }
            }
        }

        // deterministic corruption of top-1 predictions (needs at least
        // two experts to have a "wrong" one to substitute)
        if self.agreement < 1.0 && e > 1 {
            let fp = ids_fingerprint(ids);
            for layer in 0..m {
                for t in 0..l {
                    let mut r = Rng::new(
                        self.seed
                            ^ fp
                            ^ (layer as u64).wrapping_mul(0x9E3779B97F4A7C15)
                            ^ (t as u64).wrapping_mul(0xD1B54A32D192ED03),
                    );
                    if !r.bool(self.agreement) {
                        let at = (t * m + layer) * k;
                        let e0 = idx_out[at] as usize;
                        let wrong = (e0 + 1 + r.usize_below(e - 1)) % e;
                        idx_out[at] = wrong as i32;
                    }
                }
            }
        }
        Ok((idx_out, alpha_out))
    }
}

fn arg<'a>(args: &[&'a Literal], i: usize, entry: &str) -> Result<&'a Literal> {
    args.get(i)
        .copied()
        .with_context(|| format!("{entry}: missing argument {i}"))
}

impl Backend for RefBackend {
    fn platform(&self) -> String {
        "reference-cpu".into()
    }

    /// The dense entries below derive their dimensions from the argument
    /// shapes, so a leading batch dimension `B > 1` is accepted: every
    /// sequence (for `attn`) / row (for the token-wise entries) is
    /// computed by exactly the arithmetic the `B = 1` dispatch runs,
    /// which is what keeps the cross-request batched serving path
    /// bit-identical to sequential batch-1 serving.
    fn batched_entries(&self) -> bool {
        true
    }

    fn prepare(&self, entry: &str) -> Result<()> {
        let base = entry
            .rsplit_once('_')
            .map(|(b, _)| b)
            .unwrap_or(entry);
        match base {
            "embed" | "attn" | "dense_ffn" | "moe_ln" | "router" | "moe_combine"
            | "lm_head" | "cls_head" | "lm_nll" | "expert" | "hash" => Ok(()),
            other => bail!("reference backend: unknown entry family '{other}' ({entry})"),
        }
    }

    fn dispatch(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let d = self.topo.d_model;
        let base = entry
            .rsplit_once('_')
            .map(|(b, _)| b)
            .unwrap_or(entry);
        match base {
            // (i32 [B,L], tok [V,D], pos [L,D]) -> [B,L,D]
            "embed" => {
                let ids_lit = arg(args, 0, entry)?;
                anyhow::ensure!(
                    ids_lit.shape().len() == 2,
                    "{entry}: ids must be [B, L], got {:?}",
                    ids_lit.shape()
                );
                let (b, l) = (ids_lit.shape()[0], ids_lit.shape()[1]);
                let ids = ids_lit.i32s()?;
                let tok = arg(args, 1, entry)?.f32s()?;
                let pos = arg(args, 2, entry)?.f32s()?;
                let vocab = tok.len() / d;
                let mut out = vec![0f32; b * l * d];
                for s in 0..b {
                    for t in 0..l {
                        let id = clip_id(ids[s * l + t], vocab);
                        let row = (s * l + t) * d;
                        for j in 0..d {
                            out[row + j] = tok[id * d + j] + pos[t * d + j];
                        }
                    }
                }
                Ok(vec![Literal::from_f32s(&[b, l, d], out)?])
            }
            // (x [B,L,D], mask [B,L], ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo) -> x'
            "attn" => {
                let x = arg(args, 0, entry)?;
                let (b, l) = (x.shape()[0], x.shape()[1]);
                let xs = x.f32s()?;
                let mask = arg(args, 1, entry)?.f32s()?;
                let ln_g = arg(args, 2, entry)?.f32s()?;
                let ln_b = arg(args, 3, entry)?.f32s()?;
                let wq = arg(args, 4, entry)?.f32s()?;
                let bq = arg(args, 5, entry)?.f32s()?;
                let wk = arg(args, 6, entry)?.f32s()?;
                let bk = arg(args, 7, entry)?.f32s()?;
                let wv = arg(args, 8, entry)?.f32s()?;
                let bv = arg(args, 9, entry)?.f32s()?;
                let wo = arg(args, 10, entry)?.f32s()?;
                let bo = arg(args, 11, entry)?.f32s()?;
                // one output allocation; every intermediate (LN, Q/K/V,
                // scores, transposed weights) comes from the arena
                let mut out = vec![0f32; b * l * d];
                with_arena(|arena| {
                    for s in 0..b {
                        kernels::attention_into(
                            &mut out[s * l * d..(s + 1) * l * d],
                            &xs[s * l * d..(s + 1) * l * d],
                            &mask[s * l..(s + 1) * l],
                            l,
                            d,
                            self.topo.n_heads,
                            ln_g,
                            ln_b,
                            wq,
                            bq,
                            wk,
                            bk,
                            wv,
                            bv,
                            wo,
                            bo,
                            arena,
                        );
                    }
                });
                Ok(vec![Literal::from_f32s(&[b, l, d], out)?])
            }
            // (x [B,L,D], ln_g, ln_b, w1, b1, w2, b2) -> x + ffn(LN(x))
            "dense_ffn" => {
                let x = arg(args, 0, entry)?;
                let rows = x.shape()[0] * x.shape()[1];
                let xs = x.f32s()?;
                let f = arg(args, 3, entry)?.shape()[1];
                let ln_g = arg(args, 1, entry)?.f32s()?;
                let ln_b = arg(args, 2, entry)?.f32s()?;
                let w1 = arg(args, 3, entry)?.f32s()?;
                let b1 = arg(args, 4, entry)?.f32s()?;
                let w2 = arg(args, 5, entry)?.f32s()?;
                let b2 = arg(args, 6, entry)?.f32s()?;
                let mut y = vec![0f32; rows * d];
                with_arena(|arena| {
                    let mut xln = arena.take(rows * d);
                    kernels::layer_norm_into(&mut xln, xs, rows, d, ln_g, ln_b);
                    kernels::ffn_into(&mut y, &xln, rows, d, f, w1, b1, w2, b2, arena);
                    arena.put(xln);
                });
                for i in 0..rows * d {
                    y[i] += xs[i];
                }
                Ok(vec![Literal::from_f32s(x.shape(), y)?])
            }
            // (x [B,L,D], ln_g, ln_b) -> LN(x)
            "moe_ln" => {
                let x = arg(args, 0, entry)?;
                let rows = x.shape()[0] * x.shape()[1];
                let mut out = vec![0f32; rows * d];
                kernels::layer_norm_into(
                    &mut out,
                    x.f32s()?,
                    rows,
                    d,
                    arg(args, 1, entry)?.f32s()?,
                    arg(args, 2, entry)?.f32s()?,
                );
                Ok(vec![Literal::from_f32s(x.shape(), out)?])
            }
            // (xln, wr) -> (logits [1,L,E], idx i32 [1,L], alpha [1,L])
            "router" => {
                let xln = arg(args, 0, entry)?;
                let l = xln.shape()[1];
                let wr = arg(args, 1, entry)?;
                let e = wr.shape()[1];
                let xs = xln.f32s()?;
                let ws = wr.f32s()?;
                let mut logits = vec![0f32; l * e];
                with_arena(|arena| {
                    kernels::matmul_into(&mut logits, xs, ws, l, d, e, arena);
                });
                let mut idx = vec![0i32; l];
                let mut alpha = vec![0f32; l];
                for t in 0..l {
                    let mut probs = logits[t * e..(t + 1) * e].to_vec();
                    let top1 = argmax(&probs);
                    softmax_inplace(&mut probs);
                    idx[t] = top1 as i32;
                    alpha[t] = probs[top1];
                }
                Ok(vec![
                    Literal::from_f32s(&[1, l, e], logits)?,
                    Literal::from_i32s(&[1, l], idx)?,
                    Literal::from_f32s(&[1, l], alpha)?,
                ])
            }
            // (xtok [T,D], w1, b1, w2, b2) -> [T,D]
            "expert" => {
                let x = arg(args, 0, entry)?;
                let t = x.shape()[0];
                let f = arg(args, 1, entry)?.shape()[1];
                let xs = x.f32s()?;
                let w1 = arg(args, 1, entry)?.f32s()?;
                let b1 = arg(args, 2, entry)?.f32s()?;
                let w2 = arg(args, 3, entry)?.f32s()?;
                let b2 = arg(args, 4, entry)?.f32s()?;
                let mut y = vec![0f32; t * d];
                with_arena(|arena| {
                    kernels::ffn_into(&mut y, xs, t, d, f, w1, b1, w2, b2, arena);
                });
                Ok(vec![Literal::from_f32s(&[t, d], y)?])
            }
            // (x [B,L,D], y [B,L,D], alpha [B,L], mask [B,L]) -> x + alpha*y*mask
            "moe_combine" => {
                let x = arg(args, 0, entry)?;
                let rows = x.shape()[0] * x.shape()[1];
                let xs = x.f32s()?;
                let ys = arg(args, 1, entry)?.f32s()?;
                let alpha = arg(args, 2, entry)?.f32s()?;
                let mask = arg(args, 3, entry)?.f32s()?;
                let mut out = vec![0f32; rows * d];
                for t in 0..rows {
                    for j in 0..d {
                        out[t * d + j] = xs[t * d + j] + alpha[t] * ys[t * d + j] * mask[t];
                    }
                }
                Ok(vec![Literal::from_f32s(x.shape(), out)?])
            }
            // (x, ln_g, ln_b, w [D,V], b) -> [1,L,V]
            "lm_head" => {
                let x = arg(args, 0, entry)?;
                let l = x.shape()[1];
                let w = arg(args, 3, entry)?;
                let v = w.shape()[1];
                let xs = x.f32s()?;
                let ln_g = arg(args, 1, entry)?.f32s()?;
                let ln_b = arg(args, 2, entry)?.f32s()?;
                let ws = w.f32s()?;
                let bias = arg(args, 4, entry)?.f32s()?;
                let mut logits = vec![0f32; l * v];
                with_arena(|arena| {
                    let mut xn = arena.take(l * d);
                    kernels::layer_norm_into(&mut xn, xs, l, d, ln_g, ln_b);
                    kernels::matmul_into(&mut logits, &xn, ws, l, d, v, arena);
                    arena.put(xn);
                });
                kernels::add_bias(&mut logits, l, v, bias);
                Ok(vec![Literal::from_f32s(&[1, l, v], logits)?])
            }
            // (x, mask, ln_g, ln_b, w [D,C], b) -> [1,C]
            "cls_head" => {
                let x = arg(args, 0, entry)?;
                let l = x.shape()[1];
                let mask = arg(args, 1, entry)?.f32s()?;
                let w = arg(args, 4, entry)?;
                let c = w.shape()[1];
                let xn = layer_norm(
                    x.f32s()?,
                    l,
                    d,
                    arg(args, 2, entry)?.f32s()?,
                    arg(args, 3, entry)?.f32s()?,
                );
                let mut denom = 0f32;
                for t in 0..l {
                    denom += mask[t];
                }
                let denom = denom.max(1.0);
                let mut pooled = vec![0f32; d];
                for t in 0..l {
                    for j in 0..d {
                        pooled[j] += xn[t * d + j] * mask[t];
                    }
                }
                for p in pooled.iter_mut() {
                    *p /= denom;
                }
                let mut out = matmul(&pooled, w.f32s()?, 1, d, c);
                add_bias(&mut out, 1, c, arg(args, 5, entry)?.f32s()?);
                Ok(vec![Literal::from_f32s(&[1, c], out)?])
            }
            // (lm_logits [1,L,V], ids [1,L], mask [1,L]) -> (nll [1], count [1])
            "lm_nll" => {
                let logits = arg(args, 0, entry)?;
                let l = logits.shape()[1];
                let v = logits.shape()[2];
                let ls = logits.f32s()?;
                let ids = arg(args, 1, entry)?.i32s()?;
                let mask = arg(args, 2, entry)?.f32s()?;
                let mut total = 0f32;
                let mut count = 0f32;
                for t in 0..l.saturating_sub(1) {
                    let row = &ls[t * v..(t + 1) * v];
                    let mut mx = f32::NEG_INFINITY;
                    for &x in row {
                        if x > mx {
                            mx = x;
                        }
                    }
                    let mut lse = 0f32;
                    for &x in row {
                        lse += (x - mx).exp();
                    }
                    let lse = lse.ln() + mx;
                    let tgt = clip_id(ids[t + 1], v);
                    let nll = lse - row[tgt];
                    total += nll * mask[t + 1];
                    count += mask[t + 1];
                }
                Ok(vec![
                    Literal::from_f32s(&[1], vec![total])?,
                    Literal::from_f32s(&[1], vec![count])?,
                ])
            }
            // (ids, ...hash weights) -> (idx i32 [1,L,M,K], alpha [1,L,M,K])
            "hash" => {
                let ids = arg(args, 0, entry)?.i32s()?;
                let l = ids.len();
                let m = self.topo.num_moe_layers();
                let k = self.topo.hash.top_k;
                let (idx, alpha) = self.oracle_hash(ids)?;
                Ok(vec![
                    Literal::from_i32s(&[1, l, m, k], idx)?,
                    Literal::from_f32s(&[1, l, m, k], alpha)?,
                ])
            }
            other => bail!("reference backend: unknown entry '{other}' ({entry})"),
        }
    }
}
