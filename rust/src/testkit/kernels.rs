//! Allocation-free compute kernels for the reference backend.
//!
//! The historical `RefBackend` kernels allocated every intermediate
//! (`Vec<f32>` per matmul/layernorm/FFN stage) on every dispatch — a
//! malloc/free storm on the serving hot loop.  This module provides:
//!
//! * [`ScratchArena`] — a per-thread free-list of reusable `f32`
//!   buffers.  Intermediates (`xln`, Q/K/V, FFN hidden, transposed
//!   weights) are taken from and returned to the arena, so a steady-
//!   state dispatch performs **zero heap allocations for
//!   intermediates**; only the entry's output buffer (which must be
//!   moved into a `Literal`) is freshly allocated.  The zero-alloc
//!   steady state holds per **long-lived thread** (the inference
//!   thread, the hash thread, pool width 1); scoped pool workers are
//!   fresh OS threads per layer, so their arenas start cold — a
//!   persistent worker pool would extend the reuse there (tracked in
//!   ROADMAP.md).
//! * `*_into` kernels (`matmul_into`, `layer_norm_into`, `ffn_into`,
//!   `attention_into`) that write into caller-provided buffers, plus a
//!   **blocked, transposed-weight matmul microkernel**.
//!
//! ## Bit-identity contract
//!
//! Every kernel here produces **bit-identical** f32 results to the
//! historical naive kernels.  For the matmul this is by construction:
//! for each output element `(r, c)` the accumulator starts at `+0.0`
//! and receives exactly the terms `x[r,k] * w[k,c]` for `k` ascending,
//! skipping `x[r,k] == 0.0` terms (the same skip the naive kernel
//! performed) — a single well-defined f32 addition chain.  The
//! transposed layout and the row/column blocking only change *memory
//! access order*, never the per-element accumulation order, so the
//! result is the same bits.  `tests` below compare the microkernel
//! against an unblocked reference with exact equality.

use std::cell::RefCell;

pub(crate) const LN_EPS: f32 = 1e-6;

/// Per-thread free-list of reusable `f32` buffers.
pub struct ScratchArena {
    free: Vec<Vec<f32>>,
}

impl ScratchArena {
    pub fn new() -> Self {
        ScratchArena { free: Vec::new() }
    }

    /// A zero-filled buffer of `len` values, reusing a previously
    /// returned allocation when one exists.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut v = self.free.pop().unwrap_or_default();
        v.clear();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer for reuse.
    pub fn put(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.free.push(v);
        }
    }
}

impl Default for ScratchArena {
    fn default() -> Self {
        ScratchArena::new()
    }
}

thread_local! {
    static ARENA: RefCell<ScratchArena> = RefCell::new(ScratchArena::new());
}

/// Run `f` with this thread's arena.  MUST NOT be nested (the arena is
/// a `RefCell`); kernels therefore take `&mut ScratchArena` parameters
/// instead of re-entering.
pub fn with_arena<R>(f: impl FnOnce(&mut ScratchArena) -> R) -> R {
    ARENA.with(|a| f(&mut a.borrow_mut()))
}

// ---------------------------------------------------------------------------
// kernels
// ---------------------------------------------------------------------------

/// Row-count threshold at which transposing the weight into scratch
/// (one `O(inner*cols)` pass) pays for the contiguous dot-product
/// access it buys; below it the naive row-major kernel wins.
const TRANSPOSE_MIN_ROWS: usize = 4;
/// Output-row tile: each transposed weight column is streamed once per
/// tile instead of once per row.
const ROW_TILE: usize = 4;

/// `x [rows, inner] @ w [inner, cols] -> out [rows, cols]`.
///
/// Dispatches between the blocked transposed-weight microkernel (large
/// row counts) and the naive reference kernel (small ones); both are
/// bit-identical (see module docs).  `out` is fully overwritten.
pub fn matmul_into(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    rows: usize,
    inner: usize,
    cols: usize,
    arena: &mut ScratchArena,
) {
    debug_assert_eq!(out.len(), rows * cols);
    if rows >= TRANSPOSE_MIN_ROWS {
        // blocked, transposed-weight microkernel: wt[c][k] = w[k][c]
        let mut wt = arena.take(inner * cols);
        for k in 0..inner {
            let wrow = &w[k * cols..(k + 1) * cols];
            for (c, &v) in wrow.iter().enumerate() {
                wt[c * inner + k] = v;
            }
        }
        let mut r0 = 0;
        while r0 < rows {
            let r1 = (r0 + ROW_TILE).min(rows);
            for c in 0..cols {
                let wcol = &wt[c * inner..(c + 1) * inner];
                for r in r0..r1 {
                    let xrow = &x[r * inner..(r + 1) * inner];
                    // single accumulator, k ascending, zero-x skip:
                    // exactly the naive kernel's addition chain
                    let mut acc = 0f32;
                    for k in 0..inner {
                        let xv = xrow[k];
                        if xv == 0.0 {
                            continue;
                        }
                        acc += xv * wcol[k];
                    }
                    out[r * cols + c] = acc;
                }
            }
            r0 = r1;
        }
        arena.put(wt);
    } else {
        for v in out.iter_mut() {
            *v = 0.0;
        }
        for r in 0..rows {
            let xrow = &x[r * inner..(r + 1) * inner];
            let orow = &mut out[r * cols..(r + 1) * cols];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * cols..(kk + 1) * cols];
                for c in 0..cols {
                    orow[c] += xv * wrow[c];
                }
            }
            // zero x-values skipped above contribute exactly 0.0 in f32,
            // so the skip is a pure speedup with identical results
        }
    }
}

/// Allocating wrapper (oracle / cold paths).
pub fn matmul(x: &[f32], w: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * cols];
    let mut arena = ScratchArena::new();
    matmul_into(&mut out, x, w, rows, inner, cols, &mut arena);
    out
}

pub fn add_bias(y: &mut [f32], rows: usize, cols: usize, b: &[f32]) {
    for r in 0..rows {
        let row = &mut y[r * cols..(r + 1) * cols];
        for c in 0..cols {
            row[c] += b[c];
        }
    }
}

pub fn layer_norm_into(out: &mut [f32], x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32]) {
    debug_assert_eq!(out.len(), rows * d);
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mut mu = 0f32;
        for &v in row {
            mu += v;
        }
        mu /= d as f32;
        let mut var = 0f32;
        for &v in row {
            let c = v - mu;
            var += c * c;
        }
        var /= d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        let dst = &mut out[r * d..(r + 1) * d];
        for j in 0..d {
            dst[j] = (row[j] - mu) * inv * g[j] + b[j];
        }
    }
}

/// Allocating wrapper (oracle / cold paths).
pub fn layer_norm(x: &[f32], rows: usize, d: usize, g: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    layer_norm_into(&mut out, x, rows, d, g, b);
    out
}

pub fn softmax_inplace(v: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &x in v.iter() {
        if x > mx {
            mx = x;
        }
    }
    let mut sum = 0f32;
    for x in v.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in v.iter_mut() {
        *x /= sum;
    }
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// relu((x @ w1) + b1) @ w2 + b2 on [rows, d] tokens — the expert /
/// dense-FFN body (no residual).  `out` is `[rows, d]`, fully written.
#[allow(clippy::too_many_arguments)]
pub fn ffn_into(
    out: &mut [f32],
    x: &[f32],
    rows: usize,
    d: usize,
    f: usize,
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
    arena: &mut ScratchArena,
) {
    let mut h = arena.take(rows * f);
    matmul_into(&mut h, x, w1, rows, d, f, arena);
    add_bias(&mut h, rows, f, b1);
    for v in h.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    matmul_into(out, &h, w2, rows, f, d, arena);
    add_bias(out, rows, d, b2);
    arena.put(h);
}

/// Allocating wrapper (oracle / cold paths).
#[allow(clippy::too_many_arguments)]
pub fn ffn(
    x: &[f32],
    rows: usize,
    d: usize,
    f: usize,
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    b2: &[f32],
) -> Vec<f32> {
    let mut out = vec![0f32; rows * d];
    let mut arena = ScratchArena::new();
    ffn_into(&mut out, x, rows, d, f, w1, b1, w2, b2, &mut arena);
    out
}

/// Pre-LN causal multi-head attention with pad masking + residual
/// (entry_attn semantics).  x: `[L, D]` (one sequence), mask: `[L]`,
/// out: `[L, D]` fully written.
#[allow(clippy::too_many_arguments)]
pub fn attention_into(
    out: &mut [f32],
    x: &[f32],
    mask: &[f32],
    l: usize,
    d: usize,
    n_heads: usize,
    ln_g: &[f32],
    ln_b: &[f32],
    wq: &[f32],
    bq: &[f32],
    wk: &[f32],
    bk: &[f32],
    wv: &[f32],
    bv: &[f32],
    wo: &[f32],
    bo: &[f32],
    arena: &mut ScratchArena,
) {
    let hd = d / n_heads;
    let mut xln = arena.take(l * d);
    layer_norm_into(&mut xln, x, l, d, ln_g, ln_b);
    let mut q = arena.take(l * d);
    matmul_into(&mut q, &xln, wq, l, d, d, arena);
    add_bias(&mut q, l, d, bq);
    let mut k = arena.take(l * d);
    matmul_into(&mut k, &xln, wk, l, d, d, arena);
    add_bias(&mut k, l, d, bk);
    let mut v = arena.take(l * d);
    matmul_into(&mut v, &xln, wv, l, d, d, arena);
    add_bias(&mut v, l, d, bv);

    let scale = 1.0 / (hd as f32).sqrt();
    let mut o = arena.take(l * d);
    let mut scores = arena.take(l);
    for head in 0..n_heads {
        let off = head * hd;
        for lq in 0..l {
            for lk in 0..l {
                let mut dot = 0f32;
                for e in 0..hd {
                    dot += q[lq * d + off + e] * k[lk * d + off + e];
                }
                let causal = if lk <= lq { 1.0f32 } else { 0.0 };
                scores[lk] = dot * scale + (causal * mask[lk] - 1.0) * 1e9;
            }
            softmax_inplace(&mut scores);
            for e in 0..hd {
                let mut acc = 0f32;
                for lk in 0..l {
                    acc += scores[lk] * v[lk * d + off + e];
                }
                o[lq * d + off + e] = acc;
            }
        }
    }
    let mut proj = arena.take(l * d);
    matmul_into(&mut proj, &o, wo, l, d, d, arena);
    add_bias(&mut proj, l, d, bo);
    for i in 0..l * d {
        out[i] = proj[i] + x[i];
    }
    arena.put(proj);
    arena.put(scores);
    arena.put(o);
    arena.put(v);
    arena.put(k);
    arena.put(q);
    arena.put(xln);
}

/// Allocating wrapper (oracle / cold paths).
#[allow(clippy::too_many_arguments)]
pub fn attention(
    x: &[f32],
    mask: &[f32],
    l: usize,
    d: usize,
    n_heads: usize,
    ln_g: &[f32],
    ln_b: &[f32],
    wq: &[f32],
    bq: &[f32],
    wk: &[f32],
    bk: &[f32],
    wv: &[f32],
    bv: &[f32],
    wo: &[f32],
    bo: &[f32],
) -> Vec<f32> {
    let mut out = vec![0f32; l * d];
    let mut arena = ScratchArena::new();
    attention_into(
        &mut out, x, mask, l, d, n_heads, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo,
        &mut arena,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The historical naive kernel, kept verbatim as the bit-identity
    /// reference for the microkernel.
    fn matmul_reference(x: &[f32], w: &[f32], rows: usize, inner: usize, cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; rows * cols];
        for r in 0..rows {
            let xrow = &x[r * inner..(r + 1) * inner];
            let orow = &mut out[r * cols..(r + 1) * cols];
            for (kk, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[kk * cols..(kk + 1) * cols];
                for c in 0..cols {
                    orow[c] += xv * wrow[c];
                }
            }
        }
        out
    }

    fn random_vec(rng: &mut Rng, n: usize, zero_rate: f64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if rng.bool(zero_rate) {
                    0.0
                } else {
                    (rng.f64() as f32 - 0.5) * 2.0
                }
            })
            .collect()
    }

    #[test]
    fn transposed_microkernel_is_bit_identical_to_reference() {
        let mut rng = Rng::new(7);
        // shapes straddling the blocking/transpose thresholds, with and
        // without zero inputs (the skip path)
        for &(rows, inner, cols) in
            &[(1, 5, 3), (3, 8, 8), (4, 16, 4), (8, 32, 16), (17, 9, 13), (32, 16, 64)]
        {
            for &zero_rate in &[0.0, 0.4] {
                let x = random_vec(&mut rng, rows * inner, zero_rate);
                let w = random_vec(&mut rng, inner * cols, 0.0);
                let want = matmul_reference(&x, &w, rows, inner, cols);
                let got = matmul(&x, &w, rows, inner, cols);
                assert_eq!(want, got, "rows={rows} inner={inner} cols={cols} zr={zero_rate}");
                // dirty output buffer must be fully overwritten too
                let mut dirty = vec![9.5f32; rows * cols];
                let mut arena = ScratchArena::new();
                matmul_into(&mut dirty, &x, &w, rows, inner, cols, &mut arena);
                assert_eq!(want, dirty);
            }
        }
    }

    #[test]
    fn arena_reuses_capacity() {
        let mut arena = ScratchArena::new();
        let mut v = arena.take(1024);
        v[0] = 3.0;
        let ptr = v.as_ptr();
        arena.put(v);
        let v2 = arena.take(512);
        assert_eq!(v2.as_ptr(), ptr, "arena must hand back the same allocation");
        assert!(v2.iter().all(|&x| x == 0.0), "reused buffers are zeroed");
        assert_eq!(v2.len(), 512);
    }

    #[test]
    fn ffn_into_matches_wrapper_and_is_relu_correct() {
        let mut rng = Rng::new(11);
        let (rows, d, f) = (6, 8, 16);
        let x = random_vec(&mut rng, rows * d, 0.2);
        let w1 = random_vec(&mut rng, d * f, 0.0);
        let b1 = random_vec(&mut rng, f, 0.0);
        let w2 = random_vec(&mut rng, f * d, 0.0);
        let b2 = random_vec(&mut rng, d, 0.0);
        let want = ffn(&x, rows, d, f, &w1, &b1, &w2, &b2);
        let mut got = vec![7.0f32; rows * d];
        let mut arena = ScratchArena::new();
        ffn_into(&mut got, &x, rows, d, f, &w1, &b1, &w2, &b2, &mut arena);
        assert_eq!(want, got);
        // manual reference
        let mut h = matmul_reference(&x, &w1, rows, d, f);
        add_bias(&mut h, rows, f, &b1);
        for v in h.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let mut y = matmul_reference(&h, &w2, rows, f, d);
        add_bias(&mut y, rows, d, &b2);
        assert_eq!(want, y);
    }

    #[test]
    fn layer_norm_into_overwrites_dirty_buffers() {
        let mut rng = Rng::new(3);
        let (rows, d) = (4, 8);
        let x = random_vec(&mut rng, rows * d, 0.0);
        let g = random_vec(&mut rng, d, 0.0);
        let b = random_vec(&mut rng, d, 0.0);
        let want = layer_norm(&x, rows, d, &g, &b);
        let mut dirty = vec![-2.0f32; rows * d];
        layer_norm_into(&mut dirty, &x, rows, d, &g, &b);
        assert_eq!(want, dirty);
    }

    #[test]
    fn with_arena_provides_thread_local_scratch() {
        let a = with_arena(|arena| {
            let v = arena.take(64);
            let p = v.as_ptr() as usize;
            arena.put(v);
            p
        });
        let b = with_arena(|arena| {
            let v = arena.take(64);
            let p = v.as_ptr() as usize;
            arena.put(v);
            p
        });
        assert_eq!(a, b, "same thread reuses the same scratch buffer");
    }
}
