//! Synthetic weight fabrication: a deterministic, in-memory twin of the
//! blob `python/compile/serialize.py` emits.
//!
//! Every tensor the serving stack addresses by name is generated here
//! with the same naming scheme and layout conventions as the real
//! artifacts (64-byte tensor alignment, f32 little-endian, per-expert
//! parts as separate tensors).  Weights are seeded gaussians via
//! `util::rng`, so every test run sees bit-identical models.

use crate::runtime::tensor::{Dtype, TensorMeta};
use crate::runtime::WeightStore;
use crate::testkit::SynthSpec;
use crate::util::rng::Rng;

use anyhow::Result;

/// Standard-normal sample (Box-Muller).
pub fn gauss(rng: &mut Rng) -> f64 {
    let u1 = 1.0 - rng.f64(); // (0, 1] so ln is finite
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Blob builder mirroring serialize.py: tensors appended at 64-byte
/// alignment, manifest metadata tracked alongside.
pub struct BlobBuilder {
    blob: Vec<u8>,
    metas: Vec<TensorMeta>,
}

impl BlobBuilder {
    pub fn new() -> Self {
        BlobBuilder { blob: Vec::new(), metas: Vec::new() }
    }

    pub fn push_f32(&mut self, name: &str, shape: &[usize], values: &[f32]) {
        let count: usize = shape.iter().product();
        assert_eq!(values.len(), count, "tensor {name}: shape/value mismatch");
        while self.blob.len() % 64 != 0 {
            self.blob.push(0);
        }
        let offset = self.blob.len();
        for v in values {
            self.blob.extend_from_slice(&v.to_le_bytes());
        }
        self.metas.push(TensorMeta {
            name: name.to_string(),
            dtype: Dtype::F32,
            shape: shape.to_vec(),
            offset,
            nbytes: count * 4,
        });
    }

    /// Gaussian tensor with the given stddev.
    pub fn push_normal(&mut self, name: &str, shape: &[usize], scale: f64, rng: &mut Rng) {
        let count: usize = shape.iter().product();
        let values: Vec<f32> = (0..count).map(|_| (gauss(rng) * scale) as f32).collect();
        self.push_f32(name, shape, &values);
    }

    pub fn push_zeros(&mut self, name: &str, shape: &[usize]) {
        let count: usize = shape.iter().product();
        self.push_f32(name, shape, &vec![0.0; count]);
    }

    pub fn push_ones(&mut self, name: &str, shape: &[usize]) {
        let count: usize = shape.iter().product();
        self.push_f32(name, shape, &vec![1.0; count]);
    }

    pub fn finish(self) -> Result<WeightStore> {
        WeightStore::from_parts(&self.blob, self.metas)
    }

    pub fn total_tensor_bytes(&self) -> usize {
        self.metas.iter().map(|m| m.nbytes).sum()
    }
}

impl Default for BlobBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Fabricate the full weight set for a spec.  Returns the store plus
/// `(expert_param_bytes, moe_param_bytes, total_param_bytes)` for the
/// topology descriptor.
pub fn build_weights(spec: &SynthSpec) -> Result<(WeightStore, usize, usize, usize)> {
    let mut rng = Rng::new(spec.seed);
    let (d, f, v, e, h) =
        (spec.d_model, spec.d_ff, spec.vocab, spec.num_experts, spec.hash_hidden);
    let mut b = BlobBuilder::new();

    // embeddings: healthy scale so tokens are clearly separable; layer
    // norm renormalizes downstream either way
    b.push_normal("embed.tok", &[v, d], 0.5, &mut rng);
    b.push_normal("embed.pos", &[spec.max_seq_len, d], 0.1, &mut rng);

    let inv_sqrt = |n: usize| 1.0 / (n as f64).sqrt();
    for blk in 0..spec.n_blocks {
        b.push_ones(&format!("blocks.{blk}.ln1_g"), &[d]);
        b.push_zeros(&format!("blocks.{blk}.ln1_b"), &[d]);
        for w in ["wq", "wk", "wv", "wo"] {
            b.push_normal(&format!("blocks.{blk}.{w}"), &[d, d], inv_sqrt(d), &mut rng);
        }
        for bias in ["bq", "bk", "bv", "bo"] {
            b.push_zeros(&format!("blocks.{blk}.{bias}"), &[d]);
        }
        b.push_ones(&format!("blocks.{blk}.ln2_g"), &[d]);
        b.push_zeros(&format!("blocks.{blk}.ln2_b"), &[d]);
        if spec.moe_blocks.contains(&blk) {
            // router scaled up vs the python init so the synthetic model
            // routes decisively across the expert pool
            b.push_normal(&format!("blocks.{blk}.wr"), &[d, e], 0.3, &mut rng);
            for ex in 0..e {
                b.push_normal(
                    &format!("blocks.{blk}.expert.{ex}.w1"),
                    &[d, f],
                    inv_sqrt(d),
                    &mut rng,
                );
                b.push_zeros(&format!("blocks.{blk}.expert.{ex}.b1"), &[f]);
                b.push_normal(
                    &format!("blocks.{blk}.expert.{ex}.w2"),
                    &[f, d],
                    inv_sqrt(f),
                    &mut rng,
                );
                b.push_zeros(&format!("blocks.{blk}.expert.{ex}.b2"), &[d]);
            }
        } else {
            b.push_normal(&format!("blocks.{blk}.w1"), &[d, f], inv_sqrt(d), &mut rng);
            b.push_zeros(&format!("blocks.{blk}.b1"), &[f]);
            b.push_normal(&format!("blocks.{blk}.w2"), &[f, d], inv_sqrt(f), &mut rng);
            b.push_zeros(&format!("blocks.{blk}.b2"), &[d]);
        }
    }

    b.push_ones("final_ln_g", &[d]);
    b.push_zeros("final_ln_b", &[d]);
    b.push_normal("lm_head.w", &[d, v], inv_sqrt(d), &mut rng);
    b.push_zeros("lm_head.b", &[v]);
    b.push_normal("cls_head.w", &[d, spec.n_classes], inv_sqrt(d), &mut rng);
    b.push_zeros("cls_head.b", &[spec.n_classes]);

    // hash-function weights: never executed by the reference backend
    // (the hash entry is an oracle over the true router — see
    // testkit::ref_engine), but present with artifact-compatible names
    // and shapes so HashBuilder and `sida-moe validate` are satisfied.
    let m = spec.moe_blocks.len();
    b.push_normal("hash.compress_w", &[d, h], inv_sqrt(d), &mut rng);
    b.push_zeros("hash.compress_b", &[h]);
    for layer in 0..2 {
        b.push_normal(&format!("hash.lstm.{layer}.wx"), &[h, 4 * h], inv_sqrt(h), &mut rng);
        b.push_normal(&format!("hash.lstm.{layer}.wh"), &[h, 4 * h], inv_sqrt(h), &mut rng);
        b.push_zeros(&format!("hash.lstm.{layer}.b"), &[4 * h]);
    }
    b.push_normal("hash.out_w", &[h, m * spec.num_experts], inv_sqrt(h), &mut rng);
    b.push_zeros("hash.out_b", &[m * spec.num_experts]);

    let expert_param_bytes = 4 * (d * f + f + f * d + d);
    let moe_param_bytes = m * e * expert_param_bytes;
    let total_param_bytes = b.total_tensor_bytes();
    let store = b.finish()?;
    Ok((store, expert_param_bytes, moe_param_bytes, total_param_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::SynthSpec;

    #[test]
    fn gauss_moments_plausible() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = gauss(&mut rng);
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn blob_builder_aligns_and_reads_back() {
        let mut b = BlobBuilder::new();
        b.push_f32("a", &[3], &[1.0, 2.0, 3.0]);
        b.push_f32("b", &[2], &[5.0, 6.0]);
        let ws = b.finish().unwrap();
        assert_eq!(ws.f32_slice("a").unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(ws.f32_slice("b").unwrap(), &[5.0, 6.0]);
        assert_eq!(ws.meta("b").unwrap().offset % 64, 0);
    }

    #[test]
    fn weights_cover_every_serving_tensor() {
        let spec = SynthSpec::default();
        let (ws, expert_bytes, moe_bytes, _total) = build_weights(&spec).unwrap();
        for &blk in &spec.moe_blocks {
            for ex in 0..spec.num_experts {
                assert_eq!(ws.expert_bytes(blk, ex).unwrap(), expert_bytes);
            }
        }
        let from_prefix: usize = spec
            .moe_blocks
            .iter()
            .map(|&blk| ws.bytes_with_prefix(&format!("blocks.{blk}.expert.")))
            .sum();
        assert_eq!(from_prefix, moe_bytes);
        for name in ["embed.tok", "embed.pos", "final_ln_g", "lm_head.w", "cls_head.w",
                     "hash.compress_w", "hash.lstm.0.wx", "hash.out_w"] {
            assert!(ws.has(name), "missing {name}");
        }
        // dense block 0, moe block 1 under the default spec
        assert!(ws.has("blocks.0.w1"));
        assert!(ws.has("blocks.1.wr"));
    }

    #[test]
    fn deterministic_by_seed() {
        let spec = SynthSpec::default();
        let (a, ..) = build_weights(&spec).unwrap();
        let (b, ..) = build_weights(&spec).unwrap();
        assert_eq!(a.f32_slice("embed.tok").unwrap(), b.f32_slice("embed.tok").unwrap());
        let mut spec2 = SynthSpec::default();
        spec2.seed ^= 1;
        let (c, ..) = build_weights(&spec2).unwrap();
        assert_ne!(a.f32_slice("embed.tok").unwrap(), c.f32_slice("embed.tok").unwrap());
    }
}
