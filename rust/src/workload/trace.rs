//! Synthetic sentence/trace generation (Rust twin of data.py).

use crate::util::rng::Rng;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const CONTENT_START: i32 = 3;

/// Dataset profile: length band + padded model sequence length.
#[derive(Debug, Clone)]
pub struct Profile {
    pub name: String,
    pub seq_len: usize,
    pub min_len: usize,
    pub max_len: usize,
    pub n_topics: usize,
    pub zipf_a: f64,
    pub topic_frac: f64,
}

impl Profile {
    /// The three paper datasets (must agree with configs.DATASET_PROFILES).
    pub fn named(name: &str) -> anyhow::Result<Profile> {
        let (seq_len, min_len, max_len) = match name {
            "sst2" => (32, 5, 30),
            "mrpc" => (96, 40, 90),
            "multirc" => (256, 150, 250),
            other => anyhow::bail!("unknown profile '{other}' (sst2|mrpc|multirc)"),
        };
        Ok(Profile {
            name: name.to_string(),
            seq_len,
            min_len,
            max_len,
            n_topics: 4,
            zipf_a: 1.3,
            topic_frac: 0.75,
        })
    }

    pub fn all() -> Vec<Profile> {
        ["sst2", "mrpc", "multirc"]
            .iter()
            .map(|n| Profile::named(n).unwrap())
            .collect()
    }
}

/// The service-level class a request is admitted under.
///
/// `Interactive` requests carry a completion deadline measured from
/// their arrival: the admission controller rejects them early when the
/// predicted queue delay already blows the deadline, and the batch
/// former sheds them (counted, replied `{"error":"deadline"}`) when the
/// deadline is blown at batch-cut time — serving a request that has
/// already missed its SLO only delays requests that can still make
/// theirs.  `Batch` requests have no deadline and ride the throughput
/// lane; an aging credit in the former keeps them from starving under
/// sustained interactive load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SloClass {
    /// latency-sensitive: must complete within `deadline_secs` of arrival
    Interactive { deadline_secs: f64 },
    /// throughput lane: no deadline, never shed
    Batch,
}

impl Default for SloClass {
    fn default() -> Self {
        SloClass::Batch
    }
}

impl SloClass {
    pub fn is_interactive(&self) -> bool {
        matches!(self, SloClass::Interactive { .. })
    }

    /// The class deadline, `None` for the batch lane.
    pub fn deadline_secs(&self) -> Option<f64> {
        match self {
            SloClass::Interactive { deadline_secs } => Some(*deadline_secs),
            SloClass::Batch => None,
        }
    }
}

/// One serving request: a padded sentence plus arrival metadata.  The
/// paper evaluates at batch 1 (one request per forward); the batched
/// serving path coalesces several of these into one forward pass.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// padded token ids, len == profile.seq_len
    pub ids: Vec<i32>,
    /// true token count incl BOS/EOS
    pub n_tokens: usize,
    /// topic id (classification label twin)
    pub label: usize,
    /// seconds after trace start at which the request arrives
    pub arrival: f64,
    /// SLO class this request is served under (default: batch lane)
    pub class: SloClass,
}

/// Attention mask over padded ids: 1.0 for real tokens, 0.0 for
/// padding — THE canonical pad convention; every other mask helper
/// (e.g. `ModelRunner::mask_of`) delegates here so the rule lives in
/// one place.
pub fn pad_mask(ids: &[i32]) -> Vec<f32> {
    ids.iter().map(|&t| if t != PAD { 1.0 } else { 0.0 }).collect()
}

impl Request {
    /// Attention mask over this request's padded ids (see [`pad_mask`]).
    pub fn mask(&self) -> Vec<f32> {
        pad_mask(&self.ids)
    }
}

#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// next request is issued the moment the previous completes
    ClosedLoop,
    /// Poisson arrivals at `rate` requests/sec
    Poisson { rate: f64 },
    /// Markov-modulated on/off process: Poisson at `rate_on` during ON
    /// phases, silent during OFF phases; phase lengths are exponential
    /// with the given means.  Mean rate is
    /// `rate_on * mean_on / (mean_on + mean_off)`.
    Bursty { rate_on: f64, mean_on_secs: f64, mean_off_secs: f64 },
    /// Sinusoidally-modulated Poisson process (diurnal load shape):
    /// instantaneous rate `mean_rate * (1 + amplitude * sin(2π t / period))`,
    /// sampled by Lewis–Shedler thinning.  `amplitude` in [0, 1].
    Diurnal { mean_rate: f64, amplitude: f64, period_secs: f64 },
}

impl ArrivalProcess {
    /// Parse a CLI arrival-process name at the given headline rate.
    /// `bursty` concentrates the same mean rate into ON phases at 3x
    /// intensity (duty cycle 1/3); `diurnal` swings +-80% over a 1 s
    /// period (a compressed day for hermetic runs).
    pub fn parse(name: &str, rate: f64) -> anyhow::Result<ArrivalProcess> {
        match name {
            "closed" => Ok(ArrivalProcess::ClosedLoop),
            "poisson" => Ok(ArrivalProcess::Poisson { rate }),
            "bursty" => Ok(ArrivalProcess::Bursty {
                rate_on: 3.0 * rate,
                mean_on_secs: 0.05,
                mean_off_secs: 0.10,
            }),
            "diurnal" => Ok(ArrivalProcess::Diurnal {
                mean_rate: rate,
                amplitude: 0.8,
                period_secs: 1.0,
            }),
            other => anyhow::bail!("unknown arrival process '{other}' (closed|poisson|bursty|diurnal)"),
        }
    }
}

/// How a generated trace is split into SLO classes: each request is
/// interactive with probability `interactive_frac`, carrying
/// `deadline_secs`; the rest ride the batch lane.
#[derive(Debug, Clone, Copy)]
pub struct ClassMix {
    pub interactive_frac: f64,
    pub deadline_secs: f64,
}

impl ClassMix {
    /// Everything on the batch lane (the pre-SLO default).
    pub fn batch_only() -> ClassMix {
        ClassMix { interactive_frac: 0.0, deadline_secs: f64::INFINITY }
    }
}

pub struct TraceGenerator {
    pub profile: Profile,
    pub vocab: usize,
    rng: Rng,
    band: usize,
    n_content: usize,
}

impl TraceGenerator {
    pub fn new(profile: Profile, vocab: usize, seed: u64) -> Self {
        let n_content = vocab - CONTENT_START as usize;
        let band = n_content / profile.n_topics;
        TraceGenerator { profile, vocab, rng: Rng::new(seed), band, n_content }
    }

    /// Sample one padded sentence; returns (ids, true_len, topic).
    pub fn sentence(&mut self) -> (Vec<i32>, usize, usize) {
        let p = &self.profile;
        let topic = self.rng.usize_below(p.n_topics);
        let mut length = self.rng.range(p.min_len as u64, p.max_len as u64 + 1) as usize;
        length = length.min(p.seq_len - 2);
        let n_topic_tok = (p.topic_frac * length as f64).round() as usize;
        let band_lo = CONTENT_START as usize + topic * self.band;
        let mut body: Vec<i32> = Vec::with_capacity(length);
        for _ in 0..n_topic_tok {
            body.push((band_lo + self.rng.zipf(self.band, p.zipf_a)) as i32);
        }
        for _ in n_topic_tok..length {
            body.push(CONTENT_START + self.rng.zipf(self.n_content, 1.05) as i32);
        }
        self.rng.shuffle(&mut body);
        let mut ids = vec![PAD; p.seq_len];
        ids[0] = BOS;
        ids[1..1 + length].copy_from_slice(&body);
        ids[1 + length] = EOS;
        (ids, length + 2, topic)
    }

    /// Generate a trace of `n` requests under an arrival process
    /// (every request on the batch lane — the pre-SLO behaviour).
    pub fn trace(&mut self, n: usize, arrivals: ArrivalProcess) -> Vec<Request> {
        self.trace_classed(n, arrivals, ClassMix::batch_only())
    }

    /// Generate a trace of `n` requests under an arrival process, each
    /// assigned an SLO class per `mix`.
    pub fn trace_classed(
        &mut self,
        n: usize,
        arrivals: ArrivalProcess,
        mix: ClassMix,
    ) -> Vec<Request> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        // ON/OFF phase state for Bursty: start at the beginning of an
        // ON phase so short traces are not all-silence.
        let mut on_until = f64::NEG_INFINITY;
        for id in 0..n {
            let (ids, n_tokens, label) = self.sentence();
            let arrival = match arrivals {
                ArrivalProcess::ClosedLoop => 0.0,
                ArrivalProcess::Poisson { rate } => {
                    t += self.rng.exp(rate);
                    t
                }
                ArrivalProcess::Bursty { rate_on, mean_on_secs, mean_off_secs } => {
                    if on_until == f64::NEG_INFINITY {
                        on_until = self.rng.exp(1.0 / mean_on_secs);
                    }
                    loop {
                        let dt = self.rng.exp(rate_on);
                        if t + dt <= on_until {
                            t += dt;
                            break;
                        }
                        // the rest of this ON phase produced no arrival:
                        // jump over the OFF gap into the next ON phase
                        // (exponential phases are memoryless, so
                        // restarting the inter-arrival draw is exact)
                        t = on_until + self.rng.exp(1.0 / mean_off_secs);
                        on_until = t + self.rng.exp(1.0 / mean_on_secs);
                    }
                    t
                }
                ArrivalProcess::Diurnal { mean_rate, amplitude, period_secs } => {
                    // Lewis–Shedler thinning against the peak rate
                    let amp = amplitude.clamp(0.0, 1.0);
                    let rate_max = mean_rate * (1.0 + amp);
                    loop {
                        t += self.rng.exp(rate_max);
                        let rate_t = mean_rate
                            * (1.0 + amp * (std::f64::consts::TAU * t / period_secs).sin());
                        if self.rng.f64() * rate_max <= rate_t {
                            break;
                        }
                    }
                    t
                }
            };
            // short-circuit keeps the rng stream identical to pre-SLO
            // traces when the mix is batch-only (deterministic twins)
            let class = if mix.interactive_frac > 0.0 && self.rng.bool(mix.interactive_frac) {
                SloClass::Interactive { deadline_secs: mix.deadline_secs }
            } else {
                SloClass::Batch
            };
            out.push(Request { id: id as u64, ids, n_tokens, label, arrival, class });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentence_structure() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 1);
        for _ in 0..50 {
            let (ids, n, topic) = g.sentence();
            assert_eq!(ids.len(), 32);
            assert_eq!(ids[0], BOS);
            assert!(topic < 4);
            assert!((7..=32).contains(&n));
            // EOS directly after body; padding after
            assert_eq!(ids[n - 1], EOS);
            for &t in &ids[n..] {
                assert_eq!(t, PAD);
            }
            for &t in &ids[1..n - 1] {
                assert!((CONTENT_START..256).contains(&t));
            }
        }
    }

    #[test]
    fn length_bands_match_profiles() {
        for p in Profile::all() {
            let mut g = TraceGenerator::new(p.clone(), 256, 3);
            for _ in 0..20 {
                let (_, n, _) = g.sentence();
                assert!(n >= p.min_len.min(p.seq_len - 2));
                assert!(n <= p.seq_len);
            }
        }
    }

    #[test]
    fn topic_band_dominates() {
        let p = Profile::named("mrpc").unwrap();
        let mut g = TraceGenerator::new(p, 256, 5);
        // a sentence's tokens should concentrate in one band
        let (ids, n, topic) = g.sentence();
        let band = (256 - CONTENT_START as usize) / 4;
        let lo = CONTENT_START as usize + topic * band;
        let hi = lo + band;
        let in_band = ids[1..n - 1]
            .iter()
            .filter(|&&t| (t as usize) >= lo && (t as usize) < hi)
            .count();
        assert!(in_band as f64 >= 0.5 * (n - 2) as f64);
    }

    #[test]
    fn poisson_arrivals_increase() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 7);
        let tr = g.trace(20, ArrivalProcess::Poisson { rate: 100.0 });
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        assert!(tr.last().unwrap().arrival > 0.0);
    }

    #[test]
    fn closed_loop_arrivals_zero() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 7);
        let tr = g.trace(5, ArrivalProcess::ClosedLoop);
        assert!(tr.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn bursty_arrivals_increase_and_cluster() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 11);
        let arr = ArrivalProcess::Bursty {
            rate_on: 300.0,
            mean_on_secs: 0.05,
            mean_off_secs: 0.10,
        };
        let tr = g.trace(200, arr);
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        assert!(tr.last().unwrap().arrival > 0.0);
        // burstiness: the gap distribution must be far more dispersed
        // than Poisson at the same mean rate (CV^2 >> 1)
        let gaps: Vec<f64> = tr.windows(2).map(|w| w[1].arrival - w[0].arrival).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>() / gaps.len() as f64;
        let cv2 = var / (mean * mean);
        assert!(cv2 > 1.5, "on/off arrivals should be overdispersed, cv^2 = {cv2}");
    }

    #[test]
    fn diurnal_arrivals_modulate_rate() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 13);
        let arr = ArrivalProcess::Diurnal {
            mean_rate: 200.0,
            amplitude: 0.9,
            period_secs: 1.0,
        };
        let tr = g.trace(400, arr);
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        // count arrivals in the rate peak (sin > 0) vs trough (sin < 0)
        // over whole periods: the peak half must see clearly more
        let span = tr.last().unwrap().arrival.floor().max(1.0);
        let (mut peak, mut trough) = (0usize, 0usize);
        for r in &tr {
            if r.arrival >= span {
                break;
            }
            let phase = r.arrival.fract();
            if phase < 0.5 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak as f64 > 1.3 * trough as f64,
            "diurnal modulation invisible: peak {peak} vs trough {trough}"
        );
    }

    #[test]
    fn class_mix_splits_and_default_is_batch() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 17);
        let mix = ClassMix { interactive_frac: 0.5, deadline_secs: 0.2 };
        let tr = g.trace_classed(200, ArrivalProcess::Poisson { rate: 50.0 }, mix);
        let n_int = tr.iter().filter(|r| r.class.is_interactive()).count();
        assert!((40..=160).contains(&n_int), "mix 0.5 gave {n_int}/200 interactive");
        for r in &tr {
            match r.class {
                SloClass::Interactive { deadline_secs } => {
                    assert_eq!(deadline_secs, 0.2);
                    assert_eq!(r.class.deadline_secs(), Some(0.2));
                }
                SloClass::Batch => assert_eq!(r.class.deadline_secs(), None),
            }
        }

        // plain trace(): everything on the batch lane
        let mut g2 = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 17);
        let tr2 = g2.trace(20, ArrivalProcess::ClosedLoop);
        assert!(tr2.iter().all(|r| r.class == SloClass::Batch));
        assert_eq!(SloClass::default(), SloClass::Batch);
    }

    #[test]
    fn parse_arrival_names() {
        assert!(matches!(
            ArrivalProcess::parse("closed", 10.0).unwrap(),
            ArrivalProcess::ClosedLoop
        ));
        assert!(matches!(
            ArrivalProcess::parse("poisson", 10.0).unwrap(),
            ArrivalProcess::Poisson { rate } if rate == 10.0
        ));
        assert!(matches!(
            ArrivalProcess::parse("bursty", 10.0).unwrap(),
            ArrivalProcess::Bursty { rate_on, .. } if rate_on == 30.0
        ));
        assert!(matches!(
            ArrivalProcess::parse("diurnal", 10.0).unwrap(),
            ArrivalProcess::Diurnal { mean_rate, .. } if mean_rate == 10.0
        ));
        assert!(ArrivalProcess::parse("nope", 10.0).is_err());
    }

    #[test]
    fn deterministic_by_seed() {
        let p = Profile::named("sst2").unwrap();
        let a = TraceGenerator::new(p.clone(), 256, 42).trace(5, ArrivalProcess::ClosedLoop);
        let b = TraceGenerator::new(p, 256, 42).trace(5, ArrivalProcess::ClosedLoop);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.ids, y.ids);
        }
    }
}
