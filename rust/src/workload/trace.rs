//! Synthetic sentence/trace generation (Rust twin of data.py).

use crate::util::rng::Rng;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const CONTENT_START: i32 = 3;

/// Dataset profile: length band + padded model sequence length.
#[derive(Debug, Clone)]
pub struct Profile {
    pub name: String,
    pub seq_len: usize,
    pub min_len: usize,
    pub max_len: usize,
    pub n_topics: usize,
    pub zipf_a: f64,
    pub topic_frac: f64,
}

impl Profile {
    /// The three paper datasets (must agree with configs.DATASET_PROFILES).
    pub fn named(name: &str) -> anyhow::Result<Profile> {
        let (seq_len, min_len, max_len) = match name {
            "sst2" => (32, 5, 30),
            "mrpc" => (96, 40, 90),
            "multirc" => (256, 150, 250),
            other => anyhow::bail!("unknown profile '{other}' (sst2|mrpc|multirc)"),
        };
        Ok(Profile {
            name: name.to_string(),
            seq_len,
            min_len,
            max_len,
            n_topics: 4,
            zipf_a: 1.3,
            topic_frac: 0.75,
        })
    }

    pub fn all() -> Vec<Profile> {
        ["sst2", "mrpc", "multirc"]
            .iter()
            .map(|n| Profile::named(n).unwrap())
            .collect()
    }
}

/// One serving request: a padded sentence plus arrival metadata.  The
/// paper evaluates at batch 1 (one request per forward); the batched
/// serving path coalesces several of these into one forward pass.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// padded token ids, len == profile.seq_len
    pub ids: Vec<i32>,
    /// true token count incl BOS/EOS
    pub n_tokens: usize,
    /// topic id (classification label twin)
    pub label: usize,
    /// seconds after trace start at which the request arrives
    pub arrival: f64,
}

/// Attention mask over padded ids: 1.0 for real tokens, 0.0 for
/// padding — THE canonical pad convention; every other mask helper
/// (e.g. `ModelRunner::mask_of`) delegates here so the rule lives in
/// one place.
pub fn pad_mask(ids: &[i32]) -> Vec<f32> {
    ids.iter().map(|&t| if t != PAD { 1.0 } else { 0.0 }).collect()
}

impl Request {
    /// Attention mask over this request's padded ids (see [`pad_mask`]).
    pub fn mask(&self) -> Vec<f32> {
        pad_mask(&self.ids)
    }
}

#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// next request is issued the moment the previous completes
    ClosedLoop,
    /// Poisson arrivals at `rate` requests/sec
    Poisson { rate: f64 },
}

pub struct TraceGenerator {
    pub profile: Profile,
    pub vocab: usize,
    rng: Rng,
    band: usize,
    n_content: usize,
}

impl TraceGenerator {
    pub fn new(profile: Profile, vocab: usize, seed: u64) -> Self {
        let n_content = vocab - CONTENT_START as usize;
        let band = n_content / profile.n_topics;
        TraceGenerator { profile, vocab, rng: Rng::new(seed), band, n_content }
    }

    /// Sample one padded sentence; returns (ids, true_len, topic).
    pub fn sentence(&mut self) -> (Vec<i32>, usize, usize) {
        let p = &self.profile;
        let topic = self.rng.usize_below(p.n_topics);
        let mut length = self.rng.range(p.min_len as u64, p.max_len as u64 + 1) as usize;
        length = length.min(p.seq_len - 2);
        let n_topic_tok = (p.topic_frac * length as f64).round() as usize;
        let band_lo = CONTENT_START as usize + topic * self.band;
        let mut body: Vec<i32> = Vec::with_capacity(length);
        for _ in 0..n_topic_tok {
            body.push((band_lo + self.rng.zipf(self.band, p.zipf_a)) as i32);
        }
        for _ in n_topic_tok..length {
            body.push(CONTENT_START + self.rng.zipf(self.n_content, 1.05) as i32);
        }
        self.rng.shuffle(&mut body);
        let mut ids = vec![PAD; p.seq_len];
        ids[0] = BOS;
        ids[1..1 + length].copy_from_slice(&body);
        ids[1 + length] = EOS;
        (ids, length + 2, topic)
    }

    /// Generate a trace of `n` requests under an arrival process.
    pub fn trace(&mut self, n: usize, arrivals: ArrivalProcess) -> Vec<Request> {
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for id in 0..n {
            let (ids, n_tokens, label) = self.sentence();
            let arrival = match arrivals {
                ArrivalProcess::ClosedLoop => 0.0,
                ArrivalProcess::Poisson { rate } => {
                    t += self.rng.exp(rate);
                    t
                }
            };
            out.push(Request { id: id as u64, ids, n_tokens, label, arrival });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentence_structure() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 1);
        for _ in 0..50 {
            let (ids, n, topic) = g.sentence();
            assert_eq!(ids.len(), 32);
            assert_eq!(ids[0], BOS);
            assert!(topic < 4);
            assert!((7..=32).contains(&n));
            // EOS directly after body; padding after
            assert_eq!(ids[n - 1], EOS);
            for &t in &ids[n..] {
                assert_eq!(t, PAD);
            }
            for &t in &ids[1..n - 1] {
                assert!((CONTENT_START..256).contains(&t));
            }
        }
    }

    #[test]
    fn length_bands_match_profiles() {
        for p in Profile::all() {
            let mut g = TraceGenerator::new(p.clone(), 256, 3);
            for _ in 0..20 {
                let (_, n, _) = g.sentence();
                assert!(n >= p.min_len.min(p.seq_len - 2));
                assert!(n <= p.seq_len);
            }
        }
    }

    #[test]
    fn topic_band_dominates() {
        let p = Profile::named("mrpc").unwrap();
        let mut g = TraceGenerator::new(p, 256, 5);
        // a sentence's tokens should concentrate in one band
        let (ids, n, topic) = g.sentence();
        let band = (256 - CONTENT_START as usize) / 4;
        let lo = CONTENT_START as usize + topic * band;
        let hi = lo + band;
        let in_band = ids[1..n - 1]
            .iter()
            .filter(|&&t| (t as usize) >= lo && (t as usize) < hi)
            .count();
        assert!(in_band as f64 >= 0.5 * (n - 2) as f64);
    }

    #[test]
    fn poisson_arrivals_increase() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 7);
        let tr = g.trace(20, ArrivalProcess::Poisson { rate: 100.0 });
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        assert!(tr.last().unwrap().arrival > 0.0);
    }

    #[test]
    fn closed_loop_arrivals_zero() {
        let mut g = TraceGenerator::new(Profile::named("sst2").unwrap(), 256, 7);
        let tr = g.trace(5, ArrivalProcess::ClosedLoop);
        assert!(tr.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn deterministic_by_seed() {
        let p = Profile::named("sst2").unwrap();
        let a = TraceGenerator::new(p.clone(), 256, 42).trace(5, ArrivalProcess::ClosedLoop);
        let b = TraceGenerator::new(p, 256, 42).trace(5, ArrivalProcess::ClosedLoop);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.ids, y.ids);
        }
    }
}
