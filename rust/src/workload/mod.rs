//! Workload substrate: synthetic request traces mirroring the dataset
//! profiles of `python/compile/data.py` (SST2-short, MRPC-mid,
//! MultiRC-long), plus arrival processes.
//!
//! The token model is the same topic-clustered construction the Python
//! side trains on — `topic_frac` of a sentence's tokens Zipf-drawn from
//! a topic band, the rest from a global tail — so the hash function sees
//! serving traffic from the distribution it was trained on (data-aware
//! by construction, exactly the paper's setting).

pub mod trace;

pub use trace::{pad_mask, ArrivalProcess, ClassMix, Profile, Request, SloClass, TraceGenerator};
