//! Data-aware expert placement: from observed/predicted activation
//! frequencies to a home device per (layer, expert) plus replicas of
//! the hottest experts.
//!
//! SiDA's hash tables predict which experts each sentence activates;
//! summing those predictions over traffic gives a per-expert heat
//! profile ([`ActivationProfile`]).  The [`PlacementPlanner`] turns the
//! profile into a [`Placement`]:
//!
//! * every (MoE block, expert) gets exactly **one home device**, chosen
//!   greedily hottest-expert-first onto the least-loaded device — the
//!   classic longest-processing-time partition, which keeps predicted
//!   per-device load balanced and is fully deterministic (ties break on
//!   the device with fewer homes, then the lower device id).  A
//!   per-layer ⌈E/N⌉ home cap keeps per-device expert *memory* balanced
//!   even when most experts are cold;
//! * the **R hottest experts of each MoE layer** (`replicate_top`) are
//!   additionally replicated onto every other device with spare
//!   placement capacity, so the cluster router can steer their traffic
//!   to whichever device is lightest that layer — the hot-expert
//!   replication idea of "Fast MoE Inference via Predictive Prefetching
//!   and Expert Replication" (PAPERS.md);
//! * replicas never push a device past its capacity in experts
//!   (`budget / sim-expert-bytes`); homes are always assigned even on a
//!   tight budget (the runtime cache evicts under pressure — placement
//!   plans residency, the cache enforces it).
//!
//! Pure logic — unit-testable with no backend, no threads.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::coordinator::hash_table::HashTable;
use crate::experts::ExpertKey;
use crate::runtime::Topology;

/// Per-(block, expert) activation counts accumulated from hash-table
/// predictions (or any other routing observation source).
#[derive(Debug, Default, Clone)]
pub struct ActivationProfile {
    counts: BTreeMap<ExpertKey, u64>,
    /// tables observed (the planner's staleness signal)
    observed_tables: u64,
}

impl ActivationProfile {
    /// Fold one request's hash predictions into the profile: for every
    /// masked token and every used rank, the predicted expert of each
    /// MoE layer gains one count.
    pub fn observe_table(
        &mut self,
        table: &HashTable,
        moe_blocks: &[usize],
        k_used: usize,
        mask: &[f32],
    ) {
        for (layer, &block) in moe_blocks.iter().enumerate() {
            for t in 0..table.seq_len {
                if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                    continue;
                }
                for r in 0..k_used.min(table.k) {
                    let key = ExpertKey::new(block, table.expert_at(t, layer, r));
                    *self.counts.entry(key).or_insert(0) += 1;
                }
            }
        }
        self.observed_tables += 1;
    }

    pub fn count(&self, key: &ExpertKey) -> u64 {
        self.counts.get(key).copied().unwrap_or(0)
    }

    pub fn observed_tables(&self) -> u64 {
        self.observed_tables
    }
}

/// Where every expert lives: its home device plus any replicas.
#[derive(Debug, Clone)]
pub struct Placement {
    devices: usize,
    home: BTreeMap<ExpertKey, usize>,
    /// every device holding the expert (home included), ascending ids
    holders: BTreeMap<ExpertKey, Vec<usize>>,
}

impl Placement {
    pub fn devices(&self) -> usize {
        self.devices
    }

    /// The expert's home device (0 if the expert is unknown — belt and
    /// braces for keys outside the planned topology).
    pub fn home_of(&self, key: &ExpertKey) -> usize {
        self.home.get(key).copied().unwrap_or(0)
    }

    /// All devices holding the expert, home included, ascending.
    pub fn holders(&self, key: &ExpertKey) -> &[usize] {
        static HOME0: [usize; 1] = [0];
        self.holders.get(key).map(|v| &v[..]).unwrap_or(&HOME0)
    }

    /// Placement entries (home + replica) assigned to `device`.
    pub fn assigned_to(&self, device: usize) -> usize {
        self.holders.values().filter(|hs| hs.contains(&device)).count()
    }

    /// Total replica entries beyond the homes.
    pub fn replicated_entries(&self) -> usize {
        self.holders.values().map(|hs| hs.len() - 1).sum()
    }

    /// Every (block, expert) key with a home, ascending.
    pub fn keys(&self) -> impl Iterator<Item = &ExpertKey> {
        self.home.keys()
    }

    /// Structural invariants: exactly one home per planned expert, the
    /// home among the holders, holders sorted/deduped and in range.
    pub fn check_invariants(&self, topo: &Topology) -> Result<()> {
        for &block in &topo.moe_blocks {
            for expert in 0..topo.num_experts {
                let key = ExpertKey::new(block, expert);
                let Some(&home) = self.home.get(&key) else {
                    bail!("expert {key:?} has no home device");
                };
                if home >= self.devices {
                    bail!("expert {key:?} homed on out-of-range device {home}");
                }
                let holders = self.holders(&key);
                if !holders.contains(&home) {
                    bail!("expert {key:?}: home {home} missing from holders {holders:?}");
                }
                if holders.windows(2).any(|w| w[0] >= w[1]) {
                    bail!("expert {key:?}: holders {holders:?} not strictly ascending");
                }
                if holders.iter().any(|&d| d >= self.devices) {
                    bail!("expert {key:?}: holder out of range in {holders:?}");
                }
            }
        }
        let planned: usize =
            topo.moe_blocks.len() * topo.num_experts;
        if self.home.len() != planned {
            bail!("placement holds {} homes, topology needs {planned}", self.home.len());
        }
        Ok(())
    }
}

/// Greedy data-aware placement with hot-expert replication (module docs
/// describe the algorithm and its determinism guarantees).
#[derive(Debug, Clone)]
pub struct PlacementPlanner {
    pub devices: usize,
    /// hottest experts per MoE layer replicated across the fleet
    pub replicate_top: usize,
    /// max placement entries per device (simulated budget / simulated
    /// expert bytes); caps replicas only — homes are always assigned
    pub capacity_per_device: usize,
    /// availability floor: every predicted-hot expert (nonzero profile
    /// count) gets at least this many holders, best-effort under
    /// capacity.  1 (the default) is no floor — the home alone.
    pub min_replicas: usize,
}

impl PlacementPlanner {
    pub fn new(devices: usize, replicate_top: usize, capacity_per_device: usize) -> Self {
        PlacementPlanner {
            devices: devices.max(1),
            replicate_top,
            capacity_per_device: capacity_per_device.max(1),
            min_replicas: 1,
        }
    }

    /// Set the `--min-replicas` availability floor (clamped to ≥ 1).
    pub fn with_min_replicas(mut self, min_replicas: usize) -> Self {
        self.min_replicas = min_replicas.max(1);
        self
    }

    /// Plan homes + replicas for every (MoE block, expert) of `topo`
    /// from the observed heat in `profile`.  With an empty profile
    /// (cold start) every count is zero and the plan degenerates to a
    /// deterministic round-robin with the lowest-indexed experts
    /// replicated — replaced as soon as traffic is observed.
    pub fn plan(&self, topo: &Topology, profile: &ActivationProfile) -> Placement {
        let all: Vec<usize> = (0..self.devices).collect();
        self.plan_healthy(topo, profile, &all)
    }

    /// [`PlacementPlanner::plan`] restricted to the `healthy` devices
    /// (ascending ids): homes and replicas land only on healthy devices
    /// — how the router replans around a Down device and re-admits a
    /// recovered one (DESIGN.md §2.7).  The placement still spans the
    /// full fleet (`devices` unchanged), the excluded devices just hold
    /// nothing.  An empty `healthy` list degenerates to the full fleet
    /// (the all-down guard; unreachable in practice — device 0 cannot
    /// fail).
    pub fn plan_healthy(
        &self,
        topo: &Topology,
        profile: &ActivationProfile,
        healthy: &[usize],
    ) -> Placement {
        let all: Vec<usize>;
        let healthy = if healthy.is_empty() {
            all = (0..self.devices).collect();
            &all[..]
        } else {
            healthy
        };
        let mut home = BTreeMap::new();
        let mut holders: BTreeMap<ExpertKey, Vec<usize>> = BTreeMap::new();
        let mut entries = vec![0usize; self.devices];

        // per-layer home cap: each healthy device homes at most ⌈E/H⌉
        // experts of one layer, so cold experts cannot all pile onto
        // whichever device happens to carry the least predicted load —
        // per-device expert *memory* stays balanced along with the load
        let home_cap = topo.num_experts.div_ceil(healthy.len());
        let mut ranked_by_block: Vec<(usize, Vec<(u64, usize)>)> = Vec::new();
        for &block in &topo.moe_blocks {
            // hottest first; ties by ascending expert id (deterministic)
            let mut ranked: Vec<(u64, usize)> = (0..topo.num_experts)
                .map(|e| (profile.count(&ExpertKey::new(block, e)), e))
                .collect();
            ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));

            // greedy homes: least predicted load among healthy devices
            // under the home cap; ties on fewer homes, then device id
            let mut load = vec![0u64; self.devices];
            let mut homes_in_layer = vec![0usize; self.devices];
            for &(count, expert) in &ranked {
                let dev = healthy
                    .iter()
                    .copied()
                    .filter(|&d| homes_in_layer[d] < home_cap)
                    .min_by_key(|&d| (load[d], homes_in_layer[d], d))
                    .expect("home cap admits all experts");
                let key = ExpertKey::new(block, expert);
                home.insert(key, dev);
                holders.insert(key, vec![dev]);
                load[dev] += count;
                homes_in_layer[dev] += 1;
                entries[dev] += 1;
            }
            ranked_by_block.push((block, ranked));
        }

        // Replication runs AFTER every layer's homes are placed: homes
        // are unconditional, so checking replica room against a
        // partially-homed device would let later layers push it past
        // capacity.  Against the final home totals, "replication never
        // exceeds the budget" holds whenever the homes themselves fit.
        for (block, ranked) in &ranked_by_block {
            for &(_, expert) in ranked.iter().take(self.replicate_top) {
                let key = ExpertKey::new(*block, expert);
                let hs = holders.get_mut(&key).expect("homed above");
                for &dev in healthy {
                    if hs.contains(&dev) {
                        continue;
                    }
                    if entries[dev] >= self.capacity_per_device {
                        continue; // replication never exceeds the budget
                    }
                    hs.push(dev);
                    entries[dev] += 1;
                }
                hs.sort_unstable();
            }
        }

        // Availability floor (`--min-replicas K`): every predicted-hot
        // expert should survive K-1 device losses, so give it K holders
        // — hottest experts first, so under tight capacity the floor
        // protects the traffic that matters most.  Best-effort: when no
        // healthy device has spare capacity the expert keeps the
        // holders it has (the runtime cache still refabricates from
        // host RAM on demand — availability degrades, correctness does
        // not).
        let want = self.min_replicas.min(healthy.len());
        if want > 1 {
            for (block, ranked) in &ranked_by_block {
                for &(count, expert) in ranked {
                    if count == 0 {
                        continue; // floor covers predicted-hot experts
                    }
                    let key = ExpertKey::new(*block, expert);
                    let hs = holders.get_mut(&key).expect("homed above");
                    while hs.len() < want {
                        // least-filled healthy device with room, ties on id
                        let Some(dev) = healthy
                            .iter()
                            .copied()
                            .filter(|d| !hs.contains(d))
                            .filter(|&d| entries[d] < self.capacity_per_device)
                            .min_by_key(|&d| (entries[d], d))
                        else {
                            break;
                        };
                        hs.push(dev);
                        entries[dev] += 1;
                    }
                    hs.sort_unstable();
                }
            }
        }
        Placement { devices: self.devices, home, holders }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn profile_with(counts: &[(usize, usize, u64)]) -> ActivationProfile {
        let mut p = ActivationProfile::default();
        for &(block, expert, n) in counts {
            *p.counts.entry(ExpertKey::new(block, expert)).or_insert(0) += n;
        }
        p
    }

    #[test]
    fn every_expert_gets_exactly_one_home() {
        let b = testkit::tiny_bundle();
        let planner = PlacementPlanner::new(3, 1, 64);
        let placement = planner.plan(&b.topology, &ActivationProfile::default());
        placement.check_invariants(&b.topology).unwrap();
        assert_eq!(
            placement.keys().count(),
            b.topology.moe_blocks.len() * b.topology.num_experts
        );
    }

    #[test]
    fn hot_experts_are_replicated_everywhere_with_capacity() {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        let profile = profile_with(&[(block, 5, 100), (block, 2, 50), (block, 0, 1)]);
        let planner = PlacementPlanner::new(4, 1, 64);
        let placement = planner.plan(&b.topology, &profile);
        placement.check_invariants(&b.topology).unwrap();
        // the single hottest expert (5) is on every device
        assert_eq!(placement.holders(&ExpertKey::new(block, 5)).len(), 4);
        // a cold expert is not replicated
        assert_eq!(placement.holders(&ExpertKey::new(block, 7)).len(), 1);
        assert_eq!(placement.replicated_entries(), 3);
    }

    #[test]
    fn replication_respects_capacity() {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        let profile = profile_with(&[(block, 1, 10), (block, 2, 9)]);
        // 8 experts over 2 devices = 4 homes each; capacity 4 leaves no
        // replica room at all
        let planner = PlacementPlanner::new(2, 2, 4);
        let placement = planner.plan(&b.topology, &profile);
        placement.check_invariants(&b.topology).unwrap();
        assert_eq!(placement.replicated_entries(), 0);
        for dev in 0..2 {
            assert!(placement.assigned_to(dev) <= 4);
        }
        // with room for one extra entry per device, replicas return
        let placement = PlacementPlanner::new(2, 2, 5).plan(&b.topology, &profile);
        assert!(placement.replicated_entries() > 0);
        for dev in 0..2 {
            assert!(placement.assigned_to(dev) <= 5);
        }
    }

    #[test]
    fn hotter_experts_spread_across_devices() {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        // two heavy experts must land on different devices
        let profile = profile_with(&[(block, 3, 1000), (block, 6, 900)]);
        let placement = PlacementPlanner::new(2, 0, 64).plan(&b.topology, &profile);
        assert_ne!(
            placement.home_of(&ExpertKey::new(block, 3)),
            placement.home_of(&ExpertKey::new(block, 6)),
        );
    }

    #[test]
    fn min_replicas_floor_covers_predicted_hot_experts() {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        let profile = profile_with(&[(block, 1, 10), (block, 4, 5), (block, 6, 1)]);
        let placement =
            PlacementPlanner::new(4, 0, 64).with_min_replicas(2).plan(&b.topology, &profile);
        placement.check_invariants(&b.topology).unwrap();
        for &expert in &[1usize, 4, 6] {
            assert!(
                placement.holders(&ExpertKey::new(block, expert)).len() >= 2,
                "hot expert {expert} must meet the availability floor"
            );
        }
        // cold experts are not floored
        assert_eq!(placement.holders(&ExpertKey::new(block, 0)).len(), 1);
    }

    #[test]
    fn min_replicas_is_best_effort_under_capacity() {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        let profile = profile_with(&[(block, 1, 10), (block, 2, 9)]);
        // 8 experts over 2 devices = 4 homes each, filling capacity 4
        // exactly: no room for any floor replica, and no panic
        let placement =
            PlacementPlanner::new(2, 0, 4).with_min_replicas(2).plan(&b.topology, &profile);
        placement.check_invariants(&b.topology).unwrap();
        assert_eq!(placement.replicated_entries(), 0);
    }

    #[test]
    fn plan_healthy_homes_only_on_healthy_devices() {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        let profile = profile_with(&[(block, 3, 100)]);
        let planner = PlacementPlanner::new(4, 1, 64).with_min_replicas(2);
        let placement = planner.plan_healthy(&b.topology, &profile, &[0, 2, 3]);
        placement.check_invariants(&b.topology).unwrap();
        assert_eq!(placement.devices(), 4, "fleet size unchanged");
        assert_eq!(placement.assigned_to(1), 0, "Down device holds nothing");
        for key in placement.keys() {
            assert!(!placement.holders(key).contains(&1));
        }
        // the all-down guard degenerates to the full fleet
        let placement = planner.plan_healthy(&b.topology, &profile, &[]);
        placement.check_invariants(&b.topology).unwrap();
        assert!(placement.assigned_to(1) > 0);
    }

    #[test]
    fn observe_table_counts_masked_tokens_only() {
        let b = testkit::tiny_bundle();
        let builder = crate::coordinator::HashBuilder::new(&b, testkit::TINY_PROFILE).unwrap();
        let req = testkit::tiny_trace(&b, 1, 3).remove(0);
        let table = builder.build(req.id, &req.ids).unwrap();
        let mut p = ActivationProfile::default();
        p.observe_table(&table, &b.topology.moe_blocks, 1, &req.mask());
        assert_eq!(p.observed_tables(), 1);
        let total: u64 = b
            .topology
            .moe_blocks
            .iter()
            .flat_map(|&blk| {
                (0..b.topology.num_experts).map(move |e| p.count(&ExpertKey::new(blk, e)))
            })
            .sum();
        let real_tokens = req.mask().iter().filter(|&&m| m > 0.0).count() as u64;
        assert_eq!(total, real_tokens * b.topology.moe_blocks.len() as u64);
    }
}
