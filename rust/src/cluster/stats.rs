//! Per-device and cluster-wide serving statistics: memory, cache
//! traffic, load balance, and modeled interconnect cost.

use crate::cluster::failure::DeviceHealth;
use crate::experts::CacheStats;
use crate::memory::HierarchyStats;

/// One device's snapshot.
#[derive(Debug, Default, Clone)]
pub struct DeviceStats {
    pub device: usize,
    /// simulated device budget in effect
    pub budget_bytes: usize,
    /// simulated bytes resident right now
    pub used_bytes: usize,
    /// simulated peak residency over the run
    pub peak_bytes: usize,
    /// experts resident right now
    pub resident_experts: usize,
    /// placement entries (home + replica) assigned to this device
    pub assigned_experts: usize,
    /// token rows dispatched to this device
    pub rows: u64,
    /// dispatch-bucket units dispatched to this device (rows rounded up
    /// to the kernel's padded chunks — the compute the lane balancer
    /// actually weighs)
    pub bucket_units: u64,
    /// the device cache's full counter set (hits, misses, transfers,
    /// overlap split)
    pub cache: CacheStats,
    /// this device's GPU/RAM/SSD ladder — read from the cache-driven
    /// residency ledger (per-tier occupancy, promotions per hop, ladder
    /// seconds), never modeled beside it
    pub hierarchy: HierarchyStats,
    /// health at snapshot time (Up / Degraded / Down, DESIGN.md §2.7)
    pub health: DeviceHealth,
}

/// Cluster-wide snapshot: every device plus the cross-device totals.
#[derive(Debug, Default, Clone)]
pub struct ClusterStats {
    pub devices: Vec<DeviceStats>,
    /// placement entries beyond the one home per expert
    pub replicated_entries: usize,
    /// activation bytes moved across the device fabric (both directions)
    pub cross_device_bytes: u64,
    /// modeled seconds those activation transfers cost
    pub interconnect_secs: f64,
    /// placement (re)computations performed
    pub replans: u64,
    /// expert jobs rerouted because their home device was Down
    /// (replica steering plus emergency promotions)
    pub failovers: u64,
    /// the subset of failovers with no healthy holder at all — the
    /// expert was emergency-promoted onto the least-loaded healthy
    /// device, paying the fetch on the modeled timeline
    pub failover_promotions: u64,
    /// lanes lost to a mid-batch crash and recomputed on survivors
    pub retries: u64,
    /// planned prefetches dropped by injected fetch faults
    pub dropped_fetches: u64,
    /// Up→Down transitions observed on the batch-tick timeline
    pub device_failures: u64,
    /// Down→Up transitions (each triggers a re-admitting replan)
    pub recoveries: u64,
    /// measured wall seconds devices spent Down (diagnostic; the fault
    /// schedule itself is deterministic in batch ticks)
    pub downtime_secs: f64,
}

impl ClusterStats {
    /// The one max-over-mean rule both imbalance views share (1.0 =
    /// perfectly balanced; `None` for an empty fleet or no load).  The
    /// denominator is the mean over **all** devices, idle ones included
    /// — an idle device is imbalance, not a smaller cluster.
    fn imbalance_of(&self, load: impl Fn(&DeviceStats) -> u64) -> Option<f64> {
        if self.devices.is_empty() {
            return None;
        }
        let total: u64 = self.devices.iter().map(&load).sum();
        if total == 0 {
            return None;
        }
        let mean = total as f64 / self.devices.len() as f64;
        let max = self.devices.iter().map(&load).max().unwrap_or(0) as f64;
        Some(max / mean)
    }

    /// Max-over-mean row load across devices.
    pub fn load_imbalance(&self) -> Option<f64> {
        self.imbalance_of(|d| d.rows)
    }

    /// Max-over-mean **bucket-unit** load across devices — the compute
    /// imbalance the bucket-weighted lane balancer minimizes (rows
    /// round up to dispatch buckets, so this tracks what the devices
    /// actually execute; [`ClusterStats::load_imbalance`] keeps the raw
    /// row view).
    pub fn compute_imbalance(&self) -> Option<f64> {
        self.imbalance_of(|d| d.bucket_units)
    }

    /// The worst single device's peak residency — the per-device GPU
    /// memory the fleet must provision (the fig_cluster bench axis).
    pub fn max_device_peak_bytes(&self) -> usize {
        self.devices.iter().map(|d| d.peak_bytes).max().unwrap_or(0)
    }

    /// The worst single device's placement footprint in experts.
    pub fn max_device_assigned(&self) -> usize {
        self.devices.iter().map(|d| d.assigned_experts).max().unwrap_or(0)
    }

    /// The fleet-aggregate §6 ladder: every device's cache-driven
    /// ledger folded into one snapshot (occupancy sums, per-hop
    /// promotions/demotions, ladder seconds).  The ONE aggregation rule
    /// — the serve pipeline and the server `cmd:stats` reply both read
    /// this, so they can never disagree.
    pub fn hierarchy_total(&self) -> HierarchyStats {
        let mut total = HierarchyStats::default();
        for d in &self.devices {
            total.add(&d.hierarchy);
        }
        total
    }

    /// Aggregate hit rate across every device cache (`None` with no
    /// traffic anywhere).
    pub fn hit_rate(&self) -> Option<f64> {
        let hits: u64 = self.devices.iter().map(|d| d.cache.hits).sum();
        let misses: u64 = self.devices.iter().map(|d| d.cache.misses).sum();
        if hits + misses == 0 {
            None
        } else {
            Some(hits as f64 / (hits + misses) as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(device: usize, rows: u64, peak: usize) -> DeviceStats {
        DeviceStats { device, rows, peak_bytes: peak, ..Default::default() }
    }

    #[test]
    fn imbalance_is_max_over_mean() {
        let s = ClusterStats {
            devices: vec![dev(0, 30, 10), dev(1, 10, 20)],
            ..Default::default()
        };
        // mean 20, max 30 -> 1.5
        assert!((s.load_imbalance().unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(s.max_device_peak_bytes(), 20);
    }

    #[test]
    fn compute_imbalance_weighs_bucket_units() {
        let mut a = dev(0, 10, 0);
        a.bucket_units = 30;
        let mut b = dev(1, 30, 0);
        b.bucket_units = 10;
        let s = ClusterStats { devices: vec![a, b], ..Default::default() };
        // rows say device 1 is hot; bucket units say device 0 is
        assert!((s.load_imbalance().unwrap() - 1.5).abs() < 1e-12);
        assert!((s.compute_imbalance().unwrap() - 1.5).abs() < 1e-12);
        assert_eq!(ClusterStats::default().compute_imbalance(), None);
    }

    #[test]
    fn idle_cluster_has_no_imbalance() {
        let s = ClusterStats { devices: vec![dev(0, 0, 0), dev(1, 0, 0)], ..Default::default() };
        assert_eq!(s.load_imbalance(), None);
        assert_eq!(ClusterStats::default().load_imbalance(), None);
    }

    #[test]
    fn idle_device_counts_toward_imbalance() {
        let s = ClusterStats {
            devices: vec![dev(0, 40, 0), dev(1, 0, 0)],
            ..Default::default()
        };
        // mean 20, max 40 -> 2.0: one idle device of two
        assert!((s.load_imbalance().unwrap() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_aggregates_across_devices() {
        let mut a = dev(0, 1, 0);
        a.cache.hits = 3;
        a.cache.misses = 1;
        let mut b = dev(1, 1, 0);
        b.cache.hits = 1;
        b.cache.misses = 3;
        let s = ClusterStats { devices: vec![a, b], ..Default::default() };
        assert!((s.hit_rate().unwrap() - 0.5).abs() < 1e-12);
        assert_eq!(ClusterStats::default().hit_rate(), None);
    }
}
