//! Deterministic fault injection for the modeled device fleet: a seeded
//! [`FaultPlan`] of device failures/recoveries/degradations pinned to
//! the **batch-tick timeline**, and the [`FaultInjector`] the
//! [`crate::cluster::ClusterRouter`] consults on every routing and
//! prefetch decision.
//!
//! The cluster has no wall clock it could key faults to without losing
//! reproducibility, but it does have a deterministic timeline: the
//! router counts served batches (`ClusterRouter::advance_batch`, called
//! once per batch by every serving front-end).  A fault plan names tick
//! indices on that counter, so the same plan against the same trace
//! produces the same failures, the same failovers, and — because expert
//! math is device-independent — the same output bits as the fault-free
//! run.
//!
//! Plan grammar (comma-separated events, `--fault-plan`):
//!
//! ```text
//! down:D@T..U      device D crashes at batch tick T, recovers at U:
//!                  lanes in flight at tick T fail (retried once on
//!                  survivors); T < tick < U the device is Down —
//!                  excluded from assignment, prefetch, and replans;
//!                  tick >= U it is re-admitted (replan).
//! degrade:D@T..UxF device D's modeled transfer charges are multiplied
//!                  by F while T <= tick < U (accounting only — the
//!                  device still computes, so outputs are unchanged).
//! drop:D@T         prefetches planned for device D at tick T are
//!                  dropped (the expert degrades to a later blocking
//!                  miss — slower, never wrong).
//! ```
//!
//! Device 0 is the primary (dense stages + scatter accumulators live
//! there, mirroring the single-device path) and cannot go down; plans
//! that try are rejected at parse time.
//!
//! Health states ([`DeviceHealth`]): `Up` (normal), `Degraded`
//! (assignable, transfer charges inflated), `Down` (excluded).  Wall
//! downtime (`downtime_secs`) is *measured* between the Down/Up
//! transitions — a diagnostic alongside the deterministic tick
//! timeline, like the store's measured SSD seconds (DESIGN.md §2.6).

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

/// One device's health at the current batch tick.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub enum DeviceHealth {
    /// Normal operation.
    #[default]
    Up,
    /// Still serving, but its modeled transfers run slower (a flaky
    /// link, a throttled device).
    Degraded,
    /// Excluded from assignment, prefetch, and placement until
    /// recovery.
    Down,
}

/// One scheduled fault on the batch-tick timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultEvent {
    /// Device crashes at tick `at`, recovers at tick `until`.
    Down { device: usize, at: u64, until: u64 },
    /// Transfer charges on `device` multiplied by `factor_milli`/1000
    /// while `at <= tick < until` (stored in milli-units so the event
    /// stays `Eq` and exactly round-trippable through the grammar).
    Degrade { device: usize, at: u64, until: u64, factor_milli: u64 },
    /// Prefetches planned for `device` at tick `at` are dropped.
    DropFetch { device: usize, at: u64 },
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultEvent::Down { device, at, until } => {
                write!(f, "down:{device}@{at}..{until}")
            }
            FaultEvent::Degrade { device, at, until, factor_milli } => {
                write!(f, "degrade:{device}@{at}..{until}x{}", *factor_milli as f64 / 1000.0)
            }
            FaultEvent::DropFetch { device, at } => write!(f, "drop:{device}@{at}"),
        }
    }
}

/// A deterministic fault schedule: a list of [`FaultEvent`]s on the
/// batch-tick timeline.  Parse one from the `--fault-plan` grammar or
/// generate one with [`FaultPlan::seeded_random`]; `to_string()`
/// round-trips through [`FaultPlan::parse`].
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, e) in self.events.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{e}")?;
        }
        Ok(())
    }
}

impl FaultPlan {
    /// Parse the `--fault-plan` grammar (see the module docs).  An
    /// empty string is the empty (fault-free) plan.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let event = Self::parse_event(part)
                .with_context(|| format!("bad fault event '{part}'"))?;
            events.push(event);
        }
        Ok(FaultPlan { events })
    }

    fn parse_event(part: &str) -> Result<FaultEvent> {
        let (kind, rest) = part
            .split_once(':')
            .context("expected down:D@T..U, degrade:D@T..UxF, or drop:D@T")?;
        let (dev, when) = rest.split_once('@').context("expected D@<ticks>")?;
        let device: usize = dev.parse().context("bad device index")?;
        match kind {
            "down" => {
                let (at, until) = parse_range(when)?;
                if device == 0 {
                    bail!("device 0 is the primary and cannot go down");
                }
                Ok(FaultEvent::Down { device, at, until })
            }
            "degrade" => {
                let (range, factor) =
                    when.split_once('x').context("expected T..UxF")?;
                let (at, until) = parse_range(range)?;
                let factor: f64 = factor.parse().context("bad degrade factor")?;
                if !(factor > 0.0) {
                    bail!("degrade factor must be > 0");
                }
                Ok(FaultEvent::Degrade {
                    device,
                    at,
                    until,
                    factor_milli: (factor * 1000.0).round() as u64,
                })
            }
            "drop" => {
                let at: u64 = when.parse().context("bad drop tick")?;
                Ok(FaultEvent::DropFetch { device, at })
            }
            other => bail!("unknown fault kind '{other}' (down|degrade|drop)"),
        }
    }

    /// A reproducible random schedule for property tests: 1–3 events
    /// over devices `1..devices` (device 0 never fails) within
    /// `max_tick` batch ticks.
    pub fn seeded_random(seed: u64, devices: usize, max_tick: u64) -> FaultPlan {
        let mut rng = crate::util::rng::Rng::new(seed ^ 0xFA17_FA17);
        let mut events = Vec::new();
        if devices < 2 || max_tick < 2 {
            return FaultPlan { events };
        }
        let n = 1 + rng.usize_below(3);
        for _ in 0..n {
            let device = 1 + rng.usize_below(devices - 1);
            let at = 1 + rng.below(max_tick - 1);
            let until = (at + 1 + rng.below(max_tick)).min(at + max_tick);
            match rng.usize_below(3) {
                0 => events.push(FaultEvent::Down { device, at, until }),
                1 => events.push(FaultEvent::Degrade {
                    device,
                    at,
                    until,
                    factor_milli: 1000 * (2 + rng.below(7)),
                }),
                _ => events.push(FaultEvent::DropFetch { device, at }),
            }
        }
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Every device an event references must exist in a fleet of
    /// `devices` devices (checked when the router adopts the plan).
    pub fn validate(&self, devices: usize) -> Result<()> {
        for e in &self.events {
            let (d, label) = match *e {
                FaultEvent::Down { device, at, until } => {
                    if until <= at {
                        bail!("down:{device}@{at}..{until}: recovery must follow failure");
                    }
                    (device, "down")
                }
                FaultEvent::Degrade { device, at, until, .. } => {
                    if until <= at {
                        bail!("degrade:{device}@{at}..{until}: window must be non-empty");
                    }
                    (device, "degrade")
                }
                FaultEvent::DropFetch { device, .. } => (device, "drop"),
            };
            if d >= devices {
                bail!("{label} event names device {d}, fleet has {devices}");
            }
        }
        Ok(())
    }
}

fn parse_range(text: &str) -> Result<(u64, u64)> {
    let (a, b) = text.split_once("..").context("expected T..U")?;
    let at: u64 = a.parse().context("bad start tick")?;
    let until: u64 = b.parse().context("bad end tick")?;
    if until <= at {
        bail!("tick window {at}..{until} is empty");
    }
    Ok((at, until))
}

/// What one batch-tick advance changed.
#[derive(Debug, Default, Clone)]
pub struct TickTransitions {
    /// devices that transitioned Up/Degraded → Down on this tick
    pub went_down: Vec<usize>,
    /// devices that transitioned Down → Up/Degraded on this tick
    pub recovered: Vec<usize>,
}

impl TickTransitions {
    /// Whether this tick changed any device's Down status — the
    /// router's replan trigger.
    pub fn any(&self) -> bool {
        !self.went_down.is_empty() || !self.recovered.is_empty()
    }
}

/// The runtime side of a [`FaultPlan`]: tracks the batch-tick counter,
/// answers health queries deterministically from (plan, tick), and
/// measures wall downtime across Down/Up transitions.
pub struct FaultInjector {
    plan: FaultPlan,
    devices: usize,
    tick: AtomicU64,
    /// when each currently-Down device went down (wall clock, for the
    /// measured `downtime_secs` diagnostic)
    down_since: Mutex<Vec<Option<Instant>>>,
    downtime_secs: Mutex<f64>,
    device_failures: AtomicU64,
    recoveries: AtomicU64,
    dropped_fetches: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan, devices: usize) -> Self {
        FaultInjector {
            plan,
            devices,
            tick: AtomicU64::new(0),
            down_since: Mutex::new(vec![None; devices]),
            downtime_secs: Mutex::new(0.0),
            device_failures: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            dropped_fetches: AtomicU64::new(0),
        }
    }

    /// The current batch tick (0 before any batch was served).
    pub fn tick(&self) -> u64 {
        self.tick.load(Ordering::Relaxed)
    }

    /// Health of `device` at tick `t`, purely from the plan: Down while
    /// strictly inside a crash window (`at < t < until` — at `t == at`
    /// the device is still assignable but its in-flight lanes fail, see
    /// [`FaultInjector::lane_should_fail`]), Degraded inside a degrade
    /// window, Up otherwise.
    pub fn health_at(&self, device: usize, t: u64) -> DeviceHealth {
        for e in &self.plan.events {
            if let FaultEvent::Down { device: d, at, until } = *e {
                if d == device && at < t && t < until {
                    return DeviceHealth::Down;
                }
            }
        }
        for e in &self.plan.events {
            if let FaultEvent::Degrade { device: d, at, until, .. } = *e {
                if d == device && at <= t && t < until {
                    return DeviceHealth::Degraded;
                }
            }
        }
        DeviceHealth::Up
    }

    /// Health of `device` at the current tick.
    pub fn health(&self, device: usize) -> DeviceHealth {
        self.health_at(device, self.tick())
    }

    /// Advance the batch-tick counter by one and report the Down/Up
    /// transitions it caused.  Called once per served batch by the
    /// router; also maintains the failure/recovery counters and the
    /// measured wall downtime.
    pub fn advance(&self) -> TickTransitions {
        let old = self.tick.fetch_add(1, Ordering::SeqCst);
        let new = old + 1;
        let mut out = TickTransitions::default();
        if self.plan.is_empty() {
            return out;
        }
        let mut down_since = self.down_since.lock().unwrap_or_else(|e| e.into_inner());
        for device in 0..self.devices {
            let was = self.health_at(device, old) == DeviceHealth::Down;
            let is = self.health_at(device, new) == DeviceHealth::Down;
            match (was, is) {
                (false, true) => {
                    self.device_failures.fetch_add(1, Ordering::Relaxed);
                    down_since[device] = Some(Instant::now());
                    out.went_down.push(device);
                }
                (true, false) => {
                    self.recoveries.fetch_add(1, Ordering::Relaxed);
                    if let Some(t0) = down_since[device].take() {
                        *self.downtime_secs.lock().unwrap_or_else(|e| e.into_inner()) +=
                            t0.elapsed().as_secs_f64();
                    }
                    out.recovered.push(device);
                }
                _ => {}
            }
        }
        out
    }

    /// Whether a lane executing on `device` during the current tick
    /// fails (the crash lands mid-batch: the device was assignable when
    /// the layer was routed, its in-flight work is lost and must be
    /// retried on survivors).
    pub fn lane_should_fail(&self, device: usize) -> bool {
        let t = self.tick();
        self.plan.events.iter().any(|e| {
            matches!(*e, FaultEvent::Down { device: d, at, .. } if d == device && at == t)
        })
    }

    /// The multiplier on `device`'s modeled transfer charges at the
    /// current tick (1.0 when healthy).  Accounting only: a degraded
    /// device still computes, so outputs are untouched.
    pub fn degrade_factor(&self, device: usize) -> f64 {
        let t = self.tick();
        let mut factor = 1.0;
        for e in &self.plan.events {
            if let FaultEvent::Degrade { device: d, at, until, factor_milli } = *e {
                if d == device && at <= t && t < until {
                    factor *= factor_milli as f64 / 1000.0;
                }
            }
        }
        factor
    }

    /// Whether prefetches planned for `device` at the current tick are
    /// dropped; counts the drop when they are.
    pub fn drops_fetch(&self, device: usize) -> bool {
        let t = self.tick();
        let dropped = self.plan.events.iter().any(|e| {
            matches!(*e, FaultEvent::DropFetch { device: d, at } if d == device && at == t)
        });
        if dropped {
            self.dropped_fetches.fetch_add(1, Ordering::Relaxed);
        }
        dropped
    }

    /// Devices not Down at the current tick, ascending.  Never empty:
    /// device 0 cannot go down.
    pub fn healthy_devices(&self) -> Vec<usize> {
        (0..self.devices).filter(|&d| self.health(d) != DeviceHealth::Down).collect()
    }

    pub fn device_failures(&self) -> u64 {
        self.device_failures.load(Ordering::Relaxed)
    }

    pub fn recoveries(&self) -> u64 {
        self.recoveries.load(Ordering::Relaxed)
    }

    pub fn dropped_fetches(&self) -> u64 {
        self.dropped_fetches.load(Ordering::Relaxed)
    }

    /// Measured wall seconds devices have spent Down — completed
    /// outages plus the in-flight portion of any device still down.
    pub fn downtime_secs(&self) -> f64 {
        let completed = *self.downtime_secs.lock().unwrap_or_else(|e| e.into_inner());
        let down_since = self.down_since.lock().unwrap_or_else(|e| e.into_inner());
        completed
            + down_since
                .iter()
                .filter_map(|t0| t0.map(|t| t.elapsed().as_secs_f64()))
                .sum::<f64>()
    }

    /// Zero the fault counters and the measured downtime (a new
    /// measurement epoch); the tick counter and plan are state, not
    /// statistics, and carry over.
    pub fn reset_stats(&self) {
        self.device_failures.store(0, Ordering::Relaxed);
        self.recoveries.store(0, Ordering::Relaxed);
        self.dropped_fetches.store(0, Ordering::Relaxed);
        *self.downtime_secs.lock().unwrap_or_else(|e| e.into_inner()) = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trips() {
        let text = "down:1@3..8,degrade:2@1..4x3.5,drop:3@5";
        let plan = FaultPlan::parse(text).unwrap();
        assert_eq!(plan.events().len(), 3);
        assert_eq!(plan.to_string(), text);
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ").unwrap().is_empty());
    }

    #[test]
    fn bad_plans_are_rejected() {
        assert!(FaultPlan::parse("down:0@1..5").is_err(), "primary cannot fail");
        assert!(FaultPlan::parse("down:1@5..5").is_err(), "empty window");
        assert!(FaultPlan::parse("down:1@5..3").is_err(), "inverted window");
        assert!(FaultPlan::parse("degrade:1@1..3x0").is_err(), "zero factor");
        assert!(FaultPlan::parse("explode:1@1..3").is_err(), "unknown kind");
        assert!(FaultPlan::parse("down:1").is_err(), "missing ticks");
        let plan = FaultPlan::parse("down:5@1..3").unwrap();
        assert!(plan.validate(4).is_err(), "device out of fleet range");
        assert!(plan.validate(6).is_ok());
    }

    #[test]
    fn health_timeline_matches_the_grammar_semantics() {
        let inj =
            FaultInjector::new(FaultPlan::parse("down:1@2..5,degrade:2@1..4x2").unwrap(), 3);
        // tick 2: still assignable, but in-flight lanes fail
        assert_eq!(inj.health_at(1, 2), DeviceHealth::Up);
        assert_eq!(inj.health_at(1, 3), DeviceHealth::Down);
        assert_eq!(inj.health_at(1, 4), DeviceHealth::Down);
        assert_eq!(inj.health_at(1, 5), DeviceHealth::Up);
        assert_eq!(inj.health_at(2, 1), DeviceHealth::Degraded);
        assert_eq!(inj.health_at(2, 4), DeviceHealth::Up);
        assert_eq!(inj.health_at(0, 3), DeviceHealth::Up);
    }

    #[test]
    fn advance_reports_transitions_and_measures_downtime() {
        let inj = FaultInjector::new(FaultPlan::parse("down:1@1..3").unwrap(), 2);
        assert!(!inj.advance().any(), "tick 1: lane-fail window, not Down yet");
        assert!(inj.lane_should_fail(1));
        assert!(!inj.lane_should_fail(0));
        let t = inj.advance(); // tick 2: Down
        assert_eq!(t.went_down, vec![1]);
        assert_eq!(inj.health(1), DeviceHealth::Down);
        assert_eq!(inj.healthy_devices(), vec![0]);
        assert!(inj.downtime_secs() >= 0.0);
        let t = inj.advance(); // tick 3: recovered
        assert_eq!(t.recovered, vec![1]);
        assert_eq!(inj.health(1), DeviceHealth::Up);
        assert_eq!(inj.device_failures(), 1);
        assert_eq!(inj.recoveries(), 1);
        assert!(inj.downtime_secs() > 0.0, "a completed outage has wall duration");
    }

    #[test]
    fn degrade_and_drop_consult_the_current_tick() {
        let inj =
            FaultInjector::new(FaultPlan::parse("degrade:1@1..3x4,drop:1@2").unwrap(), 2);
        assert_eq!(inj.degrade_factor(1), 1.0, "tick 0: window not open");
        inj.advance();
        assert!((inj.degrade_factor(1) - 4.0).abs() < 1e-12);
        assert!(!inj.drops_fetch(1), "drop fires only at its tick");
        inj.advance();
        assert!(inj.drops_fetch(1));
        assert_eq!(inj.dropped_fetches(), 1);
        inj.advance();
        assert_eq!(inj.degrade_factor(1), 1.0, "window closed");
    }

    #[test]
    fn seeded_random_is_reproducible_and_valid() {
        for seed in 0..32u64 {
            let a = FaultPlan::seeded_random(seed, 4, 16);
            let b = FaultPlan::seeded_random(seed, 4, 16);
            assert_eq!(a, b);
            a.validate(4).unwrap();
            // the grammar round-trips every generated plan
            assert_eq!(FaultPlan::parse(&a.to_string()).unwrap(), a);
        }
        assert_ne!(
            FaultPlan::seeded_random(1, 4, 16),
            FaultPlan::seeded_random(2, 4, 16),
            "different seeds should differ (overwhelmingly)"
        );
    }
}
