//! The cluster router: per-layer partitioning of expert work across the
//! device fleet, replica steering, cross-device transfer accounting,
//! and placement lifecycle (observe traffic → replan).
//!
//! Device 0 is the **primary**: the dense per-sequence stages and the
//! scatter accumulators live there (exactly like the single-device
//! path), so expert jobs routed to any other device are charged the
//! modeled interconnect cost of shipping their gathered rows out and
//! their outputs back.  Each job goes to exactly **one** device — the
//! least-loaded holder of its expert — so the per-device expert sets of
//! a layer are disjoint by construction, and replicated experts drift
//! to whichever device is lightest in that layer.  Determinism: jobs
//! arrive in ascending expert order and the tie-breaks are total, so
//! the same routing yields the same assignment every time.  Outputs are
//! bit-identical to single-device serving regardless of assignment
//! because assignment only decides *where* an invocation computes,
//! never how results are merged.
//!
//! **Failure model** (DESIGN.md §2.7): the router owns a
//! [`FaultInjector`] on the batch-tick timeline
//! ([`ClusterRouter::advance_batch`], called once per served batch).
//! Down devices are skipped by `assign`, `plan_layer`, and
//! `fetch_planned`; a job whose home is Down steers to a healthy
//! replica (`failovers`) or, with no healthy holder at all, is
//! emergency-promoted onto the least-loaded healthy device
//! (`failover_promotions` — the promotion pays its expert fetch on the
//! modeled timeline via the lane's blocking ensure).  Lanes in flight
//! when a device crashes are recomputed once on a survivor
//! (`retries`, `model::forward::run_cluster_lanes`).  Every Down/Up
//! transition triggers a replan that excludes the dead device or
//! re-admits the recovered one.  None of this can change outputs: the
//! fault schedule only perturbs *where* jobs compute, and the scatter
//! stays on the primary in ascending expert order.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, RwLock};

use anyhow::Result;

use crate::cluster::device::DeviceSet;
use crate::cluster::failure::{DeviceHealth, FaultInjector, FaultPlan};
use crate::cluster::placement::{ActivationProfile, Placement, PlacementPlanner};
use crate::cluster::stats::{ClusterStats, DeviceStats};
use crate::cluster::ClusterConfig;
use crate::coordinator::hash_table::HashTable;
use crate::experts::ExpertKey;
use crate::memory::{CostModel, Tier};
use crate::obs::trace::{self, ArgValue};
use crate::runtime::ModelBundle;

/// One planned cluster prefetch: which expert to warm on which device,
/// plus the cross-layer scheduling metadata the shared bandwidth
/// window's EDF admission consumes (the cluster twin of
/// [`crate::experts::PlannedFetch`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterFetch {
    pub key: ExpertKey,
    pub device: usize,
    /// predicted token heat (fetch-ordering priority)
    pub token_count: usize,
    /// the expert's ladder tier on that device at planning time —
    /// SSD-deep promotions are issued first (they take ~9x as long, so
    /// they must start earliest to hide behind compute)
    pub tier: Tier,
    /// layers before this fetch's layer computes when it was planned
    /// (1 = just-in-time)
    pub layers_ahead: usize,
    /// tier-derived staging lead ([`crate::memory::lead_layers`])
    pub lead_layers: usize,
    /// modeled seconds until the layer computes — the EDF key and the
    /// bound on the fetch's overlap credit
    pub deadline_secs: f64,
    /// per-layer hash-prediction confidence (mean top-rank alpha)
    pub confidence: f64,
}

impl crate::experts::ScheduledFetch for ClusterFetch {
    fn key(&self) -> ExpertKey {
        self.key
    }
    fn tier(&self) -> Tier {
        self.tier
    }
    fn token_count(&self) -> usize {
        self.token_count
    }
    fn deadline_secs(&self) -> f64 {
        self.deadline_secs
    }
    fn confidence(&self) -> f64 {
        self.confidence
    }
    fn layers_ahead(&self) -> usize {
        self.layers_ahead
    }
}

/// See the module docs.  Shared concurrently by the worker-pool lanes,
/// the layer-ahead warmer, and the serving front-end.
pub struct ClusterRouter {
    set: DeviceSet,
    planner: PlacementPlanner,
    placement: RwLock<Placement>,
    profile: Mutex<ActivationProfile>,
    /// tables observed when the current placement was planned
    observed_at_plan: AtomicU64,
    /// per-device token rows dispatched (load-imbalance numerator)
    rows: Vec<AtomicU64>,
    /// per-device dispatch-bucket units dispatched: each job's rows
    /// rounded up to the bucket chunks the expert kernel actually pads
    /// to — the *compute* the lane balancer weighs
    bucket_units: Vec<AtomicU64>,
    cross_device_bytes: AtomicU64,
    interconnect_secs: Mutex<f64>,
    replans: AtomicU64,
    /// deterministic fault timeline + per-device health (§2.7)
    injector: FaultInjector,
    /// jobs rerouted because their home device was Down
    failovers: AtomicU64,
    /// failovers that found no healthy holder and promoted the expert
    /// onto a fresh device
    failover_promotions: AtomicU64,
    /// lanes lost to a mid-batch crash and recomputed on survivors
    retries: AtomicU64,
    d_model: usize,
    moe_blocks: Vec<usize>,
    /// the served model's topology — bucket geometry for lane weighting
    topo: std::sync::Arc<crate::runtime::Topology>,
    /// tier-ladder cost table of the device caches (all identical) —
    /// deadline/lead arithmetic for the staging scheduler
    costs: crate::memory::TierCosts,
    /// simulated (paper-scale) bytes of one expert
    sim_expert_bytes: usize,
}

impl ClusterRouter {
    /// Build the fleet and a cold-start placement (deterministic
    /// round-robin; replaced by data-aware plans as traffic arrives —
    /// or immediately via [`ClusterRouter::observe`] + `replan_now`).
    pub fn new(bundle: &ModelBundle, cfg: &ClusterConfig) -> Result<Self> {
        let topo = &bundle.topology;
        let real_expert_bytes = bundle.weights.expert_bytes(topo.moe_blocks[0], 0)?;
        let cost_model = CostModel::paper_scale(real_expert_bytes);
        let expert_sim_bytes = cost_model.sim_bytes(real_expert_bytes);
        let set = DeviceSet::new(
            cfg.devices,
            cfg.budget_per_device,
            real_expert_bytes,
            &cfg.policy,
            cfg.real_sleep,
            cfg.link.clone(),
            cfg.host_ram_budget,
            &cfg.ram_policy,
            cfg.host_bw,
        )?;
        let capacity = (cfg.budget_per_device / expert_sim_bytes.max(1)).max(1);
        let planner = PlacementPlanner::new(cfg.devices, cfg.replicate_top, capacity)
            .with_min_replicas(cfg.min_replicas);
        let fault_plan = FaultPlan::parse(&cfg.fault_plan)?;
        fault_plan.validate(cfg.devices)?;
        let placement = planner.plan(topo, &ActivationProfile::default());
        let rows = (0..cfg.devices).map(|_| AtomicU64::new(0)).collect();
        let bucket_units = (0..cfg.devices).map(|_| AtomicU64::new(0)).collect();
        Ok(ClusterRouter {
            set,
            planner,
            placement: RwLock::new(placement),
            profile: Mutex::new(ActivationProfile::default()),
            observed_at_plan: AtomicU64::new(0),
            rows,
            bucket_units,
            cross_device_bytes: AtomicU64::new(0),
            interconnect_secs: Mutex::new(0.0),
            replans: AtomicU64::new(0),
            injector: FaultInjector::new(fault_plan, cfg.devices),
            failovers: AtomicU64::new(0),
            failover_promotions: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            d_model: topo.d_model,
            moe_blocks: topo.moe_blocks.clone(),
            topo: bundle.topology.clone(),
            costs: cost_model.tier_costs(),
            sim_expert_bytes: cost_model.sim_expert_bytes,
        })
    }

    /// The box-wide staging bandwidth window shared by every device.
    pub fn bandwidth_window(&self) -> std::sync::Arc<crate::experts::BandwidthWindow> {
        self.set.bandwidth_window()
    }

    /// Cost table + simulated expert bytes the staging scheduler's
    /// deadline/lead arithmetic runs on.
    pub fn staging_costs(&self) -> (crate::memory::TierCosts, usize) {
        (self.costs.clone(), self.sim_expert_bytes)
    }

    pub fn devices(&self) -> usize {
        self.set.len()
    }

    pub fn device_cache(&self, id: usize) -> &crate::experts::SharedExpertCache {
        &self.set.device(id).cache
    }

    pub fn device_set(&self) -> &DeviceSet {
        &self.set
    }

    /// Snapshot of the current placement (tests, diagnostics).
    pub fn placement(&self) -> Placement {
        self.placement.read().unwrap().clone()
    }

    /// Fold a batch's hash predictions into the activation profile.
    pub fn observe(&self, pairs: &[(&HashTable, &[f32])], k_used: usize) {
        let mut profile = self.profile.lock().unwrap();
        for &(table, mask) in pairs {
            profile.observe_table(table, &self.moe_blocks, k_used, mask);
        }
    }

    /// Re-plan placement from everything observed so far, on the
    /// currently healthy devices only (Down devices hold nothing until
    /// they recover).  Takes the write lock briefly; in-flight
    /// assignments finish on the old plan (correctness does not depend
    /// on which plan routed a job).
    pub fn replan_now(&self, bundle: &ModelBundle) {
        let profile = self.profile.lock().unwrap().clone();
        let healthy = self.injector.healthy_devices();
        let new_plan = self.planner.plan_healthy(&bundle.topology, &profile, &healthy);
        *self.placement.write().unwrap() = new_plan;
        self.observed_at_plan.store(profile.observed_tables(), Ordering::Relaxed);
        self.replans.fetch_add(1, Ordering::Relaxed);
    }

    /// Advance the fault timeline by one batch tick — called exactly
    /// once per served batch by every serving front-end (pipeline,
    /// scheduler replay, TCP server).  A device failing or recovering
    /// on this tick triggers an immediate replan: failure evacuates its
    /// placement entries to the survivors, recovery re-admits it.
    ///
    /// The evacuation is accounted before the replan erases the
    /// evidence: every placement entry homed on a device that just went
    /// down is a failover — to a healthy replica when another holder
    /// exists, else an emergency promotion (the replan hands the expert
    /// a fresh healthy home, which pays the weight fetch on first use).
    pub fn advance_batch(&self, bundle: &ModelBundle) {
        let transitions = self.injector.advance();
        if !transitions.any() {
            return;
        }
        if trace::enabled() {
            for &d in &transitions.went_down {
                trace::instant(
                    "device_down",
                    "cluster",
                    trace::device_pid(d),
                    vec![("device", ArgValue::U(d as u64))],
                );
            }
            for &d in &transitions.recovered {
                trace::instant(
                    "device_up",
                    "cluster",
                    trace::device_pid(d),
                    vec![("device", ArgValue::U(d as u64))],
                );
            }
        }
        if !transitions.went_down.is_empty() {
            let placement = self.placement.read().unwrap();
            for key in placement.keys() {
                if !transitions.went_down.contains(&placement.home_of(key)) {
                    continue;
                }
                self.failovers.fetch_add(1, Ordering::Relaxed);
                let survives = placement
                    .holders(key)
                    .iter()
                    .any(|&d| self.injector.health(d) != DeviceHealth::Down);
                if !survives {
                    self.failover_promotions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.replan_now(bundle);
    }

    /// The fault timeline and per-device health (diagnostics, tests).
    pub fn injector(&self) -> &FaultInjector {
        &self.injector
    }

    /// Whether a lane on `device` crashes mid-batch at the current tick
    /// (consulted by `run_cluster_lanes` before it merges results).
    pub fn lane_should_fail(&self, device: usize) -> bool {
        self.injector.lane_should_fail(device)
    }

    /// Pick the survivor that recomputes a lost job `(block, expert,
    /// rows)` after `failed` crashed mid-batch: the lowest-id holder
    /// that is healthy and not itself crashing this tick, else the
    /// primary (which can never fail).  Counts the retry and records
    /// the survivor's extra load — the lost work consumed the dead
    /// device AND the survivor, and the balancer should see both.
    pub fn retry_assignment(
        &self,
        block: usize,
        expert: usize,
        rows: usize,
        failed: usize,
    ) -> usize {
        let key = ExpertKey::new(block, expert);
        let placement = self.placement.read().unwrap();
        let dev = placement
            .holders(&key)
            .iter()
            .copied()
            .filter(|&d| {
                d != failed
                    && self.injector.health(d) != DeviceHealth::Down
                    && !self.injector.lane_should_fail(d)
            })
            .min()
            .unwrap_or(0);
        drop(placement);
        self.retries.fetch_add(1, Ordering::Relaxed);
        self.rows[dev].fetch_add(rows as u64, Ordering::Relaxed);
        self.bucket_units[dev].fetch_add(self.job_bucket_units(rows) as u64, Ordering::Relaxed);
        dev
    }

    /// Re-plan when the profile has grown meaningfully since the last
    /// plan (first traffic, then every doubling) — the serving
    /// front-end's steady-state entry point.
    pub fn replan_if_due(&self, bundle: &ModelBundle) {
        let observed = self.profile.lock().unwrap().observed_tables();
        let at_plan = self.observed_at_plan.load(Ordering::Relaxed);
        if observed > 0 && (at_plan == 0 || observed >= 2 * at_plan) {
            self.replan_now(bundle);
        }
    }

    /// Dispatch-bucket compute weight of one job with `rows` gathered
    /// rows: the expert kernel pads every chunk up to a bucket
    /// (`expert_T{bucket}` artifacts), so a 5-row job on buckets
    /// {2, 4, 8} costs 8 bucket units, not 5 — rows round UP.  This is
    /// the unit the lane balancer weighs, because it is what each
    /// device actually computes.  Buckets resolve through the
    /// topology's own [`crate::runtime::Topology::bucket_for`] (the rule
    /// the chunk loop in `model::forward` uses), assuming the adaptive
    /// bucket path — the SiDA pipeline never dispatches cluster lanes
    /// with `fixed_bucket`, which belongs to the all-resident baselines.
    fn job_bucket_units(&self, rows: usize) -> usize {
        let mut units = 0usize;
        let mut remaining = rows;
        while remaining > 0 {
            let bucket = self.topo.bucket_for(remaining);
            units += bucket;
            remaining -= remaining.min(bucket);
        }
        units
    }

    /// Assign each job `(expert, row_count)` of one MoE layer (ascending
    /// expert order) to a device: the **least-loaded** holder of that
    /// expert — load measured in dispatch-bucket units (rows round up to
    /// the bucket the kernel pads to), so lanes balance actual compute
    /// rather than raw row counts — ties on the lower device id.  Also
    /// records per-device row/bucket-unit loads.  (Tier-ladder traffic
    /// needs no recording here: each device's cache drives its own
    /// ledger when the lane actually resolves residency.)
    pub fn assign(&self, block: usize, jobs: &[(usize, usize)]) -> Vec<usize> {
        let placement = self.placement.read().unwrap();
        let mut loads = vec![0usize; self.set.len()];
        let mut out = Vec::with_capacity(jobs.len());
        let mut units = Vec::with_capacity(jobs.len());
        for &(expert, rows) in jobs {
            let key = ExpertKey::new(block, expert);
            // Down devices are invisible to routing; a job whose home is
            // Down steers to a healthy replica holder (failover).  With
            // no healthy holder at all the expert is emergency-promoted:
            // routed to the least-loaded healthy device, where the
            // lane's blocking ensure fetches the weights — charged on
            // the modeled transfer timeline like any cold miss.  Either
            // way only *where* the job computes changes, so outputs stay
            // bit-identical to the fault-free run.
            let dev = match placement
                .holders(&key)
                .iter()
                .copied()
                .filter(|&d| self.injector.health(d) != DeviceHealth::Down)
                .min_by_key(|&d| (loads[d], d))
            {
                Some(d) => {
                    let home = placement.home_of(&key);
                    if self.injector.health(home) == DeviceHealth::Down {
                        self.failovers.fetch_add(1, Ordering::Relaxed);
                        if trace::enabled() {
                            trace::instant(
                                "failover",
                                "cluster",
                                trace::device_pid(d),
                                vec![
                                    ("block", ArgValue::U(block as u64)),
                                    ("expert", ArgValue::U(expert as u64)),
                                    ("down_home", ArgValue::U(home as u64)),
                                ],
                            );
                        }
                    }
                    d
                }
                None => {
                    self.failovers.fetch_add(1, Ordering::Relaxed);
                    self.failover_promotions.fetch_add(1, Ordering::Relaxed);
                    let d = (0..self.set.len())
                        .filter(|&d| self.injector.health(d) != DeviceHealth::Down)
                        .min_by_key(|&d| (loads[d], d))
                        .unwrap_or(0);
                    if trace::enabled() {
                        trace::instant(
                            "failover_promotion",
                            "cluster",
                            trace::device_pid(d),
                            vec![
                                ("block", ArgValue::U(block as u64)),
                                ("expert", ArgValue::U(expert as u64)),
                            ],
                        );
                    }
                    d
                }
            };
            let w = self.job_bucket_units(rows);
            loads[dev] += w;
            units.push(w);
            out.push(dev);
        }
        drop(placement);
        for ((&(_, rows), &dev), &w) in jobs.iter().zip(out.iter()).zip(units.iter()) {
            self.rows[dev].fetch_add(rows as u64, Ordering::Relaxed);
            self.bucket_units[dev].fetch_add(w as u64, Ordering::Relaxed);
        }
        out
    }

    /// Charge the modeled interconnect cost of running `n_rows` gathered
    /// rows on `device`: rows ship out and outputs ship back (2x), one
    /// fabric hop each way.  The primary computes in place and pays
    /// nothing.  Returns the modeled seconds (also accumulated in the
    /// cluster stats).
    pub fn charge_activation_transfer(&self, device: usize, n_rows: usize) -> f64 {
        if device == 0 || n_rows == 0 {
            return 0.0;
        }
        let bytes = 2 * n_rows * self.d_model * std::mem::size_of::<f32>();
        // a Degraded device still computes (outputs untouched) but its
        // fabric runs slower: the modeled charge is inflated (§2.7)
        let secs = self.set.link_secs(bytes) * self.injector.degrade_factor(device);
        self.cross_device_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        *self.interconnect_secs.lock().unwrap() += secs;
        if trace::enabled() {
            trace::instant(
                "interconnect",
                "cluster",
                trace::device_pid(device),
                vec![
                    ("rows", ArgValue::U(n_rows as u64)),
                    ("bytes", ArgValue::U(bytes as u64)),
                    ("modeled_secs", ArgValue::F(secs)),
                ],
            );
        }
        secs
    }

    /// Plan one MoE layer's cluster prefetch: every predicted expert
    /// missing from **any** of its holder devices — deepest ladder tier
    /// first (an SSD-deep promotion costs ~9x a RAM-resident one on
    /// that device's ladder, so it must start earliest), then hottest.
    /// Replicas are warmed on every holder — replication means the
    /// weights live on several devices, so the router can steer traffic
    /// freely without a cold-start penalty.  `layers_ahead` sets every
    /// fetch's deadline ([`crate::memory::fetch_deadline_secs`]);
    /// `max_lead` clamps the tier-derived lead (`--prefetch-depth`).
    pub fn plan_layer(
        &self,
        pairs: &[(&HashTable, &[f32])],
        block: usize,
        layer: usize,
        k_used: usize,
        layers_ahead: usize,
        max_lead: usize,
    ) -> Vec<ClusterFetch> {
        let counts = crate::experts::predicted_expert_counts(pairs, layer, k_used);
        let experts_in_layer = counts.len();
        let confidence = crate::experts::prefetch::layer_confidence(pairs, layer);
        let deadline_secs = crate::memory::fetch_deadline_secs(
            &self.costs,
            self.sim_expert_bytes,
            experts_in_layer,
            layers_ahead.max(1),
        );
        let placement = self.placement.read().unwrap();
        let mut plan = Vec::new();
        for (expert, token_count) in counts {
            let key = ExpertKey::new(block, expert);
            for &device in placement.holders(&key) {
                if self.injector.health(device) == DeviceHealth::Down {
                    continue; // never warm a dead device
                }
                let tier = self.set.device(device).tier_of(&key);
                if tier != Tier::Device {
                    plan.push(ClusterFetch {
                        key,
                        device,
                        token_count,
                        tier,
                        layers_ahead: layers_ahead.max(1),
                        lead_layers: crate::memory::lead_layers(
                            &self.costs,
                            tier,
                            self.sim_expert_bytes,
                            experts_in_layer,
                            max_lead,
                        ),
                        deadline_secs,
                        confidence,
                    });
                }
            }
        }
        plan.sort_by(|a, b| {
            b.tier
                .cmp(&a.tier)
                .then(b.token_count.cmp(&a.token_count))
                .then(a.key.cmp(&b.key))
                .then(a.device.cmp(&b.device))
        });
        plan
    }

    /// Execute a cluster fetch plan on the prefetch timeline
    /// (non-blocking; resident entries cost one read-path hit).  The
    /// plan is first admitted earliest-deadline-first into the box-wide
    /// bandwidth window ([`crate::experts::admit_edf`]) — all devices
    /// draw staging from the one shared host link, so admission and
    /// backlog are global, not per-device.  Each device's cache drives
    /// its own residency ledger as it fetches — there is no separate
    /// promote bookkeeping to drift.
    pub fn fetch_planned(&self, bundle: &ModelBundle, plan: &[ClusterFetch]) -> Result<()> {
        if plan.is_empty() {
            return Ok(());
        }
        let window = self.set.bandwidth_window();
        let rate = window.rate();
        let adm = crate::experts::admit_edf(plan.to_vec(), window.backlog_secs(), |f| {
            self.costs.promote_secs(f.tier, self.sim_expert_bytes) * rate
        });
        window.note_deferred(adm.deferred as u64);
        let t_stage = trace::begin();
        for fetch in &adm.admit {
            // a plan can outlive a health transition (it was computed at
            // an earlier tick); drop-fetch faults swallow the prefetch
            // entirely — the expert degrades to a later blocking miss,
            // which is slower but never wrong
            if self.injector.health(fetch.device) == DeviceHealth::Down
                || self.injector.drops_fetch(fetch.device)
            {
                continue;
            }
            let key = fetch.key;
            let real = bundle.weights.expert_bytes(key.block, key.expert)?;
            let _ = self
                .set
                .device(fetch.device)
                .cache
                .ensure_deadline(key, real, fetch.deadline_secs, || {
                    crate::runtime::stage_expert_parts(
                        &bundle.engine,
                        &bundle.weights,
                        key.block,
                        key.expert,
                    )
                })?;
        }
        if trace::enabled() {
            trace::complete(
                "prefetch_stage",
                "prefetch",
                trace::host_pid(),
                t_stage,
                vec![
                    ("experts", ArgValue::U(adm.admit.len() as u64)),
                    ("deferred", ArgValue::U(adm.deferred as u64)),
                    ("lead_layers", ArgValue::U(adm.max_lead_layers as u64)),
                    ("deadline_slack_ms", ArgValue::F(adm.min_slack_secs.unwrap_or(0.0) * 1e3)),
                ],
            );
        }
        Ok(())
    }

    /// Warm one MoE layer's predicted experts on their holder devices
    /// (the cluster twin of the single-device `warm_layer`).
    #[allow(clippy::too_many_arguments)]
    pub fn warm_layer(
        &self,
        bundle: &ModelBundle,
        pairs: &[(&HashTable, &[f32])],
        block: usize,
        layer: usize,
        k_used: usize,
        layers_ahead: usize,
        max_lead: usize,
    ) -> Result<()> {
        let plan = self.plan_layer(pairs, block, layer, k_used, layers_ahead, max_lead);
        self.fetch_planned(bundle, &plan)
    }

    /// Cluster-wide statistics snapshot.
    pub fn stats(&self) -> ClusterStats {
        let placement = self.placement.read().unwrap();
        let devices = self
            .set
            .iter()
            .map(|d| DeviceStats {
                device: d.id,
                budget_bytes: d.cache.budget(),
                used_bytes: d.cache.used(),
                peak_bytes: d.cache.peak(),
                resident_experts: d.cache.resident_count(),
                assigned_experts: placement.assigned_to(d.id),
                rows: self.rows[d.id].load(Ordering::Relaxed),
                bucket_units: self.bucket_units[d.id].load(Ordering::Relaxed),
                cache: d.cache.stats(),
                hierarchy: d.hierarchy_stats(),
                health: self.injector.health(d.id),
            })
            .collect();
        ClusterStats {
            devices,
            replicated_entries: placement.replicated_entries(),
            cross_device_bytes: self.cross_device_bytes.load(Ordering::Relaxed),
            interconnect_secs: *self.interconnect_secs.lock().unwrap(),
            replans: self.replans.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            failover_promotions: self.failover_promotions.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            dropped_fetches: self.injector.dropped_fetches(),
            device_failures: self.injector.device_failures(),
            recoveries: self.injector.recoveries(),
            downtime_secs: self.injector.downtime_secs(),
        }
    }

    /// Reset the serving counters (between bench phases): device cache
    /// stats, row loads, interconnect totals.  Placement and residency
    /// stay — a reset separates measurement epochs, it does not cool
    /// the fleet.
    pub fn reset_stats(&self) {
        self.set.reset_stats();
        for r in &self.rows {
            r.store(0, Ordering::Relaxed);
        }
        for u in &self.bucket_units {
            u.store(0, Ordering::Relaxed);
        }
        self.cross_device_bytes.store(0, Ordering::Relaxed);
        *self.interconnect_secs.lock().unwrap() = 0.0;
        self.failovers.store(0, Ordering::Relaxed);
        self.failover_promotions.store(0, Ordering::Relaxed);
        self.retries.store(0, Ordering::Relaxed);
        self.injector.reset_stats();
    }

    /// Every device cache's internal consistency (tests).
    pub fn check_invariants(&self) -> Result<()> {
        for d in self.set.iter() {
            d.cache.check_invariants()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn router(devices: usize, replicate_top: usize) -> (std::sync::Arc<ModelBundle>, ClusterRouter) {
        let b = testkit::tiny_bundle();
        let cfg = ClusterConfig {
            devices,
            replicate_top,
            ..ClusterConfig::default()
        };
        let r = ClusterRouter::new(&b, &cfg).unwrap();
        (b, r)
    }

    #[test]
    fn assign_covers_every_job_exactly_once() {
        let (b, r) = router(3, 1);
        let block = b.topology.moe_blocks[0];
        let jobs: Vec<(usize, usize)> = (0..8).map(|e| (e, 2 + e)).collect();
        let assign = r.assign(block, &jobs);
        assert_eq!(assign.len(), jobs.len());
        assert!(assign.iter().all(|&d| d < 3));
        // disjoint per-device expert sets: one device per job
        let stats = r.stats();
        let total_rows: u64 = stats.devices.iter().map(|d| d.rows).sum();
        assert_eq!(total_rows, jobs.iter().map(|&(_, n)| n as u64).sum::<u64>());
    }

    #[test]
    fn assignment_is_deterministic() {
        let (b, r) = router(4, 2);
        let block = b.topology.moe_blocks[0];
        let jobs: Vec<(usize, usize)> = (0..8).map(|e| (e, 1 + (e * 7) % 5)).collect();
        assert_eq!(r.assign(block, &jobs), r.assign(block, &jobs));
    }

    #[test]
    fn replicated_expert_goes_to_lightest_holder() {
        let (b, r) = router(2, 1);
        // data-aware plan: replicate_top=1 replicates the hottest
        // expert of the layer onto both devices
        let builder = crate::coordinator::HashBuilder::new(&b, testkit::TINY_PROFILE).unwrap();
        let reqs = testkit::tiny_trace(&b, 6, 21);
        let masks: Vec<Vec<f32>> = reqs.iter().map(|q| q.mask()).collect();
        let tables: Vec<_> =
            reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();
        let pairs: Vec<(&HashTable, &[f32])> =
            tables.iter().zip(masks.iter()).map(|(t, m)| (t, m.as_slice())).collect();
        r.observe(&pairs, 1);
        r.replan_now(&b);
        let placement = r.placement();
        let hot = placement
            .keys()
            .copied()
            .find(|k| placement.holders(k).len() == 2)
            .expect("replicate_top=1 must produce a replica");
        let home = placement.home_of(&hot);
        // another expert homed on the same device as the replica's home
        let pinned = placement
            .keys()
            .copied()
            .find(|k| k.block == hot.block && *k != hot && placement.home_of(k) == home)
            .expect("4 homes per device: a co-homed expert exists");
        // a heavy job lands on `home` first; the replicated expert must
        // steer to the other, lighter holder
        let assign = r.assign(hot.block, &[(pinned.expert, 100), (hot.expert, 1)]);
        assert_eq!(assign[0], home, "single-holder expert must run at home");
        assert_ne!(assign[1], home, "replica steering failed: {assign:?}");
    }

    #[test]
    fn lanes_balance_bucket_units_not_raw_rows() {
        // tiny-bundle buckets are {2, 4, 8, 32}: a 5-row job costs 8
        // bucket units (rows round UP to the kernel's padded chunk), the
        // same as a 6-row job.  Construct a replica-steering decision
        // where the two rules disagree: device 0 carries a 6-row job
        // (8 units), device 1 a 5-row job (8 units).  Bucket units say
        // the lanes tie (the replica breaks the tie to device 0); raw
        // rows say device 1 is lighter (5 < 6) and would steer there —
        // so a regression to raw-row balancing fails this assert.
        let (b, r) = router(2, 1);
        let builder = crate::coordinator::HashBuilder::new(&b, testkit::TINY_PROFILE).unwrap();
        let reqs = testkit::tiny_trace(&b, 6, 21);
        let masks: Vec<Vec<f32>> = reqs.iter().map(|q| q.mask()).collect();
        let tables: Vec<_> =
            reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();
        let pairs: Vec<(&HashTable, &[f32])> =
            tables.iter().zip(masks.iter()).map(|(t, m)| (t, m.as_slice())).collect();
        r.observe(&pairs, 1);
        r.replan_now(&b);
        let placement = r.placement();
        let hot = placement
            .keys()
            .copied()
            .find(|k| placement.holders(k).len() == 2)
            .expect("replicate_top=1 must produce a replica");
        // single-holder experts homed on device 0 and on device 1
        let homed_on = |dev: usize| {
            placement
                .keys()
                .copied()
                .find(|k| {
                    let h = placement.holders(k);
                    k.block == hot.block && *k != hot && h.len() == 1 && h[0] == dev
                })
                .unwrap_or_else(|| panic!("no single-holder expert homed on {dev}"))
        };
        let e0 = homed_on(0);
        let e1 = homed_on(1);
        let assign =
            r.assign(hot.block, &[(e0.expert, 6), (e1.expert, 5), (hot.expert, 1)]);
        assert_eq!(assign[0], 0, "single-holder expert must run at home");
        assert_eq!(assign[1], 1, "single-holder expert must run at home");
        assert_eq!(
            assign[2], 0,
            "8-vs-8 bucket units tie -> lower id; raw rows (6 vs 5) would pick 1"
        );
        let stats = r.stats();
        // 6 -> 8, 5 -> 8, 1 -> 2 bucket units; rows stay raw
        let total_units: u64 = stats.devices.iter().map(|d| d.bucket_units).sum();
        assert_eq!(total_units, 18);
        let total_rows: u64 = stats.devices.iter().map(|d| d.rows).sum();
        assert_eq!(total_rows, 12);
    }

    #[test]
    fn bucket_weighting_rounds_rows_up_to_chunks() {
        let (b, r) = router(2, 0);
        let block = b.topology.moe_blocks[0];
        // 9 rows on buckets {2,4,8,32}: the smallest bucket that fits 9
        // is 32 (the kernel pads the whole chunk) -> 32 units; 3 rows
        // -> 4 units
        let _ = r.assign(block, &[(0, 9), (1, 3)]);
        let stats = r.stats();
        let total_units: u64 = stats.devices.iter().map(|d| d.bucket_units).sum();
        assert_eq!(total_units, 36);
    }

    #[test]
    fn plan_layer_carries_scheduling_metadata_and_charges_shared_window() {
        let (b, r) = router(2, 1);
        let builder = crate::coordinator::HashBuilder::new(&b, testkit::TINY_PROFILE).unwrap();
        let reqs = testkit::tiny_trace(&b, 2, 5);
        let masks: Vec<Vec<f32>> = reqs.iter().map(|q| q.mask()).collect();
        let tables: Vec<_> =
            reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();
        let pairs: Vec<(&HashTable, &[f32])> =
            tables.iter().zip(masks.iter()).map(|(t, m)| (t, m.as_slice())).collect();
        let plan = r.plan_layer(&pairs, b.topology.moe_blocks[0], 0, 1, 2, 3);
        assert!(!plan.is_empty(), "cold fleet: the predicted union is all missing");
        for f in &plan {
            assert_eq!(f.layers_ahead, 2);
            assert!((1..=3).contains(&f.lead_layers));
            assert!(f.deadline_secs > 0.0);
            assert!((0.0..=1.0).contains(&f.confidence));
        }
        r.fetch_planned(&b, &plan).unwrap();
        assert!(
            r.bandwidth_window().backlog_secs() > 0.0,
            "cluster staging must queue on the box-wide shared window"
        );
    }

    #[test]
    fn interconnect_charged_only_off_primary() {
        let (_, r) = router(2, 0);
        assert_eq!(r.charge_activation_transfer(0, 100), 0.0);
        let secs = r.charge_activation_transfer(1, 100);
        assert!(secs > 0.0);
        let stats = r.stats();
        assert!(stats.cross_device_bytes > 0);
        assert!((stats.interconnect_secs - secs).abs() < 1e-12);
    }

    #[test]
    fn replan_if_due_fires_on_first_and_doubled_traffic() {
        let (b, r) = router(2, 1);
        assert_eq!(r.stats().replans, 0);
        let builder = crate::coordinator::HashBuilder::new(&b, testkit::TINY_PROFILE).unwrap();
        let reqs = testkit::tiny_trace(&b, 4, 9);
        let masks: Vec<Vec<f32>> = reqs.iter().map(|q| q.mask()).collect();
        let tables: Vec<_> =
            reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();
        let pairs: Vec<(&HashTable, &[f32])> =
            tables.iter().zip(masks.iter()).map(|(t, m)| (t, m.as_slice())).collect();
        r.observe(&pairs[..1], 1);
        r.replan_if_due(&b);
        assert_eq!(r.stats().replans, 1, "first observation must trigger a plan");
        r.replan_if_due(&b);
        assert_eq!(r.stats().replans, 1, "no growth, no replan");
        r.observe(&pairs[1..], 1);
        r.replan_if_due(&b);
        assert_eq!(r.stats().replans, 2, "doubled traffic must replan");
    }

    fn faulty_router(
        devices: usize,
        replicate_top: usize,
        min_replicas: usize,
        fault_plan: &str,
    ) -> (std::sync::Arc<ModelBundle>, ClusterRouter) {
        let b = testkit::tiny_bundle();
        let cfg = ClusterConfig {
            devices,
            replicate_top,
            min_replicas,
            fault_plan: fault_plan.into(),
            ..ClusterConfig::default()
        };
        let r = ClusterRouter::new(&b, &cfg).unwrap();
        (b, r)
    }

    #[test]
    fn bad_fault_plans_are_rejected_at_router_construction() {
        let b = testkit::tiny_bundle();
        for plan in ["down:7@1..3", "down:0@1..3", "gibberish"] {
            let cfg = ClusterConfig {
                devices: 2,
                fault_plan: plan.into(),
                ..ClusterConfig::default()
            };
            assert!(ClusterRouter::new(&b, &cfg).is_err(), "plan '{plan}' must be rejected");
        }
    }

    #[test]
    fn down_device_is_evacuated_and_readmitted() {
        let (b, r) = faulty_router(2, 0, 1, "down:1@1..3");
        let block = b.topology.moe_blocks[0];
        r.advance_batch(&b); // tick 1: crash lands, device still assigned
        assert!(r.lane_should_fail(1));
        r.advance_batch(&b); // tick 2: Down — replan evacuates device 1
        assert_eq!(r.placement().assigned_to(1), 0, "Down device must hold nothing");
        let assign = r.assign(block, &(0..8).map(|e| (e, 2)).collect::<Vec<_>>());
        assert!(assign.iter().all(|&d| d == 0), "all jobs must avoid the Down device");
        r.advance_batch(&b); // tick 3: recovered — replan re-admits
        assert!(r.placement().assigned_to(1) > 0, "recovered device must be re-admitted");
        let s = r.stats();
        assert_eq!(s.device_failures, 1);
        assert_eq!(s.recoveries, 1);
        assert!(s.downtime_secs > 0.0, "a completed outage has measured wall duration");
        assert!(s.replans >= 2, "failure and recovery each replan");
        // the cold round-robin plan homed 4 of 8 experts on device 1;
        // all were evacuated at the down transition, and with no
        // replicas each evacuation is an emergency promotion
        assert_eq!(s.failovers, 4);
        assert_eq!(s.failover_promotions, 4);
        r.check_invariants().unwrap();
        r.placement().check_invariants(&b.topology).unwrap();
    }

    #[test]
    fn stale_placement_fails_over_without_promotion_when_replicas_exist() {
        // min_replicas=2 on 2 devices: every hot expert lives on both.
        // Freeze the placement *before* the crash (no replan between) so
        // assignment must fail over on the stale plan: the home is Down
        // but a healthy replica exists -> failovers without promotions.
        let (b, r) = faulty_router(2, 0, 2, "down:1@1..9");
        let builder = crate::coordinator::HashBuilder::new(&b, testkit::TINY_PROFILE).unwrap();
        let reqs = testkit::tiny_trace(&b, 6, 21);
        let masks: Vec<Vec<f32>> = reqs.iter().map(|q| q.mask()).collect();
        let tables: Vec<_> =
            reqs.iter().map(|q| builder.build(q.id, &q.ids).unwrap()).collect();
        let pairs: Vec<(&HashTable, &[f32])> =
            tables.iter().zip(masks.iter()).map(|(t, m)| (t, m.as_slice())).collect();
        r.observe(&pairs, 1);
        r.replan_now(&b);
        let placement = r.placement();
        let hot: Vec<usize> = placement
            .keys()
            .copied()
            .filter(|k| placement.home_of(k) == 1 && placement.holders(k).len() == 2)
            .map(|k| k.expert)
            .collect();
        assert!(!hot.is_empty(), "min_replicas=2 must replicate hot experts");
        // advance past the crash WITHOUT letting advance_batch replan
        r.injector().advance();
        r.injector().advance();
        assert_eq!(r.injector().health(1), DeviceHealth::Down);
        let jobs: Vec<(usize, usize)> = hot.iter().map(|&e| (e, 2)).collect();
        let assign = r.assign(b.topology.moe_blocks[0], &jobs);
        assert!(assign.iter().all(|&d| d == 0));
        let s = r.stats();
        assert_eq!(s.failovers, hot.len() as u64);
        assert_eq!(s.failover_promotions, 0, "replicas exist: no promotion needed");
    }

    #[test]
    fn sole_holder_down_triggers_emergency_promotion() {
        // replicate_top=0, min_replicas=1: every expert has exactly one
        // holder.  Down the device on the stale plan and jobs for its
        // experts must be emergency-promoted.
        let (b, r) = faulty_router(2, 0, 1, "down:1@1..9");
        let placement = r.placement();
        let block = b.topology.moe_blocks[0];
        let orphaned: Vec<usize> = placement
            .keys()
            .copied()
            .filter(|k| k.block == block && placement.home_of(k) == 1)
            .map(|k| k.expert)
            .collect();
        assert!(!orphaned.is_empty());
        r.injector().advance();
        r.injector().advance();
        let jobs: Vec<(usize, usize)> = orphaned.iter().map(|&e| (e, 3)).collect();
        let assign = r.assign(block, &jobs);
        assert!(assign.iter().all(|&d| d == 0), "promotion must pick a healthy device");
        let s = r.stats();
        assert_eq!(s.failover_promotions, orphaned.len() as u64);
        assert_eq!(s.failovers, orphaned.len() as u64, "promotions count as failovers too");
    }

    #[test]
    fn retry_assignment_picks_a_live_survivor_and_records_load() {
        let (b, r) = faulty_router(2, 0, 1, "down:1@1..3");
        let block = b.topology.moe_blocks[0];
        r.advance_batch(&b); // tick 1: lanes on device 1 crash
        let dev = r.retry_assignment(block, 0, 5, 1);
        assert_ne!(dev, 1, "the survivor cannot be the crashed device");
        let s = r.stats();
        assert_eq!(s.retries, 1);
        assert_eq!(s.devices[dev].rows, 5, "retried rows charged to the survivor");
    }

    #[test]
    fn degraded_device_pays_inflated_transfer_charges() {
        let (b, r) = faulty_router(2, 0, 1, "degrade:1@1..2x4");
        let base = r.charge_activation_transfer(1, 10);
        assert!(base > 0.0);
        r.advance_batch(&b); // tick 1: degrade window opens
        assert_eq!(r.injector().health(1), DeviceHealth::Degraded);
        let slow = r.charge_activation_transfer(1, 10);
        assert!((slow - 4.0 * base).abs() < 1e-12, "factor 4 must inflate the charge");
        let assign = r.assign(b.topology.moe_blocks[0], &[(0, 2), (1, 2)]);
        assert!(assign.contains(&1), "Degraded devices still serve");
    }

    #[test]
    fn dropped_fetches_skip_the_prefetch_but_count() {
        let (b, r) = faulty_router(2, 0, 1, "drop:1@1");
        let block = b.topology.moe_blocks[0];
        r.advance_batch(&b); // tick 1: device 1's prefetches drop
        let key = ExpertKey::new(block, 0);
        let fetch = |device: usize| ClusterFetch {
            key,
            device,
            token_count: 4,
            tier: Tier::Ssd,
            layers_ahead: 1,
            lead_layers: 1,
            deadline_secs: 1.0,
            confidence: 1.0,
        };
        let plan = vec![fetch(0), fetch(1)];
        r.fetch_planned(&b, &plan).unwrap();
        assert!(r.device_cache(0).contains(&key), "healthy device's prefetch lands");
        assert!(!r.device_cache(1).contains(&key), "faulted device's prefetch dropped");
        assert_eq!(r.stats().dropped_fetches, 1);
    }
}
