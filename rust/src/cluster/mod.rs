//! Multi-device expert-parallel serving: one MoE model served across N
//! modeled devices with data-aware placement, hot-expert replication,
//! and a cluster router (DESIGN.md §2.3).
//!
//! SiDA's hash tables make expert activation *predictable per sentence*;
//! this subsystem exploits the same signal one level up: summed over
//! traffic, the predictions say which experts are hot, and hot experts
//! decide both **where** every expert should live
//! ([`PlacementPlanner`]: one home device per (layer, expert), hottest
//! experts replicated — the composition of eMoE-style workload-aware
//! placement with the hot-expert replication of "Fast MoE Inference via
//! Predictive Prefetching and Expert Replication", PAPERS.md) and
//! **who** computes each batch's expert jobs ([`ClusterRouter`]: every
//! job to the least-loaded holder of its expert, per-device expert sets
//! disjoint by construction, cross-device activation transfers charged
//! on the modeled timeline).
//!
//! The fleet itself is [`DeviceSet`]: per-device [`SharedExpertCache`]
//! budgets (the modeled GPU tier) whose embedded
//! [`crate::memory::ResidencyLedger`]s track each device's §6
//! device/RAM/SSD ladder — driven by the caches' real evictions, the
//! same mechanism single-device serving uses — plus a
//! [`TierCosts`]-based interconnect.  Outputs are **bit-identical** to
//! single-device serving at every device count: the cluster decides only where an
//! invocation computes; the scatter into the accumulators stays on the
//! primary, in ascending expert order, exactly like the sequential
//! path (asserted in `tests/cluster.rs` for devices ∈ {1, 2, 4}).
//!
//! ```
//! use sida_moe::cluster::{ClusterConfig, ClusterRouter};
//!
//! let bundle = sida_moe::testkit::tiny_bundle();
//! let router =
//!     ClusterRouter::new(&bundle, &ClusterConfig { devices: 2, ..Default::default() }).unwrap();
//! assert_eq!(router.devices(), 2);
//! // every (layer, expert) is homed exactly once even before traffic
//! router.placement().check_invariants(&bundle.topology).unwrap();
//! ```
//!
//! [`SharedExpertCache`]: crate::experts::SharedExpertCache
//! [`TierCosts`]: crate::memory::TierCosts

pub mod device;
pub mod failure;
pub mod placement;
pub mod router;
pub mod stats;

pub use device::{Device, DeviceSet};
pub use failure::{DeviceHealth, FaultEvent, FaultInjector, FaultPlan};
pub use placement::{ActivationProfile, Placement, PlacementPlanner};
pub use router::{ClusterFetch, ClusterRouter};
pub use stats::{ClusterStats, DeviceStats};

use crate::memory::TierCosts;

/// How to build a device fleet for one model.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// modeled devices serving the model (1 = the single-device path)
    pub devices: usize,
    /// hottest experts per MoE layer replicated across the fleet
    pub replicate_top: usize,
    /// simulated expert budget **per device** (each modeled accelerator
    /// has its own memory, like real GPUs do)
    pub budget_per_device: usize,
    /// eviction policy for every device cache
    pub policy: String,
    /// sleep modeled transfer time on the fetching thread's timeline
    pub real_sleep: bool,
    /// cost table of the device fabric (one RAM-hop per activation
    /// transfer direction)
    pub link: TierCosts,
    /// modeled per-device host-RAM budget the tier ladder demotes into
    /// (`--ram-budget`; overflow falls to unbounded SSD)
    pub host_ram_budget: usize,
    /// the RAM window's own eviction policy (`--ram-policy`)
    pub ram_policy: String,
    /// availability floor: every predicted-hot expert should have at
    /// least this many holders, best-effort under per-device capacity
    /// (`--min-replicas`; 1 = the home alone, i.e. no floor)
    pub min_replicas: usize,
    /// deterministic fault schedule on the batch-tick timeline
    /// (`--fault-plan`, [`FaultPlan`] grammar; empty = fault-free)
    pub fault_plan: String,
    /// modeled host-link staging bandwidth in bytes/sec (`--host-bw`;
    /// `0` = the reference PCIe link).  All devices of the box draw
    /// expert staging from ONE shared bandwidth window scaled by this
    /// — see [`crate::experts::BandwidthWindow`]
    pub host_bw: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            devices: 1,
            replicate_top: 1,
            budget_per_device: 8 << 30,
            policy: "fifo".into(),
            real_sleep: false,
            link: TierCosts::default(),
            host_ram_budget: crate::memory::DEFAULT_RAM_BUDGET,
            ram_policy: "fifo".into(),
            min_replicas: 1,
            fault_plan: String::new(),
            host_bw: 0.0,
        }
    }
}
