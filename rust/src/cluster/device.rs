//! The modeled device fleet: per-device expert caches (each driving its
//! own §6 residency ladder) and the cross-device interconnect cost
//! model.
//!
//! Each [`Device`] owns a full [`SharedExpertCache`] — its budgeted
//! "GPU" tier, the runtime source of truth for what is resident and
//! what must be fetched.  The cache itself drives the device's
//! GPU ↔ host-RAM ↔ SSD ladder (paper §6) through its embedded
//! [`crate::memory::ResidencyLedger`]: evictions demote the *actual*
//! policy-chosen victim, misses are charged tier-aware promotion cost.
//! The modeled `TieredStore` side-car that used to sit beside the cache
//! (and could drift from its eviction order) is gone — single-device
//! and cluster serving now share one residency mechanism.
//!
//! Device-to-device activation movement is charged through the same
//! [`TierCosts`] vocabulary the tier ladder uses: one
//! [`Tier::Ram`]-to-device hop over the modeled PCIe/NVLink fabric per
//! direction (see [`DeviceSet::link_secs`]).

use std::sync::Arc;

use anyhow::Result;

use crate::experts::{make_policy, BandwidthWindow, ExpertCache, ExpertKey, SharedExpertCache};
use crate::memory::{CostModel, HierarchyStats, Tier, TierCosts};

/// One modeled accelerator: a budgeted expert cache whose embedded
/// residency ledger tracks this device's position in the §6 ladder.
pub struct Device {
    pub id: usize,
    /// runtime expert residency (budget, eviction, tier ladder,
    /// transfer accounting)
    pub cache: Arc<SharedExpertCache>,
}

impl Device {
    /// Snapshot of this device's tier-ladder statistics — read straight
    /// from the cache-driven ledger, so it can never drift from the
    /// eviction order the cache actually produced.
    pub fn hierarchy_stats(&self) -> HierarchyStats {
        self.cache.hierarchy_stats()
    }

    /// Which ladder tier `key` sits in on this device.
    pub fn tier_of(&self, key: &ExpertKey) -> Tier {
        self.cache.tier_of(key)
    }
}

/// The set of modeled devices one model is served across, plus the
/// interconnect cost model for moving activations between them.
pub struct DeviceSet {
    devices: Vec<Device>,
    /// modeled device<->device fabric; a cross-device activation hop is
    /// one `Tier::Ram` promote over this cost table per direction
    pub link: TierCosts,
    /// simulated device budget, per device
    pub budget_per_device: usize,
    /// the ONE staging bandwidth window every device cache of this box
    /// charges its non-blocking prefetches into — devices share the
    /// host link, so their staging contends on a single modeled backlog
    /// rather than the independent per-cache clocks of PR 5
    window: Arc<BandwidthWindow>,
}

impl DeviceSet {
    /// Build `n` devices, each with its own `budget_per_device` expert
    /// cache (paper-scale cost model).  `ram_budget` bounds the modeled
    /// per-device host-RAM window device evictions demote into
    /// (`ram_policy` is that window's own eviction policy; overflow
    /// falls to unbounded SSD).
    /// `host_bw` (bytes/sec, `0` = the reference PCIe link) sets the
    /// shared staging window's occupancy rate — see
    /// [`BandwidthWindow::set_rate`].
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        budget_per_device: usize,
        real_expert_bytes: usize,
        policy: &str,
        real_sleep: bool,
        link: TierCosts,
        ram_budget: usize,
        ram_policy: &str,
        host_bw: f64,
    ) -> Result<Self> {
        anyhow::ensure!(n >= 1, "a cluster needs at least one device");
        let window = Arc::new(BandwidthWindow::new());
        if host_bw > 0.0 {
            window.set_rate(CostModel::paper_scale(real_expert_bytes).h2d_bandwidth / host_bw);
        }
        let mut devices = Vec::with_capacity(n);
        for id in 0..n {
            let cost = CostModel::paper_scale(real_expert_bytes).with_real_sleep(real_sleep);
            let mut cache = ExpertCache::with_hierarchy(
                budget_per_device,
                cost,
                make_policy(policy)?,
                ram_budget,
                make_policy(ram_policy)?,
            );
            // ladder events (promote/demote) land on this device's trace
            // track rather than the shared device-0 default
            cache.set_trace_pid(crate::obs::trace::device_pid(id));
            // all devices of one box draw staging bandwidth from the
            // same host link
            cache.share_window(window.clone());
            devices.push(Device { id, cache: Arc::new(SharedExpertCache::new(cache)) });
        }
        Ok(DeviceSet { devices, link, budget_per_device, window })
    }

    /// The box-wide staging bandwidth window shared by every device
    /// cache.
    pub fn bandwidth_window(&self) -> Arc<BandwidthWindow> {
        self.window.clone()
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, id: usize) -> &Device {
        &self.devices[id]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Device> {
        self.devices.iter()
    }

    /// Modeled seconds to move `bytes` across the device fabric (one
    /// hop: the data is already in a device/host-visible buffer, so the
    /// cost is a single RAM-to-device promote over the link table).
    pub fn link_secs(&self, bytes: usize) -> f64 {
        self.link.promote_secs(Tier::Ram, bytes)
    }

    /// Reset every device cache's counters and peak (between bench
    /// phases); residency — cache contents and ladder tiers — is state,
    /// not statistics, and carries across the epoch boundary.
    pub fn reset_stats(&self) {
        for d in &self.devices {
            d.cache.reset_stats();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(n: usize, budget: usize) -> DeviceSet {
        DeviceSet::new(n, budget, 1000, "fifo", false, TierCosts::default(), 1 << 24, "fifo", 0.0)
            .unwrap()
    }

    #[test]
    fn builds_n_isolated_devices() {
        let s = set(3, 1 << 20);
        assert_eq!(s.len(), 3);
        for (i, d) in s.iter().enumerate() {
            assert_eq!(d.id, i);
            assert_eq!(d.cache.budget(), 1 << 20);
            assert_eq!(d.cache.used(), 0);
        }
    }

    #[test]
    fn link_cost_is_one_ram_hop() {
        let s = set(2, 1 << 20);
        let b = 1 << 20;
        assert_eq!(s.link_secs(b), s.link.promote_secs(Tier::Ram, b));
        assert!(s.link_secs(b) > 0.0);
    }

    #[test]
    fn devices_share_one_staging_window() {
        // a non-blocking fetch through device 0's cache backlogs the
        // box-wide window, and device 1's cache sees the same backlog —
        // staging bandwidth is shared, not per-cache
        let s = set(2, 1 << 20);
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        s.device(0)
            .cache
            .ensure(ExpertKey::new(0, 0), 1000, false, || Ok([buf(), buf(), buf(), buf()]))
            .unwrap();
        let b0 = s.device(0).cache.prefetch_backlog_secs();
        let b1 = s.device(1).cache.prefetch_backlog_secs();
        assert!(b0 > 0.0, "non-blocking fetch must queue on the window");
        assert_eq!(b0, b1, "both caches read the one shared window");
        assert_eq!(b0, s.bandwidth_window().backlog_secs());
    }

    #[test]
    fn ladder_is_cache_driven_and_per_device() {
        // fetching through device 0's cache promotes in ITS ledger only;
        // device 1's ladder stays untouched
        let s = set(2, 1 << 20);
        let key = ExpertKey::new(0, 0);
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        s.device(0)
            .cache
            .ensure(key, 1000, false, || Ok([buf(), buf(), buf(), buf()]))
            .unwrap();
        assert_eq!(s.device(0).tier_of(&key), Tier::Device);
        assert_eq!(s.device(0).hierarchy_stats().promotions_from_ssd, 1);
        assert_eq!(s.device(1).tier_of(&key), Tier::Ssd, "other ledgers untouched");
        assert_eq!(s.device(1).hierarchy_stats().promotions_from_ssd, 0);
        s.device(0).cache.check_invariants().unwrap();
    }
}
