//! The modeled device fleet: per-device expert caches, per-device
//! three-tier residency ledgers, and the cross-device interconnect cost
//! model.
//!
//! Each [`Device`] owns a full [`SharedExpertCache`] (its budgeted
//! "GPU" tier — the runtime source of truth for what is resident and
//! what must be fetched) plus a [`TieredStore`] ledger that models the
//! same device's position in the device ↔ host-RAM ↔ SSD ladder of
//! paper §6 (promotions are recorded when the cluster routes work to
//! the device; FIFO demotions model budget pressure down the ladder).
//! The ledger is modeled *accounting* — the cache enforces the budget;
//! the ledger reports where the bytes came from.
//!
//! Device-to-device activation movement is charged through the same
//! [`TierCosts`] vocabulary the tier ladder uses: one
//! [`Tier::Ram`]-to-device hop over the modeled PCIe/NVLink fabric per
//! direction (see [`DeviceSet::link_secs`]).

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::experts::{make_policy, ExpertCache, ExpertKey, SharedExpertCache};
use crate::memory::{CostModel, HierarchyStats, Tier, TierCosts, TieredStore};

/// One modeled accelerator: a budgeted expert cache plus the modeled
/// three-tier residency ledger for the experts routed to it.
pub struct Device {
    pub id: usize,
    /// runtime expert residency (budget, eviction, transfer accounting)
    pub cache: Arc<SharedExpertCache>,
    /// modeled device/RAM/SSD ladder for this device's expert traffic
    tiers: Mutex<TieredStore<ExpertKey>>,
}

impl Device {
    /// Record that `key` was brought to (or used on) this device:
    /// promotes it in the tier ledger and returns the modeled promote
    /// seconds (0 when already device-resident in the ledger).
    pub fn note_promote(&self, key: ExpertKey, sim_bytes: usize) -> f64 {
        self.tiers.lock().unwrap().promote(key, sim_bytes)
    }

    /// Snapshot of this device's tier-ladder statistics.
    pub fn hierarchy_stats(&self) -> HierarchyStats {
        self.tiers.lock().unwrap().stats.clone()
    }
}

/// The set of modeled devices one model is served across, plus the
/// interconnect cost model for moving activations between them.
pub struct DeviceSet {
    devices: Vec<Device>,
    /// modeled device<->device fabric; a cross-device activation hop is
    /// one `Tier::Ram` promote over this cost table per direction
    pub link: TierCosts,
    /// simulated device budget, per device
    pub budget_per_device: usize,
}

impl DeviceSet {
    /// Build `n` devices, each with its own `budget_per_device` expert
    /// cache (paper-scale cost model) and a fresh tier ledger.
    /// `host_ram_budget` bounds the modeled per-device RAM tier the
    /// ladder demotes into (experts pushed further fall to SSD).
    pub fn new(
        n: usize,
        budget_per_device: usize,
        real_expert_bytes: usize,
        policy: &str,
        real_sleep: bool,
        link: TierCosts,
        host_ram_budget: usize,
    ) -> Result<Self> {
        anyhow::ensure!(n >= 1, "a cluster needs at least one device");
        let mut devices = Vec::with_capacity(n);
        for id in 0..n {
            let cost = CostModel::paper_scale(real_expert_bytes).with_real_sleep(real_sleep);
            devices.push(Device {
                id,
                cache: Arc::new(SharedExpertCache::new(ExpertCache::new(
                    budget_per_device,
                    cost,
                    make_policy(policy)?,
                ))),
                tiers: Mutex::new(TieredStore::new(
                    budget_per_device,
                    host_ram_budget,
                    link.clone(),
                )),
            });
        }
        Ok(DeviceSet { devices, link, budget_per_device })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, id: usize) -> &Device {
        &self.devices[id]
    }

    pub fn iter(&self) -> impl Iterator<Item = &Device> {
        self.devices.iter()
    }

    /// Modeled seconds to move `bytes` across the device fabric (one
    /// hop: the data is already in a device/host-visible buffer, so the
    /// cost is a single RAM-to-device promote over the link table).
    pub fn link_secs(&self, bytes: usize) -> f64 {
        self.link.promote_secs(Tier::Ram, bytes)
    }

    /// Reset every device cache's counters and peak (between bench
    /// phases); tier ledgers keep their residency but a fresh stats
    /// epoch is what the caches report from here on.
    pub fn reset_stats(&self) {
        for d in &self.devices {
            d.cache.reset_stats();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_n_isolated_devices() {
        let set =
            DeviceSet::new(3, 1 << 20, 1000, "fifo", false, TierCosts::default(), 1 << 24)
                .unwrap();
        assert_eq!(set.len(), 3);
        for (i, d) in set.iter().enumerate() {
            assert_eq!(d.id, i);
            assert_eq!(d.cache.budget(), 1 << 20);
            assert_eq!(d.cache.used(), 0);
        }
    }

    #[test]
    fn link_cost_is_one_ram_hop() {
        let set =
            DeviceSet::new(2, 1 << 20, 1000, "fifo", false, TierCosts::default(), 1 << 24)
                .unwrap();
        let b = 1 << 20;
        assert_eq!(set.link_secs(b), set.link.promote_secs(Tier::Ram, b));
        assert!(set.link_secs(b) > 0.0);
    }

    #[test]
    fn ledger_promotes_and_reports() {
        let set =
            DeviceSet::new(2, 10_000, 1000, "fifo", false, TierCosts::default(), 1 << 24)
                .unwrap();
        let key = ExpertKey::new(0, 0);
        let first = set.device(0).note_promote(key, 4_000);
        assert!(first > 0.0, "cold promote must cost modeled time");
        let again = set.device(0).note_promote(key, 4_000);
        assert_eq!(again, 0.0, "device-resident promote is free");
        let h = set.device(0).hierarchy_stats();
        assert_eq!(h.device_hits, 1);
        // device 1's ledger is untouched
        assert_eq!(set.device(1).hierarchy_stats().device_hits, 0);
    }
}
