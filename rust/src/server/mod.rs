//! Serving front-end: a line-protocol TCP server over the SiDA pipeline.
//!
//! Protocol (one JSON object per line):
//!   -> {"ids": [1, 17, 42, ..., 2]}          token ids (unpadded ok)
//!   <- {"id": 3, "label": 2, "latency_ms": 1.9}
//!   -> {"cmd": "stats"}                       server counters
//!   -> {"cmd": "shutdown"}
//!
//! No tokio in the vendored crate set, so this is a std::net +
//! thread-per-connection server; the SiDA pipeline behind it is
//! internally threaded (hash-building / prefetch / inference), matching
//! the paper's architecture where the front-end only feeds batches.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::hash_thread::HashBuilder;
use crate::coordinator::pipeline::argmax;
use crate::experts::{make_policy, ExpertCache};
use crate::memory::CostModel;
use crate::model::{ExpertProvider, ForwardOptions, ModelRunner};
use crate::runtime::ModelBundle;
use crate::util::json::{obj, Json};

pub struct ServerState {
    pub runner: ModelRunner,
    pub hash: HashBuilder,
    pub cache: Mutex<ExpertCache>,
    pub k_used: usize,
    pub served: AtomicU64,
    pub shutdown: AtomicBool,
}

impl ServerState {
    pub fn new(
        bundle: Arc<ModelBundle>,
        profile: &str,
        budget_sim_bytes: usize,
        k_used: usize,
    ) -> Result<Self> {
        let runner = ModelRunner::new(bundle.clone(), profile)?;
        let hash = HashBuilder::new(&bundle, profile)?;
        let real = bundle.weights.expert_bytes(bundle.topology.moe_blocks[0], 0)?;
        let cache = Mutex::new(ExpertCache::new(
            budget_sim_bytes,
            CostModel::paper_scale(real),
            make_policy("fifo")?,
        ));
        Ok(ServerState {
            runner,
            hash,
            cache,
            k_used,
            served: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Serve one request synchronously (hash build + forward).
    pub fn serve_one(&self, ids_unpadded: &[i32]) -> Result<(usize, f64)> {
        let l = self.runner.seq_len;
        let mut ids = vec![0i32; l];
        let n = ids_unpadded.len().min(l);
        ids[..n].copy_from_slice(&ids_unpadded[..n]);
        let t0 = Instant::now();
        let req_id = self.served.fetch_add(1, Ordering::SeqCst);
        let table = self.hash.build(req_id, &ids)?;
        let mut provider = ExpertProvider::Shared { cache: &self.cache, blocking: true };
        let out = self.runner.forward(
            &ids,
            Some((&table, self.k_used)),
            &mut provider,
            ForwardOptions { want_cls: true, ..Default::default() },
        )?;
        let label = out.cls_logits.as_ref().map(|v| argmax(v)).unwrap_or(0);
        Ok((label, t0.elapsed().as_secs_f64()))
    }
}

fn handle_conn(state: Arc<ServerState>, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr()?;
    log::info!("connection from {peer}");
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", obj(vec![("error", Json::Str(e.to_string()))]))?;
                continue;
            }
        };
        if let Some(cmd) = req.opt("cmd") {
            match cmd.as_str().unwrap_or("") {
                "stats" => {
                    let served = state.served.load(Ordering::SeqCst);
                    let cache = state.cache.lock().unwrap();
                    let cs = cache.stats().clone();
                    writeln!(
                        writer,
                        "{}",
                        obj(vec![
                            ("served", Json::Num(served as f64)),
                            ("cache_hits", Json::Num(cs.hits as f64)),
                            ("cache_misses", Json::Num(cs.misses as f64)),
                            ("device_used_bytes", Json::Num(cache.used() as f64)),
                        ])
                    )?;
                }
                "shutdown" => {
                    state.shutdown.store(true, Ordering::SeqCst);
                    writeln!(writer, "{}", obj(vec![("ok", Json::Bool(true))]))?;
                    return Ok(());
                }
                other => {
                    writeln!(
                        writer,
                        "{}",
                        obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))])
                    )?;
                }
            }
            continue;
        }
        let ids: Vec<i32> = match req.get("ids").and_then(|v| v.as_arr().map(|a| a.to_vec())) {
            Ok(arr) => arr.iter().filter_map(|v| v.as_i64().ok()).map(|v| v as i32).collect(),
            Err(e) => {
                writeln!(writer, "{}", obj(vec![("error", Json::Str(e.to_string()))]))?;
                continue;
            }
        };
        match state.serve_one(&ids) {
            Ok((label, secs)) => {
                let id = state.served.load(Ordering::SeqCst) - 1;
                writeln!(
                    writer,
                    "{}",
                    obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("label", Json::Num(label as f64)),
                        ("latency_ms", Json::Num(secs * 1e3)),
                    ])
                )?;
            }
            Err(e) => {
                writeln!(writer, "{}", obj(vec![("error", Json::Str(e.to_string()))]))?;
            }
        }
    }
    Ok(())
}

/// Run the TCP server until a `shutdown` command arrives.
pub fn run_server(state: Arc<ServerState>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("sida-moe serving on {addr} (model {})", state.runner.bundle.topology.name);
    run_server_on(state, listener)
}

/// Serve on an already-bound listener (lets tests bind port 0 and read
/// the ephemeral address before starting the accept loop).
pub fn run_server_on(state: Arc<ServerState>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    let mut handles = Vec::new();
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                let st = state.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(st, stream) {
                        log::warn!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}
