//! Serving front-end: a line-protocol TCP server over one shared SiDA
//! serving pipeline.
//!
//! Connections no longer compute inline: every connection thread only
//! parses requests and admits them into a single bounded admission
//! queue; one shared worker pulls size/deadline-formed batches
//! ([`BatchFormer`]) off that queue, builds the hash tables, and issues
//! one [`ModelRunner::forward_batch`] per batch — so concurrent clients
//! share expert invocations and H2D transfers, which is where the
//! paper's throughput multiplier over batch-1 serving comes from.
//! Per-request latency is attributed as queueing/batching delay
//! (admission to batch cut) plus shared inference time, both reported
//! to the client and recorded in [`BatchingStats`].
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"ids": [1, 17, 42, 2]}      token ids (unpadded ok)
//! -> {"ids": [...], "class": "interactive", "deadline_ms": 50}
//! <- {"id": 3, "label": 2, "latency_ms": 1.9, "queue_ms": 0.4, "infer_ms": 1.5}
//! -> {"cmd": "stats"}             server + batching counters (JSON)
//! -> {"cmd": "metrics"}           Prometheus text exposition, terminated
//!                                 by a literal "# EOF" line
//! -> {"cmd": "shutdown"}
//! ```
//!
//! `"class": "interactive"` requests carry an SLO deadline
//! (`deadline_ms`, defaulting to `ServerConfig::default_deadline_secs`)
//! and ride the batch former's interactive lane: they are rejected at
//! admission when the EWMA queue-delay prediction already exceeds the
//! deadline (`{"error": "deadline ..."}`), and shed at batch-cut time
//! when the deadline is blown while queued.  Everything else rides the
//! batch lane, protected from starvation by the former's aging credit.
//!
//! When the admission queue is full the request is rejected
//! immediately (`{"error": "queue full ..."}`) and counted — bounded
//! memory under overload, clients retry.
//!
//! If the shared batch worker panics, every pending and in-flight
//! request receives an error reply (no 30 s client timeouts), the
//! server flips `shutdown`, and the failure is surfaced as
//! `worker_panics` in `cmd: stats`.
//!
//! No tokio in the vendored crate set, so this is a std::net +
//! thread-per-connection front-end; batching happens behind the queue,
//! not per connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::cluster::{ClusterConfig, ClusterRouter};
use crate::coordinator::batcher::{
    AdmitOutcome, BatchFormer, BatchPolicy, FormedBatch, QueueDelayEstimator,
};
use crate::coordinator::hash_table::HashTable;
use crate::coordinator::hash_thread::HashBuilder;
use crate::coordinator::pipeline::{argmax, run_gated_forward, WarmTarget};
use crate::experts::{make_policy, ExpertCache, SharedExpertCache};
use crate::memory::CostModel;
use crate::metrics::BatchingStats;
use crate::model::{BatchItem, ExpertProvider, ForwardOptions, ModelRunner};
use crate::runtime::ModelBundle;
use crate::util::json::{obj, Json};
use crate::util::pool::WorkerPool;
use crate::workload::{Request, SloClass};

/// Front-end tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// simulated device budget for expert weights
    pub budget_sim_bytes: usize,
    /// modeled host-RAM tier budget (`--ram-budget`): device evictions
    /// demote into this §6 ladder window; overflow falls to SSD, and
    /// SSD-deep misses pay the NVMe+PCIe ladder.  Per device in cluster
    /// mode.
    pub ram_budget_sim_bytes: usize,
    /// the RAM window's own eviction policy (`--ram-policy`)
    pub ram_policy: String,
    /// on-disk expert store directory (`--store-dir`): SSD promotions
    /// do real, hash-verified blob reads; reopening an existing
    /// directory pre-seeds the SSD tier so a restarted server warm-hits
    /// instead of re-fabricating.  Empty = modeled-only SSD tier.
    /// Single-device serving only.
    pub store_dir: String,
    /// on-disk store byte budget (`--ssd-budget`, 0 = unbounded)
    pub ssd_budget_bytes: usize,
    /// hash experts consumed per token
    pub k_used: usize,
    /// staging depth of the cross-layer prefetch scheduler
    /// (`--prefetch-depth`; 1 = one-layer-ahead baseline)
    pub prefetch_depth: usize,
    /// modeled host-link staging bandwidth, bytes/sec (`--host-bw`;
    /// 0 = the reference PCIe link)
    pub host_bw: f64,
    /// batch-forming policy (size/deadline/queue bound)
    pub batch: BatchPolicy,
    /// worker-pool width for concurrent expert execution (0 = auto)
    pub pool_threads: usize,
    /// modeled devices to serve across (1 = single device; > 1 enables
    /// expert parallelism with data-aware placement — `--devices`).
    /// `budget_sim_bytes` is then per device.
    pub devices: usize,
    /// hottest experts per MoE layer replicated across the fleet
    /// (`--replicate-top`; cluster mode only)
    pub replicate_top: usize,
    /// availability floor: every predicted-hot expert placed on at
    /// least this many devices (`--min-replicas`; cluster mode only)
    pub min_replicas: usize,
    /// deterministic fault schedule on the batch-tick timeline
    /// (`--fault-plan`; cluster mode only, empty = fault-free)
    pub fault_plan: String,
    /// SLO deadline applied to `"class": "interactive"` requests that
    /// carry no `deadline_ms` of their own (`--slo-deadline`)
    pub default_deadline_secs: f64,
    /// socket read/write timeout per connection (`--conn-timeout`,
    /// seconds; 0 = none): a client idle past this gets an error reply
    /// and its handler thread is reaped instead of held forever
    pub conn_timeout_secs: f64,
    /// write a Chrome trace-event JSON here on shutdown
    /// (`--trace-out`; empty = tracing stays off)
    pub trace_out: String,
    /// periodic metrics snapshot to stderr every this many seconds
    /// (`--metrics-interval`; 0 = off)
    pub metrics_interval_secs: f64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            budget_sim_bytes: 8 << 30,
            ram_budget_sim_bytes: crate::memory::DEFAULT_RAM_BUDGET,
            ram_policy: "fifo".into(),
            store_dir: String::new(),
            ssd_budget_bytes: 0,
            k_used: 1,
            prefetch_depth: 3,
            host_bw: 0.0,
            batch: BatchPolicy::default(),
            pool_threads: 0,
            devices: 1,
            replicate_top: 1,
            min_replicas: 1,
            fault_plan: String::new(),
            default_deadline_secs: 0.100,
            conn_timeout_secs: 0.0,
            trace_out: String::new(),
            metrics_interval_secs: 0.0,
        }
    }
}

/// A completed request, as handed back to the connection thread.
struct Reply {
    id: u64,
    label: usize,
    /// admission -> batch cut (queueing + batching delay)
    queue_secs: f64,
    /// shared hash-build + forward time of the batch
    infer_secs: f64,
}

/// What the worker sends the connection thread: a reply or an error
/// message (anyhow errors are not cloneable across a whole batch).
type ReplyOutcome = std::result::Result<Reply, String>;

pub struct ServerState {
    pub runner: ModelRunner,
    pub hash: HashBuilder,
    pub cache: Arc<SharedExpertCache>,
    /// the device fleet + router when `ServerConfig::devices > 1`
    pub cluster: Option<Arc<ClusterRouter>>,
    pub k_used: usize,
    /// staging depth of the depth-window warmer (`--prefetch-depth`)
    pub prefetch_depth: usize,
    /// the single shared admission queue all connections feed
    queue: Mutex<BatchFormer<Sender<ReplyOutcome>>>,
    queue_cv: Condvar,
    /// batching counters + latency attribution (see `cmd: stats`)
    pub batching: Mutex<BatchingStats>,
    /// EWMA of per-request service seconds, driving SLO admission
    estimator: Mutex<QueueDelayEstimator>,
    /// requests completed by the shared worker
    pub served: AtomicU64,
    /// requests rejected at admission (queue full / shutting down)
    pub rejected: AtomicU64,
    /// requests rejected at admission because the predicted queue delay
    /// already exceeded their deadline
    pub rejected_slo: AtomicU64,
    /// batch-worker panics caught (the server shuts down after one)
    pub worker_panics: AtomicU64,
    /// test hook: the next batch the worker runs panics
    #[doc(hidden)]
    pub inject_panic: AtomicBool,
    /// default deadline for interactive requests without their own
    default_deadline_secs: f64,
    /// socket read/write timeout per connection (0 = none)
    conn_timeout_secs: f64,
    /// write a Chrome trace-event JSON here on shutdown (empty = off)
    trace_out: String,
    /// periodic stderr metrics snapshot interval (0 = off)
    metrics_interval_secs: f64,
    /// per-instance metrics registry: `cmd:stats` and `cmd:metrics`
    /// both render from snapshots published here, so the two endpoints
    /// can never drift (instance-local, not the process-global registry,
    /// so parallel test servers don't cross-contaminate)
    pub obs: crate::obs::Registry,
    next_id: AtomicU64,
    pub shutdown: AtomicBool,
    t0: Instant,
}

impl ServerState {
    pub fn new(bundle: Arc<ModelBundle>, profile: &str, cfg: ServerConfig) -> Result<Self> {
        let pool = WorkerPool::from_config(cfg.pool_threads);
        let runner = ModelRunner::with_pool(bundle.clone(), profile, pool)?;
        let hash = HashBuilder::new(&bundle, profile)?;
        let real = bundle.weights.expert_bytes(bundle.topology.moe_blocks[0], 0)?;
        let mut core = ExpertCache::with_hierarchy(
            cfg.budget_sim_bytes,
            CostModel::paper_scale(real),
            make_policy("fifo")?,
            cfg.ram_budget_sim_bytes,
            make_policy(&cfg.ram_policy)?,
        );
        if !cfg.store_dir.is_empty() {
            if cfg.devices > 1 {
                anyhow::bail!(
                    "--store-dir applies to single-device serving \
                     (cluster devices run store-less)"
                );
            }
            let store = crate::memory::ExpertStore::open(
                std::path::Path::new(&cfg.store_dir),
                cfg.ssd_budget_bytes as u64,
            )?;
            core.attach_store(crate::experts::bind_store(&bundle, store));
        }
        let cache = Arc::new(SharedExpertCache::new(core));
        if cfg.host_bw > 0.0 {
            cache
                .bandwidth_window()
                .set_rate(CostModel::paper_scale(real).h2d_bandwidth / cfg.host_bw);
        }
        let cluster = if cfg.devices > 1 {
            Some(Arc::new(ClusterRouter::new(
                &bundle,
                &ClusterConfig {
                    devices: cfg.devices,
                    replicate_top: cfg.replicate_top,
                    min_replicas: cfg.min_replicas,
                    fault_plan: cfg.fault_plan.clone(),
                    budget_per_device: cfg.budget_sim_bytes,
                    host_ram_budget: cfg.ram_budget_sim_bytes,
                    ram_policy: cfg.ram_policy.clone(),
                    host_bw: cfg.host_bw,
                    ..ClusterConfig::default()
                },
            )?))
        } else {
            None
        };
        Ok(ServerState {
            runner,
            hash,
            cache,
            cluster,
            k_used: cfg.k_used,
            prefetch_depth: cfg.prefetch_depth.max(1),
            queue: Mutex::new(BatchFormer::new(cfg.batch)),
            queue_cv: Condvar::new(),
            batching: Mutex::new(BatchingStats::default()),
            estimator: Mutex::new(QueueDelayEstimator::default()),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            rejected_slo: AtomicU64::new(0),
            worker_panics: AtomicU64::new(0),
            inject_panic: AtomicBool::new(false),
            default_deadline_secs: cfg.default_deadline_secs,
            conn_timeout_secs: cfg.conn_timeout_secs,
            trace_out: cfg.trace_out.clone(),
            metrics_interval_secs: cfg.metrics_interval_secs,
            obs: crate::obs::Registry::new(),
            next_id: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            t0: Instant::now(),
        })
    }

    /// Monotonic seconds since server start — the clock the batch
    /// former's deadlines run on.
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    /// The expert provider serving this front-end: the shared cache, or
    /// the cluster router in multi-device mode.
    fn provider(&self) -> ExpertProvider<'_> {
        match &self.cluster {
            Some(router) => ExpertProvider::Cluster { router, blocking: true },
            None => ExpertProvider::Shared { cache: &self.cache, blocking: true },
        }
    }

    /// Who the layer-ahead warmer stages experts into.
    fn warm_target(&self) -> WarmTarget {
        match &self.cluster {
            Some(router) => WarmTarget::Cluster { router: router.clone() },
            None => WarmTarget::Single { cache: self.cache.clone() },
        }
    }

    /// Serve one request synchronously (hash build + batch-1 forward),
    /// bypassing the admission queue — the direct embedding API for
    /// callers that hold a `ServerState` without running the TCP
    /// front-end.  Counted in `served` like worker-served requests.
    pub fn serve_one(&self, ids_unpadded: &[i32]) -> Result<(usize, f64)> {
        let l = self.runner.seq_len;
        let mut ids = vec![0i32; l];
        let n = ids_unpadded.len().min(l);
        ids[..n].copy_from_slice(&ids_unpadded[..n]);
        let t0 = Instant::now();
        let req_id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let table = self.hash.build(req_id, &ids)?;
        let mut provider = self.provider();
        let out = self.runner.forward(
            &ids,
            Some((&table, self.k_used)),
            &mut provider,
            ForwardOptions { want_cls: true, ..Default::default() },
        )?;
        self.served.fetch_add(1, Ordering::SeqCst);
        let label = out.cls_logits.as_ref().map(|v| argmax(v)).unwrap_or(0);
        Ok((label, t0.elapsed().as_secs_f64()))
    }

    /// Pad and admit one request into the shared queue; `Ok` carries
    /// the receiver the reply will arrive on, `Err` the rejection
    /// reason.
    fn submit(
        &self,
        ids_unpadded: &[i32],
        class: SloClass,
    ) -> std::result::Result<Receiver<ReplyOutcome>, String> {
        let l = self.runner.seq_len;
        let mut ids = vec![0i32; l];
        let n = ids_unpadded.len().min(l);
        ids[..n].copy_from_slice(&ids_unpadded[..n]);
        let now = self.now();
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let req = Request { id, ids, n_tokens: n, label: 0, arrival: now, class };
        let (tx, rx) = channel();
        // snapshot the service-time EWMA outside the queue lock (lock
        // order: never hold both)
        let estimator = lock_tolerant(&self.estimator).clone();
        let outcome = {
            // the shutdown check must happen under the queue lock: the
            // worker reads the flag and performs its final drain under
            // this lock, so an admit that observes shutdown == false is
            // guaranteed to be seen by that drain (no stranded request)
            let mut q = lock_tolerant(&self.queue);
            if self.shutdown.load(Ordering::SeqCst) {
                self.rejected.fetch_add(1, Ordering::SeqCst);
                return Err("server shutting down".into());
            }
            if !estimator.admits(&req.class, q.len()) {
                self.rejected_slo.fetch_add(1, Ordering::SeqCst);
                return Err(format!(
                    "deadline: predicted queue delay {:.1} ms exceeds the {:.1} ms SLO — rejected at admission",
                    estimator.estimated_delay_secs(q.len()) * 1e3,
                    req.class.deadline_secs().unwrap_or(0.0) * 1e3,
                ));
            }
            // capture the bound under this same lock — no second
            // acquisition just to render the error string
            let capacity = q.policy().capacity;
            match q.admit(req, tx, now) {
                AdmitOutcome::Admitted => Ok(()),
                AdmitOutcome::Rejected => Err(capacity),
            }
        };
        match outcome {
            Ok(()) => {
                self.queue_cv.notify_all();
                Ok(rx)
            }
            Err(capacity) => {
                self.rejected.fetch_add(1, Ordering::SeqCst);
                Err(format!("queue full (capacity {capacity}) — retry later"))
            }
        }
    }
}

/// Build one consistent stats snapshot as the `cmd:stats` JSON field
/// list.  Shared by the `stats`/`metrics` endpoints and the periodic
/// `--metrics-interval` reporter, so every exposition path reads the
/// same values from one snapshot.
fn stats_fields(state: &ServerState) -> Vec<(&'static str, Json)> {
    let served = state.served.load(Ordering::SeqCst);
    let rejected = state.rejected.load(Ordering::SeqCst);
    let rejected_slo = state.rejected_slo.load(Ordering::SeqCst);
    let worker_panics = state.worker_panics.load(Ordering::SeqCst);
    let queued = lock_tolerant(&state.queue).len();
    let (batches, mean_size, delay_ms, infer_ms, conn_timeouts, slo) = {
        let mut b = lock_tolerant(&state.batching);
        let slo = (
            b.shed,
            b.slo_attainment(),
            b.latency_interactive.p99() * 1e3,
            b.latency_interactive.p999() * 1e3,
            b.latency_batch.p99() * 1e3,
            b.latency_batch.p999() * 1e3,
        );
        (
            b.batches,
            b.mean_batch_size().unwrap_or(0.0),
            b.batching_delay.mean() * 1e3,
            b.inference.mean() * 1e3,
            b.conn_timeouts,
            slo,
        )
    };
    let (shed, attainment, int_p99, int_p999, bat_p99, bat_p999) = slo;
    // ONE cluster snapshot per reply, so the top-level
    // aggregates and the per-device array below can
    // never disagree.  Top-level cache fields reflect
    // wherever serving actually resolves residency:
    // the aggregate over every device cache in cluster
    // mode, the single shared cache otherwise.
    let cluster = state.cluster.as_ref().map(|r| r.stats());
    let (hits, misses, overlapped, used) = match &cluster {
        Some(cl) => (
            cl.devices.iter().map(|d| d.cache.hits).sum::<u64>(),
            cl.devices.iter().map(|d| d.cache.misses).sum::<u64>(),
            cl.devices
                .iter()
                .map(|d| d.cache.overlapped_transfer_secs)
                .sum::<f64>(),
            cl.devices.iter().map(|d| d.used_bytes).sum::<usize>(),
        ),
        None => {
            let cs = state.cache.stats();
            (cs.hits, cs.misses, cs.overlapped_transfer_secs, state.cache.used())
        }
    };
    // the §6 ladder, from the same snapshot: aggregate
    // over every device's cache-driven ledger in
    // cluster mode, the single cache's ledger otherwise
    let hier = match &cluster {
        Some(cl) => cl.hierarchy_total(),
        None => state.cache.hierarchy_stats(),
    };
    // the shared staging bandwidth window: box-wide in cluster mode
    // (every device charges the one window), the single cache's
    // otherwise — same resolution rule as residency above
    let window = match &state.cluster {
        Some(router) => router.bandwidth_window().snapshot(),
        None => state.cache.bandwidth_window().snapshot(),
    };
    let mut fields = vec![
        ("served", Json::Num(served as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("rejected_slo", Json::Num(rejected_slo as f64)),
        ("shed", Json::Num(shed as f64)),
        ("worker_panics", Json::Num(worker_panics as f64)),
        ("slo_attainment", Json::Num(attainment.unwrap_or(1.0))),
        ("latency_p99_ms_interactive", Json::Num(int_p99)),
        ("latency_p999_ms_interactive", Json::Num(int_p999)),
        ("latency_p99_ms_batch", Json::Num(bat_p99)),
        ("latency_p999_ms_batch", Json::Num(bat_p999)),
        ("queued", Json::Num(queued as f64)),
        ("batches_formed", Json::Num(batches as f64)),
        ("mean_batch_size", Json::Num(mean_size)),
        ("batching_delay_ms_mean", Json::Num(delay_ms)),
        ("infer_ms_mean", Json::Num(infer_ms)),
        ("conn_timeouts", Json::Num(conn_timeouts as f64)),
        ("cache_hits", Json::Num(hits as f64)),
        ("cache_misses", Json::Num(misses as f64)),
        ("transfer_overlapped_secs", Json::Num(overlapped)),
        ("prefetch_backlog_secs", Json::Num(window.backlog_secs)),
        ("prefetch_carried_backlog_secs", Json::Num(window.carried_backlog_secs)),
        ("prefetch_admitted", Json::Num(window.admitted as f64)),
        ("prefetch_deferred", Json::Num(window.deferred_low_confidence as f64)),
        (
            // `null` until compute advances have offered any drain —
            // distinct from a true 0% utilization
            "prefetch_window_utilization",
            window.utilization().map(Json::Num).unwrap_or(Json::Null),
        ),
        ("device_used_bytes", Json::Num(used as f64)),
        ("ram_used_bytes", Json::Num(hier.ram_bytes as f64)),
        ("ssd_used_bytes", Json::Num(hier.ssd_bytes as f64)),
        ("demotions_to_ram", Json::Num(hier.demotions_to_ram as f64)),
        ("demotions_to_ssd", Json::Num(hier.demotions_to_ssd as f64)),
        ("ssd_promote_secs", Json::Num(hier.ssd_promote_secs)),
        ("ladder_secs", Json::Num(hier.ladder_secs())),
        ("measured_ssd_read_secs", Json::Num(hier.measured_ssd_read_secs)),
        ("measured_ssd_write_secs", Json::Num(hier.measured_ssd_write_secs)),
        ("store_bytes_on_disk", Json::Num(hier.store_bytes_on_disk as f64)),
        ("integrity_failures", Json::Num(hier.integrity_failures as f64)),
        ("store_hits", Json::Num(hier.store_hits as f64)),
        ("refabrications", Json::Num(hier.refabrications as f64)),
    ];
    if let Some(cl) = &cluster {
        let devices: Vec<Json> = cl
            .devices
            .iter()
            .map(|d| {
                obj(vec![
                    ("device", Json::Num(d.device as f64)),
                    ("used_bytes", Json::Num(d.used_bytes as f64)),
                    ("peak_bytes", Json::Num(d.peak_bytes as f64)),
                    (
                        "assigned_experts",
                        Json::Num(d.assigned_experts as f64),
                    ),
                    ("rows", Json::Num(d.rows as f64)),
                    ("hits", Json::Num(d.cache.hits as f64)),
                    ("misses", Json::Num(d.cache.misses as f64)),
                    (
                        "health",
                        Json::Str(format!("{:?}", d.health).to_lowercase()),
                    ),
                ])
            })
            .collect();
        fields.push(("devices", Json::Arr(devices)));
        fields.push((
            "load_imbalance",
            Json::Num(cl.load_imbalance().unwrap_or(0.0)),
        ));
        fields.push((
            "cross_device_bytes",
            Json::Num(cl.cross_device_bytes as f64),
        ));
        fields.push((
            "interconnect_secs",
            Json::Num(cl.interconnect_secs),
        ));
        fields.push((
            "replicated_entries",
            Json::Num(cl.replicated_entries as f64),
        ));
        fields.push(("failovers", Json::Num(cl.failovers as f64)));
        fields.push((
            "failover_promotions",
            Json::Num(cl.failover_promotions as f64),
        ));
        fields.push(("retries", Json::Num(cl.retries as f64)));
        fields.push((
            "dropped_fetches",
            Json::Num(cl.dropped_fetches as f64),
        ));
        fields.push((
            "device_failures",
            Json::Num(cl.device_failures as f64),
        ));
        fields.push(("recoveries", Json::Num(cl.recoveries as f64)));
        fields.push(("downtime_secs", Json::Num(cl.downtime_secs)));
    }
    fields
}

/// Lock a mutex, recovering the data from a poisoned lock: the batch
/// worker wraps its fallible work in `catch_unwind`, and a panic that
/// slipped through must not cascade into every connection thread.
fn lock_tolerant<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Mirror one `cmd:stats` snapshot into the server's registry: every
/// numeric field becomes a `sida_server_<field>` gauge, and the
/// per-device array becomes `sida_server_device_<field>` gauges carrying
/// a `device` label.  Because `cmd:metrics` renders the registry right
/// after this publish, a scrape and a `cmd:stats` issued on the same
/// snapshot agree field-for-field (tests/server.rs asserts it).
fn publish_stats_fields(reg: &crate::obs::Registry, fields: &[(&'static str, Json)]) {
    for (name, v) in fields {
        match v {
            Json::Num(x) => {
                reg.gauge(&format!("sida_server_{name}"), "server stats field (see cmd:stats)")
                    .set(*x);
            }
            Json::Arr(devices) if *name == "devices" => {
                for d in devices {
                    let Ok(id) = d.get_usize("device") else { continue };
                    let label = id.to_string();
                    for key in
                        ["used_bytes", "peak_bytes", "assigned_experts", "rows", "hits", "misses"]
                    {
                        if let Some(x) = d.opt(key).and_then(|j| j.as_f64().ok()) {
                            reg.gauge_with(
                                &format!("sida_server_device_{key}"),
                                &[("device", label.as_str())],
                                "per-device server stats field (see cmd:stats)",
                            )
                            .set(x);
                        }
                    }
                }
            }
            _ => {}
        }
    }
}

/// Wait for the next formed batch: cut on size, cut on deadline, or
/// drain on shutdown.  Returns `None` when shut down with nothing
/// pending — the worker's exit condition.
fn next_batch(state: &ServerState) -> Option<FormedBatch<Sender<ReplyOutcome>>> {
    let mut q = lock_tolerant(&state.queue);
    loop {
        let now = state.now();
        if state.shutdown.load(Ordering::SeqCst) {
            return q.form_now(now);
        }
        if let Some(batch) = q.try_form(now) {
            return Some(batch);
        }
        // sleep until the oldest pending request's deadline, capped so
        // shutdown and missed notifies are always noticed promptly
        let wait = q
            .next_deadline()
            .map(|d| (d - now).max(0.0))
            .unwrap_or(0.05)
            .clamp(0.001, 0.05);
        let (guard, _timeout) = state
            .queue_cv
            .wait_timeout(q, Duration::from_secs_f64(wait))
            .unwrap_or_else(|e| e.into_inner());
        q = guard;
    }
}

/// Hash-build + batched forward for one formed batch; returns the
/// per-request labels in batch order.
///
/// The forward runs gated against a layer-ahead warmer (same machinery
/// as `Pipeline::serve_batched`): while the batch computes MoE layer
/// *j*, the warmer stages layer *j+1*'s batch-union expert set, so
/// expert fetches ride the overlapped prefetch timeline instead of
/// stalling the shared worker.
fn run_batch(
    state: &ServerState,
    batch: &FormedBatch<Sender<ReplyOutcome>>,
) -> Result<Vec<usize>> {
    if state.inject_panic.swap(false, Ordering::SeqCst) {
        panic!("injected batch panic (test hook)");
    }
    let mut tables = Vec::with_capacity(batch.len());
    for (req, _) in &batch.requests {
        tables.push(state.hash.build(req.id, &req.ids)?);
    }
    let masks: Vec<Vec<f32>> = batch.requests.iter().map(|(req, _)| req.mask()).collect();
    let items: Vec<BatchItem<'_>> = batch
        .requests
        .iter()
        .zip(tables.iter())
        .map(|((req, _), table)| BatchItem {
            ids: &req.ids[..],
            hash: Some((table, state.k_used)),
        })
        .collect();
    let pairs: Vec<(&HashTable, &[f32])> = tables
        .iter()
        .zip(masks.iter())
        .map(|(table, mask)| (table, mask.as_slice()))
        .collect();
    // cluster mode learns placement from live traffic: fold this
    // batch's predictions into the activation profile and re-plan when
    // the profile has grown enough (first batch, then every doubling)
    if let Some(router) = &state.cluster {
        // one fault-timeline tick per batch: failures/recoveries take
        // effect (and force a replan) before this batch is routed
        router.advance_batch(&state.runner.bundle);
        router.observe(&pairs, state.k_used);
        router.replan_if_due(&state.runner.bundle);
    }
    let mut provider = state.provider();
    let opts = ForwardOptions { want_cls: true, ..Default::default() };
    let trace_ids: Vec<u64> = batch.requests.iter().map(|(req, _)| req.id).collect();
    let t_batch = crate::obs::trace::begin();
    if crate::obs::trace::enabled() {
        for &rid in &trace_ids {
            crate::obs::trace::flow('s', rid, crate::obs::trace::host_pid());
        }
    }
    let out = run_gated_forward(
        &state.runner.bundle,
        &state.warm_target(),
        &pairs,
        &state.runner.bundle.topology.moe_blocks,
        state.k_used,
        state.prefetch_depth,
        &trace_ids,
        |hooks| state.runner.forward_batch_hooked(&items, &mut provider, opts, hooks),
    )?;
    if crate::obs::trace::enabled() {
        use crate::obs::trace::ArgValue;
        // flow ends bind to the enclosing slice (`bp:"e"`): emit before
        // the batch span closes
        for &rid in &trace_ids {
            crate::obs::trace::flow('f', rid, crate::obs::trace::host_pid());
        }
        crate::obs::trace::complete(
            "batch",
            "serve",
            crate::obs::trace::host_pid(),
            t_batch,
            vec![("requests", ArgValue::U(trace_ids.len() as u64))],
        );
    }
    Ok(out
        .outputs
        .iter()
        .map(|o| o.cls_logits.as_ref().map(|v| argmax(v)).unwrap_or(0))
        .collect())
}

/// Best-effort human-readable panic payload.
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Serve one formed batch and deliver every reply (or the shared
/// error).  Returns `false` when the batch panicked — the worker must
/// shut the server down rather than limp on with unknown state.
fn serve_batch(state: &ServerState, batch: FormedBatch<Sender<ReplyOutcome>>) -> bool {
    // deliver shed replies first: these requests blew their deadline in
    // the queue and were cut out of the batch by the former
    if !batch.shed.is_empty() {
        lock_tolerant(&state.batching).observe_shed(batch.shed.len());
        for (req, tx) in &batch.shed {
            let _ = tx.send(Err(format!(
                "deadline: request {} shed — SLO expired while queued",
                req.id
            )));
        }
    }
    if batch.requests.is_empty() {
        return true;
    }
    let t0 = Instant::now();
    let result =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_batch(state, &batch)));
    let infer_secs = t0.elapsed().as_secs_f64();
    match result {
        Ok(Ok(labels)) => {
            {
                let mut b = lock_tolerant(&state.batching);
                b.observe_batch(&batch.batching_delays, infer_secs);
                for ((req, _), delay) in batch.requests.iter().zip(batch.batching_delays.iter())
                {
                    b.observe_request(&req.class, *delay + infer_secs);
                }
            }
            lock_tolerant(&state.estimator).observe(infer_secs / batch.requests.len() as f64);
            for (((req, tx), label), delay) in batch
                .requests
                .iter()
                .zip(labels)
                .zip(batch.batching_delays.iter())
            {
                state.served.fetch_add(1, Ordering::SeqCst);
                // a client that hung up just drops its reply
                let _ = tx.send(Ok(Reply {
                    id: req.id,
                    label,
                    queue_secs: *delay,
                    infer_secs,
                }));
            }
            true
        }
        Ok(Err(e)) => {
            let msg = format!("{e:#}");
            for (_, tx) in &batch.requests {
                let _ = tx.send(Err(msg.clone()));
            }
            true
        }
        Err(payload) => {
            let msg = format!("serving worker panicked: {}", panic_msg(payload.as_ref()));
            log::error!("{msg}");
            for (_, tx) in &batch.requests {
                let _ = tx.send(Err(msg.clone()));
            }
            false
        }
    }
}

/// The shared worker: pull formed batches until shutdown + drained.
/// A panicking batch kills the worker — but not silently: the panic is
/// counted, `shutdown` flips, and every request still queued gets an
/// error reply instead of a 30 s client timeout.
fn worker_loop(state: &ServerState) {
    while let Some(batch) = next_batch(state) {
        if !serve_batch(state, batch) {
            worker_died(state);
            return;
        }
    }
}

/// Post-panic teardown: surface the failure, stop admissions, and fail
/// every pending request promptly.
fn worker_died(state: &ServerState) {
    state.worker_panics.fetch_add(1, Ordering::SeqCst);
    // the store is ordered before the queue drain below: a submit that
    // admitted under the lock before us is drained here; one that locks
    // after us observes shutdown and rejects — no stranded request
    state.shutdown.store(true, Ordering::SeqCst);
    let mut q = lock_tolerant(&state.queue);
    while let Some(batch) = q.form_now(state.now()) {
        for (_, tx) in batch.requests.iter().chain(batch.shed.iter()) {
            let _ = tx.send(Err("serving worker died; server shutting down".into()));
        }
    }
    drop(q);
    state.queue_cv.notify_all();
}

fn handle_conn(state: Arc<ServerState>, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr()?;
    log::info!("connection from {peer}");
    // with --conn-timeout set, an idle client cannot pin this handler
    // thread forever: the blocking read wakes with WouldBlock/TimedOut,
    // the client gets one error reply, and the connection is reaped
    if state.conn_timeout_secs > 0.0 {
        let t = Duration::from_secs_f64(state.conn_timeout_secs);
        stream.set_read_timeout(Some(t))?;
        stream.set_write_timeout(Some(t))?;
    }
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                lock_tolerant(&state.batching).conn_timeouts += 1;
                let _ = writeln!(
                    writer,
                    "{}",
                    obj(vec![(
                        "error",
                        Json::Str(format!(
                            "connection idle past --conn-timeout ({}s); closing",
                            state.conn_timeout_secs
                        )),
                    )])
                );
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let req = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", obj(vec![("error", Json::Str(e.to_string()))]))?;
                continue;
            }
        };
        if let Some(cmd) = req.opt("cmd") {
            match cmd.as_str().unwrap_or("") {
                // one snapshot feeds BOTH exposition endpoints: the
                // JSON `stats` reply and the Prometheus-text `metrics`
                // reply are rendered from the identical field list (and
                // the registry is updated from it either way), so the
                // two can never drift
                which @ ("stats" | "metrics") => {
                    let fields = stats_fields(&state);
                    publish_stats_fields(&state.obs, &fields);
                    if which == "metrics" {
                        crate::obs::publish::publish_trace_health(&state.obs);
                        write!(writer, "{}", crate::obs::publish::render_text(&state.obs))?;
                        writeln!(writer, "# EOF")?;
                    } else {
                        writeln!(writer, "{}", obj(fields))?;
                    }
                }
                "shutdown" => {
                    state.shutdown.store(true, Ordering::SeqCst);
                    state.queue_cv.notify_all();
                    writeln!(writer, "{}", obj(vec![("ok", Json::Bool(true))]))?;
                    return Ok(());
                }
                other => {
                    writeln!(
                        writer,
                        "{}",
                        obj(vec![("error", Json::Str(format!("unknown cmd '{other}'")))])
                    )?;
                }
            }
            continue;
        }
        let ids: Vec<i32> = match req.get("ids").and_then(|v| v.as_arr().map(|a| a.to_vec())) {
            Ok(arr) => arr.iter().filter_map(|v| v.as_i64().ok()).map(|v| v as i32).collect(),
            Err(e) => {
                writeln!(writer, "{}", obj(vec![("error", Json::Str(e.to_string()))]))?;
                continue;
            }
        };
        let class = match req.opt("class").map(|c| c.as_str().unwrap_or("")) {
            None => SloClass::Batch,
            Some("batch") => SloClass::Batch,
            Some("interactive") => {
                let deadline_secs = req
                    .opt("deadline_ms")
                    .and_then(|v| v.as_f64().ok())
                    .map(|ms| ms / 1e3)
                    .unwrap_or(state.default_deadline_secs);
                SloClass::Interactive { deadline_secs }
            }
            Some(other) => {
                writeln!(
                    writer,
                    "{}",
                    obj(vec![(
                        "error",
                        Json::Str(format!("unknown class '{other}' (interactive|batch)")),
                    )])
                )?;
                continue;
            }
        };
        match state.submit(&ids, class) {
            Ok(rx) => match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(reply)) => {
                    writeln!(
                        writer,
                        "{}",
                        obj(vec![
                            ("id", Json::Num(reply.id as f64)),
                            ("label", Json::Num(reply.label as f64)),
                            (
                                "latency_ms",
                                Json::Num((reply.queue_secs + reply.infer_secs) * 1e3),
                            ),
                            ("queue_ms", Json::Num(reply.queue_secs * 1e3)),
                            ("infer_ms", Json::Num(reply.infer_secs * 1e3)),
                        ])
                    )?;
                }
                Ok(Err(msg)) => {
                    writeln!(writer, "{}", obj(vec![("error", Json::Str(msg))]))?;
                }
                Err(_) => {
                    writeln!(
                        writer,
                        "{}",
                        obj(vec![(
                            "error",
                            Json::Str("timed out waiting for the serving worker".into()),
                        )])
                    )?;
                }
            },
            Err(msg) => {
                writeln!(writer, "{}", obj(vec![("error", Json::Str(msg))]))?;
            }
        }
    }
    Ok(())
}

/// Run the TCP server until a `shutdown` command arrives.
pub fn run_server(state: Arc<ServerState>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    println!("sida-moe serving on {addr} (model {})", state.runner.bundle.topology.name);
    run_server_on(state, listener)
}

/// Serve on an already-bound listener (lets tests bind port 0 and read
/// the ephemeral address before starting the accept loop).  Spawns the
/// shared batch worker, accepts connections until shutdown, then joins
/// connection threads and the worker (which drains the queue first).
pub fn run_server_on(state: Arc<ServerState>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    // --metrics-interval: publish a fresh snapshot into the registry and
    // print one line to stderr every interval; polls shutdown at 50ms so
    // teardown is prompt
    let reporter = (state.metrics_interval_secs > 0.0).then(|| {
        let st = state.clone();
        std::thread::Builder::new()
            .name("sida-metrics".into())
            .spawn(move || {
                let tick = Duration::from_millis(50);
                let mut elapsed = 0.0;
                while !st.shutdown.load(Ordering::SeqCst) {
                    std::thread::sleep(tick);
                    elapsed += tick.as_secs_f64();
                    if elapsed + 1e-9 >= st.metrics_interval_secs {
                        elapsed = 0.0;
                        publish_stats_fields(&st.obs, &stats_fields(&st));
                        eprintln!("{}", crate::obs::publish::snapshot_line(&st.obs));
                    }
                }
            })
            .expect("spawn metrics reporter")
    });
    let worker = {
        let st = state.clone();
        std::thread::Builder::new()
            .name("sida-batch-worker".into())
            .spawn(move || worker_loop(&st))
            .expect("spawn batch worker")
    };
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        if state.shutdown.load(Ordering::SeqCst) {
            break;
        }
        // reap finished connection threads so a long-lived server does
        // not accumulate one dead JoinHandle per connection ever served
        handles.retain(|h| !h.is_finished());
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                let st = state.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(st, stream) {
                        log::warn!("connection error: {e:#}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    state.queue_cv.notify_all();
    let _ = worker.join();
    if let Some(h) = reporter {
        let _ = h.join();
    }
    if !state.trace_out.is_empty() {
        crate::obs::trace::write_to(&state.trace_out)?;
        log::info!(
            "trace: {} events ({} dropped) -> {}",
            crate::obs::trace::len(),
            crate::obs::trace::dropped(),
            state.trace_out
        );
    }
    Ok(())
}
