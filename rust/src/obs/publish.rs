//! Snapshot publishers: mirror the serving stack's accumulator structs
//! (`ServeStats` and friends) into a [`Registry`].
//!
//! The accumulators stay the single WRITERS (hot paths keep their plain
//! counters and the tests that assert on them keep working); the
//! registry is the single EXPORT surface.  Everything that leaves the
//! process — the serve report, the server's `cmd:stats` JSON, the
//! `cmd:metrics` Prometheus text, the `--metrics-interval` stderr line
//! — reads one published snapshot, so the views cannot drift.
//!
//! Conventions: every series is `sida_`-prefixed; counters end in
//! `_total`; seconds/bytes units are spelled in the name; optional
//! ratios (`hit_rate`, `slo_attainment`, …) publish `NaN` when the run
//! produced no traffic for them — the same distinction the report
//! structs make with `Option`/`null`.

use crate::metrics::ServeStats;
use crate::obs::registry::Registry;
use crate::obs::{prom, trace};

fn opt(v: Option<f64>) -> f64 {
    v.unwrap_or(f64::NAN)
}

/// Publish one serving run's aggregate stats.  Idempotent: republishing
/// a newer snapshot overwrites the same series.
pub fn publish_serve_stats(reg: &Registry, stats: &ServeStats) {
    // ---- request flow -----------------------------------------------------
    reg.counter("sida_requests_total", "requests served").set(stats.requests);
    reg.counter("sida_batches_total", "forward passes issued").set(stats.batches);
    reg.counter("sida_shed_total", "admitted requests shed with a blown deadline")
        .set(stats.shed);
    reg.counter_with(
        "sida_rejected_total",
        &[("reason", "queue_full")],
        "arrivals rejected at admission",
    )
    .set(stats.rejected);
    reg.counter_with(
        "sida_rejected_total",
        &[("reason", "slo")],
        "arrivals rejected at admission",
    )
    .set(stats.rejected_slo);
    reg.counter("sida_interactive_offered_total", "interactive requests offered")
        .set(stats.interactive_offered);
    reg.counter("sida_slo_attained_total", "interactive requests served within deadline")
        .set(stats.slo_attained);
    reg.gauge("sida_slo_attainment_ratio", "attained / offered interactive (NaN: none offered)")
        .set(opt(stats.slo_attainment()));
    reg.gauge("sida_mean_batch_size", "requests per formed batch (NaN: no batches)")
        .set(opt(stats.mean_batch_size()));
    reg.gauge("sida_throughput_rps", "served requests per wall second")
        .set(stats.throughput());

    // ---- time -------------------------------------------------------------
    reg.gauge("sida_wall_seconds", "wall-clock seconds of the run").set(stats.wall_secs);
    reg.gauge("sida_hash_build_seconds", "hash-building thread seconds (overlapped)")
        .set(stats.hash_build_secs);
    reg.gauge(
        "sida_modeled_request_seconds",
        "modeled per-request latency: critical path + exposed transfer (NaN: no requests)",
    )
    .set(opt(stats.modeled_request_secs()));
    let phases: &[(&str, f64)] = &[
        ("dense", stats.phases.dense_secs),
        ("selection", stats.phases.selection_secs),
        ("gather", stats.phases.gather_secs),
        ("expert", stats.phases.expert_secs),
        ("expert_wall", stats.phases.expert_wall_secs),
        ("scatter", stats.phases.scatter_secs),
        ("stall", stats.phases.stall_secs),
        ("transfer", stats.phases.transfer_secs),
    ];
    for (phase, secs) in phases {
        reg.gauge_with(
            "sida_phase_seconds",
            &[("phase", phase)],
            "cumulative forward-phase seconds",
        )
        .set(*secs);
    }
    reg.counter("sida_expert_invocations_total", "expert FFN invocations")
        .set(stats.phases.expert_invocations as u64);

    // ---- latency ----------------------------------------------------------
    reg.histogram("sida_request_latency_seconds", "end-to-end request latency")
        .reload(stats.latency.samples().iter().copied());
    let quantiles: &[(&str, f64)] = &[("p50", 0.50), ("p95", 0.95), ("p99", 0.99), ("p999", 0.999)];
    let mut lat = stats.latency.clone();
    let mut lat_int = stats.latency_interactive.clone();
    let mut lat_batch = stats.latency_batch.clone();
    for (name, q) in quantiles {
        reg.gauge_with(
            "sida_latency_seconds",
            &[("class", "all"), ("q", name)],
            "exact nearest-rank latency quantiles",
        )
        .set(if lat.is_empty() { f64::NAN } else { lat.quantile(*q) });
        reg.gauge_with(
            "sida_latency_seconds",
            &[("class", "interactive"), ("q", name)],
            "exact nearest-rank latency quantiles",
        )
        .set(if lat_int.is_empty() { f64::NAN } else { lat_int.quantile(*q) });
        reg.gauge_with(
            "sida_latency_seconds",
            &[("class", "batch"), ("q", name)],
            "exact nearest-rank latency quantiles",
        )
        .set(if lat_batch.is_empty() { f64::NAN } else { lat_batch.quantile(*q) });
    }

    // ---- memory + cache ---------------------------------------------------
    reg.gauge("sida_peak_device_bytes", "peak simulated device bytes")
        .set(stats.peak_device_bytes as f64);
    reg.gauge("sida_budget_bytes", "simulated device budget").set(stats.budget_bytes as f64);
    reg.counter("sida_cache_hits_total", "expert cache hits").set(stats.cache_hits);
    reg.counter("sida_cache_misses_total", "expert cache misses").set(stats.cache_misses);
    reg.counter("sida_cache_blocking_misses_total", "misses paid on the critical path")
        .set(stats.blocking_misses);
    reg.counter("sida_cache_evictions_total", "expert cache evictions").set(stats.evictions);
    reg.gauge("sida_cache_hit_ratio", "hits / (hits + misses) (NaN: no traffic)")
        .set(opt(stats.hit_rate()));
    reg.counter("sida_transferred_sim_bytes_total", "simulated H2D bytes moved")
        .set(stats.transferred_bytes);
    reg.gauge("sida_modeled_transfer_seconds", "modeled H2D transfer seconds (both timelines)")
        .set(stats.modeled_transfer_secs);
    reg.gauge(
        "sida_overlapped_transfer_seconds",
        "modeled transfer seconds hidden behind compute",
    )
    .set(stats.overlapped_transfer_secs);
    reg.gauge("sida_exposed_transfer_seconds", "modeled transfer seconds on the critical path")
        .set(stats.exposed_transfer_secs());

    // ---- cross-layer prefetch bandwidth scheduler -------------------------
    reg.gauge("sida_prefetch_backlog_secs", "staging seconds queued on the bandwidth window")
        .set(stats.prefetch_backlog_secs);
    reg.gauge(
        "sida_prefetch_carried_backlog_secs",
        "backlog seconds carried across epoch resets (drain-or-carry)",
    )
    .set(stats.prefetch_carried_backlog_secs);
    reg.gauge(
        "sida_prefetch_window_utilization",
        "used / offered window drain capacity (NaN: no drain yet)",
    )
    .set(opt(stats.prefetch_window_utilization));
    reg.counter("sida_prefetch_admitted_total", "fetches admitted EDF into the window")
        .set(stats.prefetch_admitted);
    reg.counter(
        "sida_prefetch_deferred_total",
        "low-confidence speculative fetches deferred by the scheduler",
    )
    .set(stats.prefetch_deferred);

    // ---- §6 tier ladder ---------------------------------------------------
    let h = &stats.hierarchy;
    reg.gauge("sida_ladder_seconds", "tier-ladder seconds (== modeled transfer attribution)")
        .set(stats.ladder_secs());
    let tiers: &[(&str, usize)] =
        &[("device", h.device_bytes), ("ram", h.ram_bytes), ("ssd", h.ssd_bytes)];
    for (tier, bytes) in tiers {
        reg.gauge_with("sida_tier_bytes", &[("tier", tier)], "simulated bytes resident per tier")
            .set(*bytes as f64);
    }
    reg.counter_with(
        "sida_ladder_promotions_total",
        &[("from", "ram")],
        "promotions into device tier by source",
    )
    .set(h.promotions_from_ram);
    reg.counter_with(
        "sida_ladder_promotions_total",
        &[("from", "ssd")],
        "promotions into device tier by source",
    )
    .set(h.promotions_from_ssd);
    reg.counter_with(
        "sida_ladder_demotions_total",
        &[("to", "ram")],
        "device-tier demotions by destination",
    )
    .set(h.demotions_to_ram);
    reg.counter_with(
        "sida_ladder_demotions_total",
        &[("to", "ssd")],
        "device-tier demotions by destination",
    )
    .set(h.demotions_to_ssd);
    reg.gauge_with(
        "sida_ladder_promote_seconds",
        &[("from", "ram")],
        "modeled promotion seconds by source tier",
    )
    .set(h.ram_promote_secs);
    reg.gauge_with(
        "sida_ladder_promote_seconds",
        &[("from", "ssd")],
        "modeled promotion seconds by source tier",
    )
    .set(h.ssd_promote_secs);
    reg.gauge_with(
        "sida_measured_ssd_seconds",
        &[("op", "read")],
        "measured wall seconds of on-disk store I/O",
    )
    .set(h.measured_ssd_read_secs);
    reg.gauge_with(
        "sida_measured_ssd_seconds",
        &[("op", "write")],
        "measured wall seconds of on-disk store I/O",
    )
    .set(h.measured_ssd_write_secs);
    reg.gauge("sida_store_bytes_on_disk", "expert-store bytes on disk")
        .set(h.store_bytes_on_disk as f64);
    reg.counter("sida_store_hits_total", "SSD promotions served by verified reads")
        .set(h.store_hits);
    reg.counter("sida_store_misses_total", "SSD promotions with no readable blob")
        .set(h.store_misses);
    reg.counter("sida_store_writes_total", "blobs written to disk").set(h.store_writes);
    reg.counter("sida_store_refabrications_total", "promotions re-fabricated from the bundle")
        .set(h.refabrications);
    reg.counter("sida_store_integrity_failures_total", "blob verifications that failed")
        .set(h.integrity_failures);
    reg.counter("sida_store_reclaimed_total", "store entries reclaimed by the SSD budget")
        .set(h.store_reclaimed);

    // ---- cluster ----------------------------------------------------------
    if let Some(cs) = &stats.cluster {
        publish_cluster(reg, cs);
    }
    publish_trace_health(reg);
}

fn publish_cluster(reg: &Registry, cs: &crate::cluster::ClusterStats) {
    use crate::cluster::DeviceHealth;
    reg.gauge("sida_cluster_devices", "devices in the modeled fleet")
        .set(cs.devices.len() as f64);
    reg.gauge("sida_cluster_replicated_entries", "placement entries beyond one home per expert")
        .set(cs.replicated_entries as f64);
    reg.counter("sida_cluster_cross_device_bytes_total", "activation bytes across the fabric")
        .set(cs.cross_device_bytes);
    reg.gauge("sida_cluster_interconnect_seconds", "modeled activation-transfer seconds")
        .set(cs.interconnect_secs);
    reg.counter("sida_cluster_replans_total", "placement (re)computations").set(cs.replans);
    reg.counter("sida_cluster_failovers_total", "jobs rerouted off a Down home")
        .set(cs.failovers);
    reg.counter("sida_cluster_failover_promotions_total", "failovers with no healthy holder")
        .set(cs.failover_promotions);
    reg.counter("sida_cluster_retries_total", "lanes recomputed after a mid-batch crash")
        .set(cs.retries);
    reg.counter("sida_cluster_dropped_fetches_total", "planned prefetches dropped by faults")
        .set(cs.dropped_fetches);
    reg.counter("sida_cluster_device_failures_total", "Up->Down transitions")
        .set(cs.device_failures);
    reg.counter("sida_cluster_recoveries_total", "Down->Up transitions").set(cs.recoveries);
    reg.gauge("sida_cluster_downtime_seconds", "measured wall seconds devices spent Down")
        .set(cs.downtime_secs);
    reg.gauge("sida_cluster_load_imbalance", "max-over-mean row load (NaN: idle)")
        .set(opt(cs.load_imbalance()));
    reg.gauge("sida_cluster_compute_imbalance", "max-over-mean bucket-unit load (NaN: idle)")
        .set(opt(cs.compute_imbalance()));
    for d in &cs.devices {
        let id = d.device.to_string();
        let l: &[(&str, &str)] = &[("device", id.as_str())];
        reg.gauge_with("sida_device_up", l, "1 Up, 0.5 Degraded, 0 Down").set(match d.health {
            DeviceHealth::Up => 1.0,
            DeviceHealth::Degraded => 0.5,
            DeviceHealth::Down => 0.0,
        });
        reg.gauge_with("sida_device_peak_bytes", l, "peak simulated bytes per device")
            .set(d.peak_bytes as f64);
        reg.gauge_with("sida_device_used_bytes", l, "simulated bytes resident per device")
            .set(d.used_bytes as f64);
        reg.gauge_with("sida_device_resident_experts", l, "experts resident per device")
            .set(d.resident_experts as f64);
        reg.gauge_with("sida_device_assigned_experts", l, "placement entries per device")
            .set(d.assigned_experts as f64);
        reg.counter_with("sida_device_rows_total", l, "token rows dispatched per device")
            .set(d.rows);
        reg.counter_with("sida_device_bucket_units_total", l, "dispatch buckets per device")
            .set(d.bucket_units);
        reg.counter_with("sida_device_cache_hits_total", l, "cache hits per device")
            .set(d.cache.hits);
        reg.counter_with("sida_device_cache_misses_total", l, "cache misses per device")
            .set(d.cache.misses);
    }
}

/// Publish the tracer's own health counters (buffer fill + drops).
pub fn publish_trace_health(reg: &Registry) {
    reg.counter("sida_trace_events_dropped_total", "trace ring-buffer events dropped (oldest)")
        .set(trace::dropped());
    reg.gauge("sida_trace_events", "trace events currently buffered").set(trace::len() as f64);
    reg.gauge("sida_trace_enabled", "1 when span tracing is recording")
        .set(if trace::enabled() { 1.0 } else { 0.0 });
}

/// Prometheus text for the registry's current contents.
pub fn render_text(reg: &Registry) -> String {
    prom::render(&reg.snapshot())
}

/// One compact stderr line for `--metrics-interval`: every non-zero
/// counter/gauge as `name{labels}=value`.
pub fn snapshot_line(reg: &Registry) -> String {
    use crate::obs::registry::SnapValue;
    let mut out = String::from("metrics:");
    for s in reg.snapshot() {
        let val = match s.value {
            SnapValue::Counter(0) => continue,
            SnapValue::Counter(n) => format!("{n}"),
            SnapValue::Gauge(v) if v == 0.0 || v.is_nan() => continue,
            SnapValue::Gauge(v) => format!("{v:.6}"),
            SnapValue::Histogram { .. } => continue,
        };
        out.push(' ');
        out.push_str(&s.name);
        if !s.labels.is_empty() {
            out.push('{');
            out.push_str(&s.labels);
            out.push('}');
        }
        out.push('=');
        out.push_str(&val);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publishes_a_rich_series_set() {
        let reg = Registry::new();
        let mut stats = ServeStats::default();
        stats.requests = 8;
        stats.batches = 2;
        stats.wall_secs = 0.5;
        stats.cache_hits = 30;
        stats.cache_misses = 10;
        stats.latency.record(0.010);
        stats.latency.record(0.020);
        stats.hierarchy.promotions_from_ssd = 3;
        stats.hierarchy.ssd_promote_secs = 0.3;
        stats.prefetch_backlog_secs = 0.125;
        stats.prefetch_admitted = 7;
        stats.prefetch_deferred = 2;
        stats.prefetch_window_utilization = Some(0.5);
        publish_serve_stats(&reg, &stats);
        // the acceptance floor is 25 exported series; single-device
        // publishing alone must clear it with headroom
        assert!(reg.series_count() >= 25, "only {} series", reg.series_count());
        let text = render_text(&reg);
        assert_eq!(prom::sample(&text, "sida_requests_total"), Some(8.0));
        assert_eq!(prom::sample(&text, "sida_prefetch_backlog_secs"), Some(0.125));
        assert_eq!(prom::sample(&text, "sida_prefetch_admitted_total"), Some(7.0));
        assert_eq!(prom::sample(&text, "sida_prefetch_deferred_total"), Some(2.0));
        assert_eq!(prom::sample(&text, "sida_prefetch_window_utilization"), Some(0.5));
        assert_eq!(prom::sample(&text, "sida_cache_hits_total"), Some(30.0));
        assert_eq!(prom::sample(&text, "sida_cache_hit_ratio"), Some(0.75));
        assert_eq!(
            prom::sample(&text, "sida_ladder_promotions_total{from=\"ssd\"}"),
            Some(3.0)
        );
        assert_eq!(prom::sample(&text, "sida_request_latency_seconds_count"), Some(2.0));
    }

    #[test]
    fn republish_overwrites_not_accumulates() {
        let reg = Registry::new();
        let mut stats = ServeStats::default();
        stats.requests = 5;
        publish_serve_stats(&reg, &stats);
        stats.requests = 9;
        publish_serve_stats(&reg, &stats);
        let text = render_text(&reg);
        assert_eq!(prom::sample(&text, "sida_requests_total"), Some(9.0));
    }

    #[test]
    fn cluster_devices_get_labeled_series() {
        use crate::cluster::{ClusterStats, DeviceStats};
        let reg = Registry::new();
        let mut stats = ServeStats::default();
        let mut cs = ClusterStats::default();
        for id in 0..2 {
            let mut d = DeviceStats { device: id, ..Default::default() };
            d.rows = 10 + id as u64;
            cs.devices.push(d);
        }
        cs.failovers = 4;
        stats.cluster = Some(cs);
        publish_serve_stats(&reg, &stats);
        let text = render_text(&reg);
        assert_eq!(prom::sample(&text, "sida_device_rows_total{device=\"0\"}"), Some(10.0));
        assert_eq!(prom::sample(&text, "sida_device_rows_total{device=\"1\"}"), Some(11.0));
        assert_eq!(prom::sample(&text, "sida_cluster_failovers_total"), Some(4.0));
        assert_eq!(prom::sample(&text, "sida_device_up{device=\"0\"}"), Some(1.0));
    }

    #[test]
    fn snapshot_line_skips_zeros() {
        let reg = Registry::new();
        reg.counter("sida_a_total", "a").set(0);
        reg.counter("sida_b_total", "b").set(7);
        let line = snapshot_line(&reg);
        assert!(line.contains("sida_b_total=7"));
        assert!(!line.contains("sida_a_total"));
    }
}
