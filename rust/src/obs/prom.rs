//! Prometheus text exposition (version 0.0.4) rendered from a registry
//! snapshot: `# HELP`/`# TYPE` once per metric family, counters and
//! gauges as plain samples, histograms as cumulative `_bucket{le=...}`
//! series plus `_sum` and `_count`.

use crate::obs::registry::{SeriesSnapshot, SnapValue};

/// Format a sample value the way Prometheus expects: integers without a
/// decimal point, infinities as `+Inf`/`-Inf`.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        return "NaN".to_string();
    }
    if v == f64::INFINITY {
        return "+Inf".to_string();
    }
    if v == f64::NEG_INFINITY {
        return "-Inf".to_string();
    }
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

fn sample_line(out: &mut String, name: &str, labels: &str, value: &str) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        out.push_str(labels);
        out.push('}');
    }
    out.push(' ');
    out.push_str(value);
    out.push('\n');
}

fn join_labels(base: &str, extra: &str) -> String {
    if base.is_empty() {
        extra.to_string()
    } else if extra.is_empty() {
        base.to_string()
    } else {
        format!("{base},{extra}")
    }
}

/// Render a snapshot (sorted by name, as [`crate::obs::Registry::snapshot`]
/// produces) as Prometheus text format.
pub fn render(snaps: &[SeriesSnapshot]) -> String {
    let mut out = String::new();
    let mut last_name: Option<&str> = None;
    for s in snaps {
        if last_name != Some(s.name.as_str()) {
            out.push_str(&format!("# HELP {} {}\n", s.name, escape_help(&s.help)));
            let kind = match &s.value {
                SnapValue::Counter(_) => "counter",
                SnapValue::Gauge(_) => "gauge",
                SnapValue::Histogram { .. } => "histogram",
            };
            out.push_str(&format!("# TYPE {} {}\n", s.name, kind));
            last_name = Some(s.name.as_str());
        }
        match &s.value {
            SnapValue::Counter(n) => {
                sample_line(&mut out, &s.name, &s.labels, &format!("{n}"));
            }
            SnapValue::Gauge(v) => {
                sample_line(&mut out, &s.name, &s.labels, &fmt_value(*v));
            }
            SnapValue::Histogram { cumulative, sum, count } => {
                let bucket = format!("{}_bucket", s.name);
                for (le, cum) in cumulative {
                    let labels = join_labels(&s.labels, &format!("le=\"{}\"", fmt_value(*le)));
                    sample_line(&mut out, &bucket, &labels, &format!("{cum}"));
                }
                sample_line(&mut out, &format!("{}_sum", s.name), &s.labels, &fmt_value(*sum));
                sample_line(&mut out, &format!("{}_count", s.name), &s.labels, &format!("{count}"));
            }
        }
    }
    out
}

/// Look up one sample in rendered text by its full series name
/// (including labels, e.g. `sida_device_rows_total{device="0"}`).
/// Used by the view-agreement tests.
pub fn sample(text: &str, series: &str) -> Option<f64> {
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let Some((name, value)) = line.rsplit_once(' ') else {
            continue;
        };
        if name == series {
            return match value {
                "+Inf" => Some(f64::INFINITY),
                "-Inf" => Some(f64::NEG_INFINITY),
                v => v.parse().ok(),
            };
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::Registry;

    #[test]
    fn renders_families_once_and_samples_per_series() {
        let reg = Registry::new();
        reg.counter_with("sida_x_total", &[("device", "0")], "x help").add(3);
        reg.counter_with("sida_x_total", &[("device", "1")], "x help").add(5);
        reg.gauge("sida_y_bytes", "y help").set(1.5e9);
        let text = render(&reg.snapshot());
        assert_eq!(text.matches("# HELP sida_x_total").count(), 1);
        assert_eq!(text.matches("# TYPE sida_x_total counter").count(), 1);
        assert_eq!(sample(&text, "sida_x_total{device=\"0\"}"), Some(3.0));
        assert_eq!(sample(&text, "sida_x_total{device=\"1\"}"), Some(5.0));
        assert_eq!(sample(&text, "sida_y_bytes"), Some(1.5e9));
    }

    #[test]
    fn histogram_renders_cumulative_buckets() {
        let reg = Registry::new();
        let h = reg.histogram_with("sida_lat_seconds", &[], "latency", &[0.1, 1.0]);
        h.observe(0.05);
        h.observe(0.5);
        h.observe(5.0);
        let text = render(&reg.snapshot());
        assert_eq!(sample(&text, "sida_lat_seconds_bucket{le=\"0.1\"}"), Some(1.0));
        assert_eq!(sample(&text, "sida_lat_seconds_bucket{le=\"1\"}"), Some(2.0));
        assert_eq!(sample(&text, "sida_lat_seconds_bucket{le=\"+Inf\"}"), Some(3.0));
        assert_eq!(sample(&text, "sida_lat_seconds_count"), Some(3.0));
        assert!((sample(&text, "sida_lat_seconds_sum").unwrap() - 5.55).abs() < 1e-12);
        assert_eq!(text.matches("# TYPE sida_lat_seconds histogram").count(), 1);
    }

    #[test]
    fn integer_and_float_formatting() {
        assert_eq!(fmt_value(5.0), "5");
        assert_eq!(fmt_value(0.25), "0.25");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
    }
}
