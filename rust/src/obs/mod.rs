//! Observability: the unified metrics registry, the per-request span
//! tracer, and the Prometheus text exposition (DESIGN.md §2.8).
//!
//! Three pieces, one rule — every number that leaves the process goes
//! through the [`Registry`]:
//!
//! * [`registry`] — typed [`Counter`]/[`Gauge`]/[`Histogram`] handles
//!   backed by atomics, registered once under (name, labels);
//! * [`trace`] — a ring-buffer span recorder (off by default,
//!   `--trace-out FILE` to enable) exported as Chrome trace-event JSON
//!   with request ids as flow events;
//! * [`prom`] + [`publish`] — snapshot publishers mapping the serving
//!   accumulator structs into registry series and rendering them as
//!   Prometheus text (`cmd:metrics`) or a compact stderr line
//!   (`--metrics-interval`).

pub mod prom;
pub mod publish;
pub mod registry;
pub mod trace;

pub use registry::{default_secs_buckets, Counter, Gauge, Histogram, Registry};
pub use registry::{SeriesSnapshot, SnapValue};
