//! Per-request span tracer: a lock-cheap ring-buffer event recorder
//! exported as Chrome trace-event JSON (loadable in `chrome://tracing`
//! and Perfetto).
//!
//! Off by default.  When disabled every emission helper is a single
//! relaxed atomic load and an early return — no clock reads, no
//! allocation, no lock — which is what lets the serving hot path keep
//! emission calls unconditionally inline.  `--trace-out FILE` (serve and
//! server CLIs) enables recording and writes the JSON on exit.
//!
//! Model:
//!
//! * **pid** — one Chrome "process" per device timeline: pid 0 is the
//!   host (batcher, scheduler, server threads), pid `1 + d` is device
//!   `d` (see [`crate::obs::trace::host_pid`] / [`device_pid`]);
//! * **tid** — one Chrome "thread" per OS worker thread (small dense
//!   ids handed out per thread on first emission);
//! * **spans** — `ph:"X"` complete events with µs timestamps/durations;
//!   exact f64 second values ride in `args` so trace consumers (and the
//!   self-consistency test in `tests/obs.rs`) are not limited to µs
//!   resolution;
//! * **flows** — request ids become flow events (`ph:"s"/"t"/"f"`, name
//!   `req`) so one request can be followed from batch formation through
//!   per-layer device lanes to completion;
//! * **ring buffer** — bounded at [`enable`]'s capacity; when full the
//!   OLDEST event is dropped and `dropped()` counts it (exported as
//!   `sida_trace_events_dropped_total`).
//!
//! Recording never touches the f32 compute path: with tracing on,
//! outputs are bit-identical to a traced-off run (asserted by
//! `tests/obs.rs` and the `fig_obs` bench gate).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Default ring capacity (events) used by `--trace-out`.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static T0: OnceLock<Instant> = OnceLock::new();
static BUF: OnceLock<Mutex<TraceBuf>> = OnceLock::new();

struct TraceBuf {
    events: VecDeque<Event>,
    cap: usize,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn buf() -> &'static Mutex<TraceBuf> {
    BUF.get_or_init(|| Mutex::new(TraceBuf { events: VecDeque::new(), cap: DEFAULT_CAPACITY }))
}

/// Small dense per-thread id (first emission on a thread assigns one).
fn tid() -> u64 {
    thread_local! {
        static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// Chrome pid for host-side timelines (queue, batching, scatter).
pub fn host_pid() -> u32 {
    0
}

/// Chrome pid for device `d`'s timeline.
pub fn device_pid(device: usize) -> u32 {
    1 + device as u32
}

/// One recorded trace event (see the Chrome trace-event format).
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    pub cat: &'static str,
    pub ph: char,
    pub ts_us: u64,
    pub dur_us: u64,
    pub pid: u32,
    pub tid: u64,
    /// Flow id (`ph` s/t/f); 0 means "no id".
    pub id: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

#[derive(Clone, Debug)]
pub enum ArgValue {
    U(u64),
    F(f64),
    S(String),
}

impl ArgValue {
    fn to_json(&self) -> Json {
        match self {
            ArgValue::U(n) => Json::Num(*n as f64),
            ArgValue::F(x) => Json::Num(*x),
            ArgValue::S(s) => Json::Str(s.clone()),
        }
    }
}

// ---------------------------------------------------------------------------
// lifecycle
// ---------------------------------------------------------------------------

/// Start recording into a fresh ring of `cap` events.
pub fn enable(cap: usize) {
    let _ = T0.get_or_init(Instant::now);
    let mut b = lock(buf());
    b.cap = cap.max(1);
    b.events.clear();
    DROPPED.store(0, Ordering::Relaxed);
    ENABLED.store(true, Ordering::Release);
}

/// Stop recording (the buffer is kept for export).
pub fn disable() {
    ENABLED.store(false, Ordering::Release);
}

/// One relaxed load — THE guard every emission helper bails on.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Microseconds since the tracer first started (0 when never enabled).
pub fn now_us() -> u64 {
    match T0.get() {
        Some(t0) => t0.elapsed().as_micros() as u64,
        None => 0,
    }
}

/// Span-start helper: a timestamp when enabled, 0 (and no clock read)
/// when disabled.
#[inline]
pub fn begin() -> u64 {
    if enabled() {
        now_us()
    } else {
        0
    }
}

pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

pub fn len() -> usize {
    lock(buf()).events.len()
}

pub fn is_empty() -> bool {
    len() == 0
}

/// Clone the recorded events (oldest first).
pub fn snapshot_events() -> Vec<Event> {
    lock(buf()).events.iter().cloned().collect()
}

// ---------------------------------------------------------------------------
// emission
// ---------------------------------------------------------------------------

pub fn record(ev: Event) {
    if !enabled() {
        return;
    }
    let mut b = lock(buf());
    if b.events.len() >= b.cap {
        b.events.pop_front();
        DROPPED.fetch_add(1, Ordering::Relaxed);
    }
    b.events.push_back(ev);
}

/// Complete span (`ph:"X"`) from `start_us` (a [`begin`] value) to now.
pub fn complete(
    name: &'static str,
    cat: &'static str,
    pid: u32,
    start_us: u64,
    args: Vec<(&'static str, ArgValue)>,
) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        cat,
        ph: 'X',
        ts_us: start_us,
        dur_us: now_us().saturating_sub(start_us),
        pid,
        tid: tid(),
        id: 0,
        args,
    });
}

/// Complete span with explicit µs duration (for replayed timings).
pub fn complete_at(
    name: &'static str,
    cat: &'static str,
    pid: u32,
    start_us: u64,
    dur_us: u64,
    args: Vec<(&'static str, ArgValue)>,
) {
    if !enabled() {
        return;
    }
    record(Event { name, cat, ph: 'X', ts_us: start_us, dur_us, pid, tid: tid(), id: 0, args });
}

/// Instant event (`ph:"i"`).
pub fn instant(
    name: &'static str,
    cat: &'static str,
    pid: u32,
    args: Vec<(&'static str, ArgValue)>,
) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        cat,
        ph: 'i',
        ts_us: now_us(),
        dur_us: 0,
        pid,
        tid: tid(),
        id: 0,
        args,
    });
}

/// Flow event for a request id: `ph` is `'s'` (start, at batch
/// formation), `'t'` (step, inside a device lane span) or `'f'` (end,
/// at request completion).  Flow events bind to the enclosing slice on
/// the same pid/tid at this timestamp.
pub fn flow(ph: char, request_id: u64, pid: u32) {
    if !enabled() {
        return;
    }
    record(Event {
        name: "req",
        cat: "flow",
        ph,
        ts_us: now_us(),
        dur_us: 0,
        pid,
        tid: tid(),
        // flow ids must be non-zero; offset keeps request id 0 traceable
        id: request_id + 1,
        args: vec![("request", ArgValue::U(request_id))],
    });
}

// ---------------------------------------------------------------------------
// export
// ---------------------------------------------------------------------------

fn event_json(ev: &Event) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(ev.name.to_string()));
    o.insert("cat".to_string(), Json::Str(ev.cat.to_string()));
    o.insert("ph".to_string(), Json::Str(ev.ph.to_string()));
    o.insert("ts".to_string(), Json::Num(ev.ts_us as f64));
    o.insert("pid".to_string(), Json::Num(ev.pid as f64));
    o.insert("tid".to_string(), Json::Num(ev.tid as f64));
    if ev.ph == 'X' {
        o.insert("dur".to_string(), Json::Num(ev.dur_us as f64));
    }
    if ev.id != 0 {
        o.insert("id".to_string(), Json::Num(ev.id as f64));
    }
    if ev.ph == 'f' {
        // bind the flow end to the enclosing slice, not the next one
        o.insert("bp".to_string(), Json::Str("e".to_string()));
    }
    if ev.ph == 'i' {
        o.insert("s".to_string(), Json::Str("t".to_string()));
    }
    if !ev.args.is_empty() {
        let args: BTreeMap<String, Json> =
            ev.args.iter().map(|(k, v)| (k.to_string(), v.to_json())).collect();
        o.insert("args".to_string(), Json::Obj(args));
    }
    Json::Obj(o)
}

fn metadata_json(pid: u32, tid: Option<u64>, name: String) -> Json {
    let mut o = BTreeMap::new();
    o.insert(
        "name".to_string(),
        Json::Str(if tid.is_some() { "thread_name" } else { "process_name" }.to_string()),
    );
    o.insert("ph".to_string(), Json::Str("M".to_string()));
    o.insert("ts".to_string(), Json::Num(0.0));
    o.insert("pid".to_string(), Json::Num(pid as f64));
    o.insert("tid".to_string(), Json::Num(tid.unwrap_or(0) as f64));
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(name));
    o.insert("args".to_string(), Json::Obj(args));
    Json::Obj(o)
}

/// The full Chrome trace-event document for the recorded buffer.
pub fn export_json() -> Json {
    let events = snapshot_events();
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    let mut tids: BTreeSet<(u32, u64)> = BTreeSet::new();
    for ev in &events {
        pids.insert(ev.pid);
        tids.insert((ev.pid, ev.tid));
    }
    let mut arr = Vec::with_capacity(events.len() + pids.len() + tids.len());
    for pid in &pids {
        let name = if *pid == 0 {
            "host".to_string()
        } else {
            format!("device{}", pid - 1)
        };
        arr.push(metadata_json(*pid, None, name));
    }
    for (pid, tid) in &tids {
        arr.push(metadata_json(*pid, Some(*tid), format!("worker{tid}")));
    }
    arr.extend(events.iter().map(event_json));
    let mut o = BTreeMap::new();
    o.insert("traceEvents".to_string(), Json::Arr(arr));
    o.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    o.insert(
        "otherData".to_string(),
        Json::Obj(BTreeMap::from([(
            "dropped_events".to_string(),
            Json::Num(dropped() as f64),
        )])),
    );
    Json::Obj(o)
}

/// Write the trace document to `path`.
pub fn write_to(path: &str) -> Result<()> {
    std::fs::write(path, export_json().to_string())
        .with_context(|| format!("writing trace to {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // the tracer is process-global: serialize the tests that toggle it
    static LOCK: Mutex<()> = Mutex::new(());

    fn test_lock() -> MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _g = test_lock();
        disable();
        let before = len();
        complete("unit_noop", "test", 0, begin(), vec![]);
        instant("unit_noop_i", "test", 0, vec![]);
        flow('s', 42, 0);
        assert_eq!(len(), before);
        assert!(!snapshot_events().iter().any(|e| e.name.starts_with("unit_noop")));
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let _g = test_lock();
        enable(4);
        for i in 0..10u64 {
            record(Event {
                name: "unit_ring",
                cat: "test",
                ph: 'i',
                ts_us: i,
                dur_us: 0,
                pid: 0,
                tid: 0,
                id: 0,
                args: vec![("seq", ArgValue::U(i))],
            });
        }
        let events = snapshot_events();
        assert_eq!(events.len(), 4);
        assert_eq!(dropped(), 6);
        // the survivors are the NEWEST four, in order
        let seqs: Vec<u64> = events
            .iter()
            .map(|e| match e.args[0].1 {
                ArgValue::U(n) => n,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        disable();
    }

    #[test]
    fn export_is_valid_chrome_trace_json() {
        let _g = test_lock();
        enable(64);
        let t = begin();
        complete("unit_span", "test", 1, t, vec![("secs", ArgValue::F(0.25))]);
        flow('s', 7, 1);
        instant("unit_mark", "test", 2, vec![("k", ArgValue::S("v".to_string()))]);
        let doc = export_json();
        disable();
        // roundtrip through the serializer and parser
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name + >=1 thread_name + 3 recorded
        assert!(events.len() >= 6, "got {} events", events.len());
        let span = events
            .iter()
            .find(|e| e.get_str("name").is_ok_and(|n| n == "unit_span"))
            .expect("span exported");
        assert_eq!(span.get_str("ph").unwrap(), "X");
        assert!(span.get("dur").is_ok());
        assert_eq!(span.get("args").unwrap().get_f64("secs").unwrap(), 0.25);
        let f = events
            .iter()
            .find(|e| e.get_str("ph").is_ok_and(|p| p == "s"))
            .expect("flow start exported");
        assert_eq!(f.get("id").unwrap().as_u64().unwrap(), 8);
        assert!(events.iter().any(|e| {
            e.get_str("name").is_ok_and(|n| n == "process_name")
                && e.get("args").unwrap().get_str("name").is_ok_and(|n| n == "device0")
        }));
    }
}
