//! Unified metrics registry: typed `Counter`/`Gauge`/`Histogram` handles
//! backed by atomics, registered once under a (name, labels) key.
//!
//! The serving stack accumulates statistics in several purpose-built
//! structs (`CacheStats`, `BatchingStats`, `ClusterStats`,
//! `HierarchyStats`, `ServeStats`).  Those structs stay — they are the
//! snapshot views the tests and benches assert on — but every exported
//! number now flows through ONE registry so the serve report, the
//! server's `cmd:stats`/`cmd:metrics` replies and the bench JSON all
//! read the same series (see [`crate::obs::publish`]).
//!
//! Handles are cheap clones of an `Arc<AtomicU64>`; registration is
//! idempotent (same name + labels returns the same underlying cell) and
//! re-registering a name under a different type panics — that is a
//! programming error, not a runtime condition.
//!
//! Two registries matter in practice:
//!
//! * [`Registry::global`] — the process-wide registry the CLI serve
//!   path publishes into;
//! * per-instance registries (`Registry::new`) — the TCP server gives
//!   each `ServerState` its own so parallel test servers in one process
//!   do not pollute each other's exact counts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // a panicked holder leaves the data valid (all writes are atomic
    // stores); poisoning must not take metrics down with it
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// handles
// ---------------------------------------------------------------------------

/// Monotonically increasing event count (u64).
#[derive(Clone, Debug, Default)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.cell.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the count.  Used by the snapshot publishers, which
    /// mirror an externally accumulated total into the registry.
    pub fn set(&self, n: u64) {
        self.cell.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// Instantaneous f64 value (stored as IEEE-754 bits in an `AtomicU64`).
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    bits: Arc<AtomicU64>,
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, delta: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + delta).to_bits();
            match self
                .bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram with atomic per-bucket counts.
///
/// Unlike [`crate::metrics::LatencyHistogram`] (which keeps every
/// sample for exact quantiles) this is a constant-memory Prometheus
/// histogram: ascending finite upper bounds plus an implicit `+Inf`
/// bucket, a total count and an f64 sum.  Quantiles are therefore only
/// known to bucket resolution — [`Histogram::quantile_bounds`] returns
/// the enclosing bucket interval, which the tests check against the
/// exact `LatencyHistogram` answer.
#[derive(Clone, Debug)]
pub struct Histogram {
    core: Arc<HistCore>,
}

#[derive(Debug)]
struct HistCore {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

/// Default latency buckets (seconds): log-spaced 1µs .. 10s.
pub fn default_secs_buckets() -> Vec<f64> {
    vec![
        1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2,
        2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    ]
}

impl Histogram {
    fn with_bounds(bounds: &[f64]) -> Histogram {
        let mut bounds: Vec<f64> = bounds.iter().copied().filter(|b| b.is_finite()).collect();
        bounds.sort_by(|a, b| a.total_cmp(b));
        bounds.dedup();
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            core: Arc::new(HistCore {
                bounds,
                buckets,
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0),
            }),
        }
    }

    pub fn observe(&self, v: f64) {
        let core = &self.core;
        let idx = core
            .bounds
            .iter()
            .position(|b| v <= *b)
            .unwrap_or(core.bounds.len());
        core.buckets[idx].fetch_add(1, Ordering::Relaxed);
        core.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = core.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match core
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.core.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.core.sum_bits.load(Ordering::Relaxed))
    }

    /// Cumulative `(le, count)` pairs; the last entry is `+Inf`.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.core.bounds.len() + 1);
        let mut acc = 0u64;
        for (i, b) in self.core.bounds.iter().enumerate() {
            acc += self.core.buckets[i].load(Ordering::Relaxed);
            out.push((*b, acc));
        }
        acc += self.core.buckets[self.core.bounds.len()].load(Ordering::Relaxed);
        out.push((f64::INFINITY, acc));
        out
    }

    /// The `[lower, upper]` bucket interval containing the nearest-rank
    /// `q`-quantile (matching `LatencyHistogram::quantile` rank rules).
    pub fn quantile_bounds(&self, q: f64) -> (f64, f64) {
        let n = self.count();
        if n == 0 {
            return (0.0, 0.0);
        }
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let rank = ((q * n as f64).ceil() as u64).max(1);
        let mut lower = 0.0;
        for (le, cum) in self.cumulative() {
            if cum >= rank {
                return (lower, le);
            }
            lower = le;
        }
        (lower, f64::INFINITY)
    }

    /// Zero all buckets, the count and the sum.
    pub fn reset(&self) {
        for b in &self.core.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.core.count.store(0, Ordering::Relaxed);
        self.core.sum_bits.store(0, Ordering::Relaxed);
    }

    /// Reset then observe every sample: mirrors an exact sample set
    /// (e.g. a `LatencyHistogram`) into the bucketed exposition view.
    pub fn reload(&self, samples: impl IntoIterator<Item = f64>) {
        self.reset();
        for s in samples {
            self.observe(s);
        }
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum Series {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

#[derive(Debug)]
struct Entry {
    help: String,
    series: Series,
}

/// Snapshot of one series, ready for exposition (see [`crate::obs::prom`]).
#[derive(Clone, Debug)]
pub struct SeriesSnapshot {
    pub name: String,
    /// Rendered label pairs without braces (`device="0"`), or empty.
    pub labels: String,
    pub help: String,
    pub value: SnapValue,
}

#[derive(Clone, Debug)]
pub enum SnapValue {
    Counter(u64),
    Gauge(f64),
    Histogram {
        cumulative: Vec<(f64, u64)>,
        sum: f64,
        count: u64,
    },
}

/// Render label pairs as `k1="v1",k2="v2"` with Prometheus escaping.
fn fmt_labels(labels: &[(&str, &str)]) -> String {
    let mut out = String::new();
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out
}

#[derive(Debug, Default)]
pub struct Registry {
    series: Mutex<BTreeMap<(String, String), Entry>>,
}

static GLOBAL: OnceLock<Registry> = OnceLock::new();

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// The process-wide registry used by the CLI serve path.
    pub fn global() -> &'static Registry {
        GLOBAL.get_or_init(Registry::new)
    }

    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, &[], help)
    }

    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Counter {
        let entry = self.entry(name, labels, help, || Series::Counter(Counter::default()));
        match entry {
            Series::Counter(c) => c,
            _ => panic!("metric '{name}' already registered with a non-counter type"),
        }
    }

    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, &[], help)
    }

    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)], help: &str) -> Gauge {
        let entry = self.entry(name, labels, help, || Series::Gauge(Gauge::default()));
        match entry {
            Series::Gauge(g) => g,
            _ => panic!("metric '{name}' already registered with a non-gauge type"),
        }
    }

    /// Histogram with [`default_secs_buckets`] bounds.
    pub fn histogram(&self, name: &str, help: &str) -> Histogram {
        self.histogram_with(name, &[], help, &default_secs_buckets())
    }

    /// Bounds apply on first registration only; later calls return the
    /// existing series unchanged.
    pub fn histogram_with(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        bounds: &[f64],
    ) -> Histogram {
        let entry = self.entry(name, labels, help, || {
            Series::Histogram(Histogram::with_bounds(bounds))
        });
        match entry {
            Series::Histogram(h) => h,
            _ => panic!("metric '{name}' already registered with a non-histogram type"),
        }
    }

    fn entry(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        help: &str,
        make: impl FnOnce() -> Series,
    ) -> Series {
        let key = (name.to_string(), fmt_labels(labels));
        let mut map = lock(&self.series);
        map.entry(key)
            .or_insert_with(|| Entry { help: help.to_string(), series: make() })
            .series
            .clone()
    }

    pub fn series_count(&self) -> usize {
        lock(&self.series).len()
    }

    /// Sorted snapshot (by name, then labels) — all series of one
    /// metric family are contiguous, as the text exposition requires.
    pub fn snapshot(&self) -> Vec<SeriesSnapshot> {
        lock(&self.series)
            .iter()
            .map(|((name, labels), e)| SeriesSnapshot {
                name: name.clone(),
                labels: labels.clone(),
                help: e.help.clone(),
                value: match &e.series {
                    Series::Counter(c) => SnapValue::Counter(c.get()),
                    Series::Gauge(g) => SnapValue::Gauge(g.get()),
                    Series::Histogram(h) => SnapValue::Histogram {
                        cumulative: h.cumulative(),
                        sum: h.sum(),
                        count: h.count(),
                    },
                },
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent() {
        let reg = Registry::new();
        let a = reg.counter("x_total", "help");
        let b = reg.counter("x_total", "help");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(reg.series_count(), 1);
    }

    #[test]
    fn labels_split_series() {
        let reg = Registry::new();
        let a = reg.counter_with("y_total", &[("device", "0")], "h");
        let b = reg.counter_with("y_total", &[("device", "1")], "h");
        a.inc();
        assert_eq!(a.get(), 1);
        assert_eq!(b.get(), 0);
        assert_eq!(reg.series_count(), 2);
    }

    #[test]
    #[should_panic(expected = "non-counter")]
    fn type_mismatch_panics() {
        let reg = Registry::new();
        let _ = reg.gauge("z", "h");
        let _ = reg.counter("z", "h");
    }

    #[test]
    fn gauge_add_is_exact_under_contention() {
        let reg = Registry::new();
        let g = reg.gauge("g", "h");
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let g = g.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        g.add(0.5);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // 0.5 is a power of two: the CAS-summed total is exact
        assert_eq!(g.get(), 2000.0);
    }

    #[test]
    fn histogram_cumulative_is_monotone() {
        let h = Histogram::with_bounds(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 3.0, 8.0, 1.0] {
            h.observe(v);
        }
        let cum = h.cumulative();
        assert_eq!(cum.len(), 4);
        assert!(cum.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(cum.last().unwrap().1, 5);
        assert_eq!(h.count(), 5);
        assert!((h.sum() - 14.0).abs() < 1e-12);
        // le=1.0 is inclusive: 0.5 and 1.0 land there
        assert_eq!(cum[0], (1.0, 2));
    }

    #[test]
    fn quantile_bounds_bracket() {
        let h = Histogram::with_bounds(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 1.6, 3.0] {
            h.observe(v);
        }
        let (lo, hi) = h.quantile_bounds(0.5);
        assert_eq!((lo, hi), (1.0, 2.0));
        let (lo, hi) = h.quantile_bounds(1.0);
        assert_eq!((lo, hi), (2.0, 4.0));
        assert_eq!(h.quantile_bounds(0.0), (0.0, 1.0));
    }

    #[test]
    fn reload_replaces_contents() {
        let h = Histogram::with_bounds(&[1.0, 2.0]);
        h.observe(0.5);
        h.reload([1.5, 1.5, 5.0]);
        assert_eq!(h.count(), 3);
        let cum = h.cumulative();
        assert_eq!(cum[0].1, 0);
        assert_eq!(cum[1].1, 2);
        assert_eq!(cum[2].1, 3);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let reg = Registry::new();
        reg.counter("b_total", "bb").inc();
        reg.gauge("a_gauge", "aa").set(1.5);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].name, "a_gauge");
        assert_eq!(snap[1].name, "b_total");
        assert!(matches!(snap[0].value, SnapValue::Gauge(v) if v == 1.5));
        assert!(matches!(snap[1].value, SnapValue::Counter(1)));
    }
}
