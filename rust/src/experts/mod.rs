//! Expert residency management: keys, eviction policies, device cache.

pub mod cache;
pub mod policy;
pub mod prefetch;

pub use cache::{CacheStats, ExpertCache, ResidentExpert};
pub use prefetch::{plan_prefetch, PlannedFetch};
pub use policy::{make_policy, EvictionPolicy};

/// Identity of one expert: (transformer block index, expert index).
/// The unit of offloading in SiDA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertKey {
    pub block: usize,
    pub expert: usize,
}

impl ExpertKey {
    pub fn new(block: usize, expert: usize) -> Self {
        ExpertKey { block, expert }
    }
}
