//! Expert residency management: keys, eviction policies, the budgeted
//! device cache, and prefetch planning.
//!
//! The unit of offloading is one expert ([`ExpertKey`]: block × expert
//! index).  [`ExpertCache`] holds the staged weights of resident
//! experts under a simulated byte budget with pluggable eviction
//! ([`make_policy`]: fifo/lru/lfu/clock) and drives the §6 GPU → RAM →
//! SSD [`crate::memory::ResidencyLedger`] — evictions demote their
//! policy-chosen victim down the ladder and each miss is charged the
//! tier-aware promotion cost of where the expert really sat;
//! [`SharedExpertCache`] wraps it for the concurrent serving path
//! (read-lock hits, write-lock misses, counted pins — see that module
//! for the lock discipline); [`plan_prefetch`] /
//! [`plan_prefetch_union`] / [`plan_prefetch_layer`] turn hash-table
//! predictions into ordered fetch plans (per request / per
//! cross-request batch / per MoE layer for the depth-window warmer,
//! deepest-tier-first so SSD promotions start earliest, each fetch
//! carrying a deadline, a tier-derived lead and a prediction
//! confidence); [`BandwidthWindow`] / [`admit_edf`] schedule those
//! plans earliest-deadline-first into one budgeted, shareable modeled
//! bandwidth window (the cross-layer prefetch scheduler).

pub mod bandwidth;
pub mod cache;
pub mod policy;
pub mod prefetch;
pub mod shared;

pub use bandwidth::{admit_edf, Admission, BandwidthWindow, ScheduledFetch, WindowSnapshot};
pub use cache::{CacheStats, EnsureOutcome, ExpertCache, ResidentExpert, StoreBinding};
pub use prefetch::{
    layer_confidence, plan_prefetch, plan_prefetch_layer, plan_prefetch_union,
    predicted_expert_counts, PlannedFetch,
};
pub use policy::{make_policy, EvictionPolicy};
pub use shared::SharedExpertCache;

/// Identity of one expert: (transformer block index, expert index).
/// The unit of offloading in SiDA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertKey {
    pub block: usize,
    pub expert: usize,
}

impl ExpertKey {
    pub fn new(block: usize, expert: usize) -> Self {
        ExpertKey { block, expert }
    }
}

/// Bind an on-disk [`crate::memory::ExpertStore`] to a model bundle:
/// the [`StoreBinding`] a cache attaches via
/// [`ExpertCache::attach_store`] / [`SharedExpertCache::attach_store`].
/// `spill` serializes the canonical payload from the host
/// [`crate::runtime::WeightStore`] (the authoritative copy), `stage`
/// turns a verified payload back into device buffers — so a warm
/// promotion is bit-identical to a bundle fetch.
pub fn bind_store(
    bundle: &crate::runtime::ModelBundle,
    store: std::sync::Arc<crate::memory::ExpertStore>,
) -> StoreBinding {
    let spill = {
        let weights = bundle.weights.clone();
        move |key: ExpertKey| weights.expert_payload(key.block, key.expert)
    };
    let stage = {
        let engine = bundle.engine.clone();
        let weights = bundle.weights.clone();
        move |key: ExpertKey, payload: &[u8]| {
            crate::runtime::stage_expert_parts_from_payload(
                &engine,
                &weights,
                key.block,
                key.expert,
                payload,
            )
        }
    };
    StoreBinding {
        store,
        spill: std::sync::Arc::new(spill),
        stage: std::sync::Arc::new(stage),
    }
}
