//! Expert residency management: keys, eviction policies, the budgeted
//! device cache, and prefetch planning.
//!
//! The unit of offloading is one expert ([`ExpertKey`]: block × expert
//! index).  [`ExpertCache`] holds the staged weights of resident
//! experts under a simulated byte budget with pluggable eviction
//! ([`make_policy`]: fifo/lru/lfu/clock) and charges modeled H2D
//! transfer cost per fetch; [`plan_prefetch`] /
//! [`plan_prefetch_union`] turn hash-table predictions into ordered
//! fetch plans (per request / per cross-request batch).

pub mod cache;
pub mod policy;
pub mod prefetch;

pub use cache::{CacheStats, ExpertCache, ResidentExpert};
pub use prefetch::{plan_prefetch, plan_prefetch_union, PlannedFetch};
pub use policy::{make_policy, EvictionPolicy};

/// Identity of one expert: (transformer block index, expert index).
/// The unit of offloading in SiDA.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertKey {
    pub block: usize,
    pub expert: usize,
}

impl ExpertKey {
    pub fn new(block: usize, expert: usize) -> Self {
        ExpertKey { block, expert }
    }
}
