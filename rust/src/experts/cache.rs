//! Expert cache: residency of per-expert weights on the simulated GPU
//! tier, with pluggable eviction and transfer-cost accounting.
//!
//! This is the mechanism behind the paper's inference-thread step (2)-c:
//! "load activated experts to GPU and offload inactivated experts to
//! RAM", with "a first-in-first-out scheme applied on experts if no
//! memory budgets remain".  The cache stores the staged PJRT device
//! buffers (4 parts per expert: w1, b1, w2, b2); the host copy always
//! remains in the `WeightStore`, so eviction is free (drop the buffers).
//!
//! Below the device tier the cache drives the §6 GPU -> RAM -> SSD
//! [`ResidencyLedger`]: every eviction demotes its policy-chosen victim
//! into the budgeted RAM window (overflow falls to SSD), and every miss
//! is charged the tier-aware promotion cost of where the expert really
//! sat — the quantity the fig8/fig11 memory arguments and the
//! `fig_hierarchy` bench depend on being exact, not modeled beside the
//! cache.
//!
//! `ExpertCache` itself is the single-owner core (`&mut` mutators, as
//! used by the baselines and unit tests).  The serving hot path shares
//! one cache across the worker pool, the layer-ahead warmer and the
//! hash/prefetch stages through [`super::SharedExpertCache`], which
//! splits read-mostly lookups from mutation — see that module for the
//! lock discipline.  Two pieces of this type are designed for that
//! shared use: pins are **counted** and mutate through `&self` (several
//! pool threads may pin the same expert concurrently), and
//! [`ExpertCache::try_ensure`] reports budget-exhausted-while-pinned as
//! an outcome instead of an error so concurrent callers can wait for an
//! unpin and retry.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::experts::bandwidth::BandwidthWindow;
use crate::experts::policy::EvictionPolicy;
use crate::experts::ExpertKey;
use crate::memory::{
    CostModel, DevicePool, ExpertStore, HierarchyStats, ReadOutcome, ReserveOutcome,
    ResidencyLedger, Tier, DEFAULT_RAM_BUDGET, PAYLOAD_HEADER_BYTES,
};
use crate::obs::trace::{self, ArgValue};
use crate::runtime::DeviceBuffer;

/// The four staged parts of one resident expert (w1, b1, w2, b2) in
/// artifact argument order.
pub struct ResidentExpert {
    pub parts: [DeviceBuffer; 4],
}

#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// simulated bytes moved host->device
    pub transferred_sim_bytes: u64,
    /// modeled seconds spent on transfers (== wall time in real_sleep
    /// mode), across BOTH timelines (critical path + prefetch).  Each
    /// miss is charged the **tier-aware** ladder cost of where the
    /// expert actually sat ([`crate::memory::ResidencyLedger`]): one
    /// PCIe hop for a RAM-resident expert, NVMe + PCIe (~9x) for an
    /// SSD-deep one — and equals the ledger's
    /// [`crate::memory::HierarchyStats::ladder_secs`] attribution
    pub modeled_transfer_secs: f64,
    /// the share of `modeled_transfer_secs` credited as hidden on the
    /// prefetch timeline.  Non-blocking fetches queue on one modeled
    /// link (the [`BandwidthWindow`], shareable across every device
    /// cache of a box): a fetch is credited only for the part of its
    /// modeled time that fits between the link's backlog and the
    /// fetch's deadline, so the credit is bounded by the bandwidth
    /// window that actually existed AND by the compute window before
    /// need-time — a burst of prefetches issued in one instant is not
    /// all "free".  The critical path only pays the difference — see
    /// [`crate::memory::exposed_transfer_secs`]
    pub overlapped_transfer_secs: f64,
    /// transfers that happened on the critical path (inference thread
    /// blocked on them) as opposed to prefetched ahead of time
    pub blocking_misses: u64,
}

impl CacheStats {
    /// Hit fraction over all lookups, or `None` when there was no
    /// traffic — a cache that was never consulted has no hit rate, and
    /// reporting `0.0` would read as "0% hits" in reports.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.misses;
        if total == 0 {
            None
        } else {
            Some(self.hits as f64 / total as f64)
        }
    }

    /// Modeled transfer seconds left exposed on the critical path after
    /// overlap (never negative).
    pub fn exposed_transfer_secs(&self) -> f64 {
        crate::memory::exposed_transfer_secs(
            self.modeled_transfer_secs,
            self.overlapped_transfer_secs,
        )
    }
}

impl fmt::Display for CacheStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "hits={} misses={} (blocking {}) hit_rate={} evictions={} transfer={:.1}MB \
             modeled={:.3}s (overlapped {:.3}s)",
            self.hits,
            self.misses,
            self.blocking_misses,
            crate::metrics::report::fmt_rate(self.hit_rate()),
            self.evictions,
            self.transferred_sim_bytes as f64 / 1e6,
            self.modeled_transfer_secs,
            self.overlapped_transfer_secs
        )
    }
}

/// The cache's handle on the on-disk SSD tier: the store itself plus
/// the two bundle-capturing closures that cross the experts/runtime
/// boundary — `spill` serializes an expert's canonical payload from the
/// host weights (demotion writes, fabrication write-through) and
/// `stage` turns a *verified* payload back into device buffers
/// (promotion reads).  Build one with [`super::bind_store`]; `Clone` is
/// cheap (three `Arc`s).
#[derive(Clone)]
pub struct StoreBinding {
    pub store: Arc<ExpertStore>,
    pub spill: Arc<dyn Fn(ExpertKey) -> Result<Vec<u8>> + Send + Sync>,
    pub stage: Arc<dyn Fn(ExpertKey, &[u8]) -> Result<[DeviceBuffer; 4]> + Send + Sync>,
}

/// Outcome of [`ExpertCache::try_ensure`].
pub enum EnsureOutcome {
    Resident {
        expert: Arc<ResidentExpert>,
        hit: bool,
        /// modeled transfer seconds charged for this call (0.0 on hits)
        transfer_secs: f64,
    },
    /// The expert would not fit and every resident expert is pinned by
    /// an in-flight invocation.  Concurrent callers wait for an unpin
    /// and retry; single-owner callers treat this as an error.
    AllPinned,
}

pub struct ExpertCache {
    pool: DevicePool<ExpertKey>,
    cost: CostModel,
    policy: Box<dyn EvictionPolicy>,
    resident: HashMap<ExpertKey, Arc<ResidentExpert>>,
    /// the §6 GPU -> RAM -> SSD residency ledger this cache DRIVES:
    /// every policy-chosen eviction demotes its actual victim, every
    /// miss promotes from (and is charged for) the tier the expert
    /// really sat in.  The ledger's Device tier mirrors `resident`
    /// exactly — `check_invariants` proves it
    ledger: ResidencyLedger,
    /// the modeled prefetch link (a backlog queue in modeled seconds).
    /// Non-blocking fetches queue behind each other on it; only the
    /// part of a transfer that fits between the backlog and the fetch's
    /// deadline is credited as overlapped, so hidden-transfer credit
    /// can never exceed the modeled bandwidth window (a burst of
    /// prefetches issued in one instant is not "free" — see
    /// `CacheStats::overlapped_transfer_secs`).  Per-cache by default;
    /// [`ExpertCache::share_window`] points every device cache of a box
    /// at ONE window, making host-RAM bandwidth a shared resource
    /// (`--host-bw`).
    window: Arc<BandwidthWindow>,
    /// pin **counts** per expert: under the worker pool several
    /// invocations can pin the same expert concurrently, and the first
    /// unpin must not strip protection from the rest.  Interior
    /// mutability so pins work through `&self` (the shared cache pins
    /// under a read lock, concurrent with other readers).
    pinned: Mutex<HashMap<ExpertKey, u32>>,
    /// the on-disk SSD tier, when attached (`--store-dir`): SSD
    /// promotions read (and verify) real blobs, demote spills and
    /// fabrications write them — all on a measured timeline beside the
    /// ledger's modeled one
    store: Option<StoreBinding>,
    /// Chrome trace pid this cache's ladder events are emitted under
    /// (device 0 by default; cluster device caches override — see
    /// [`crate::obs::trace::device_pid`])
    trace_pid: u32,
    stats: CacheStats,
}

impl ExpertCache {
    /// `budget_sim_bytes` is the simulated device budget (paper scale).
    /// The tier ladder below the device gets the default RAM window
    /// ([`DEFAULT_RAM_BUDGET`], FIFO) — see
    /// [`ExpertCache::with_hierarchy`] for explicit control.
    pub fn new(budget_sim_bytes: usize, cost: CostModel, policy: Box<dyn EvictionPolicy>) -> Self {
        let ram_policy = crate::experts::make_policy("fifo").expect("fifo policy always exists");
        Self::with_hierarchy(budget_sim_bytes, cost, policy, DEFAULT_RAM_BUDGET, ram_policy)
    }

    /// Build a cache with an explicit §6 ladder below the device tier:
    /// `ram_budget_sim_bytes` bounds the modeled host-RAM window device
    /// evictions demote into (overflow falls to unbounded SSD), and
    /// `ram_policy` is that window's own eviction policy
    /// (`--ram-budget` / `--ram-policy`).
    pub fn with_hierarchy(
        budget_sim_bytes: usize,
        cost: CostModel,
        policy: Box<dyn EvictionPolicy>,
        ram_budget_sim_bytes: usize,
        ram_policy: Box<dyn EvictionPolicy>,
    ) -> Self {
        let ledger = ResidencyLedger::new(ram_budget_sim_bytes, ram_policy, cost.tier_costs());
        ExpertCache {
            pool: DevicePool::new(budget_sim_bytes),
            cost,
            policy,
            resident: HashMap::new(),
            ledger,
            window: Arc::new(BandwidthWindow::new()),
            pinned: Mutex::new(HashMap::new()),
            store: None,
            trace_pid: trace::device_pid(0),
            stats: CacheStats::default(),
        }
    }

    /// Set the Chrome trace pid for this cache's ladder events (cluster
    /// device caches report under their own device timeline).
    pub fn set_trace_pid(&mut self, pid: u32) {
        self.trace_pid = pid;
    }

    /// Attach the on-disk SSD tier.  Every key already in the store
    /// (a reopened `--store-dir`) pre-seeds the ledger's SSD tier, so a
    /// restarted process promotes warm from disk instead of
    /// re-fabricating — blob payload bytes convert to simulated scale
    /// minus the fixed header, matching what a live demotion records.
    pub fn attach_store(&mut self, binding: StoreBinding) {
        for (key, payload_bytes) in binding.store.keys_with_bytes() {
            let real = (payload_bytes as usize).saturating_sub(PAYLOAD_HEADER_BYTES);
            self.ledger.seed_ssd(key, self.cost.sim_bytes(real));
        }
        self.store = Some(binding);
    }

    /// The attached on-disk store, if any (diagnostics/tests).
    pub fn store(&self) -> Option<&StoreBinding> {
        self.store.as_ref()
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Which tier of the §6 ladder `key` currently sits in (Device for
    /// resident experts; the ledger answers for RAM/SSD).  Drives the
    /// tier-aware prefetch ordering: SSD-deep predicted experts are
    /// promoted earliest because their misses would cost ~9x.
    pub fn tier_of(&self, key: &ExpertKey) -> Tier {
        self.ledger.tier_of(key)
    }

    /// Snapshot of the tier ladder: per-tier occupancy, promotions per
    /// hop, and the ladder seconds attribution of
    /// [`CacheStats::modeled_transfer_secs`] — with the on-disk store's
    /// measured timeline (real read/write seconds, bytes on disk,
    /// integrity counters) folded in when a store is attached.
    pub fn hierarchy_stats(&self) -> HierarchyStats {
        let mut h = self.ledger.stats();
        if let Some(binding) = &self.store {
            let s = binding.store.stats();
            h.measured_ssd_read_secs = s.read_secs;
            h.measured_ssd_write_secs = s.write_secs;
            h.store_bytes_on_disk = s.bytes_on_disk as usize;
            h.integrity_failures = s.integrity_failures;
            h.store_hits = s.reads;
            h.store_misses = s.misses;
            h.refabrications = s.refabrications;
            h.store_writes = s.writes;
            h.store_reclaimed = s.reclaimed;
        }
        h
    }

    /// The modeled host-RAM window below this cache's device tier.
    pub fn ram_budget(&self) -> usize {
        self.ledger.ram_budget()
    }

    /// See [`EvictionPolicy::uses_access`].
    pub fn policy_uses_access(&self) -> bool {
        self.policy.uses_access()
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
        self.ledger.reset_stats();
        if let Some(binding) = &self.store {
            binding.store.reset_stats();
        }
        self.pool.reset_peak();
        self.reset_transfer_clock();
    }

    /// Start a new epoch on the modeled prefetch link, **carrying** any
    /// scheduled backlog forward explicitly (it stays queued and is
    /// recorded as carried — [`BandwidthWindow::carry_epoch`]) instead
    /// of silently discarding it: work the warmup epoch scheduled but
    /// the link had not absorbed is still in flight when the measured
    /// epoch opens, and dropping it would both flatter the measured
    /// run's credit and violate conservation of scheduled seconds.
    /// Returns the carried backlog.
    pub fn reset_transfer_clock(&mut self) -> f64 {
        self.window.carry_epoch()
    }

    /// The modeled prefetch link this cache charges non-blocking
    /// staging into.
    pub fn bandwidth_window(&self) -> Arc<BandwidthWindow> {
        self.window.clone()
    }

    /// Point this cache at a shared [`BandwidthWindow`] (all devices of
    /// one box draw host-RAM bandwidth from one window).  Call before
    /// traffic: backlog already queued on the old window stays there.
    pub fn share_window(&mut self, window: Arc<BandwidthWindow>) {
        self.window = window;
    }

    /// Modeled transfer seconds currently queued on the prefetch link.
    pub fn prefetch_backlog_secs(&self) -> f64 {
        self.window.backlog_secs()
    }

    pub fn budget(&self) -> usize {
        self.pool.budget()
    }

    pub fn used(&self) -> usize {
        self.pool.used()
    }

    pub fn peak(&self) -> usize {
        self.pool.peak()
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn contains(&self, key: &ExpertKey) -> bool {
        self.resident.contains_key(key)
    }

    pub fn get(&self, key: &ExpertKey) -> Option<Arc<ResidentExpert>> {
        self.resident.get(key).cloned()
    }

    /// Pin an expert against eviction (it is about to be used by an
    /// in-flight invocation).  Pins nest: each `pin` needs one `unpin`.
    pub fn pin(&self, key: ExpertKey) {
        *self.pinned.lock().unwrap().entry(key).or_insert(0) += 1;
    }

    pub fn unpin(&self, key: &ExpertKey) {
        let mut pins = self.pinned.lock().unwrap();
        if let Some(count) = pins.get_mut(key) {
            *count -= 1;
            if *count == 0 {
                pins.remove(key);
            }
        }
    }

    pub fn unpin_all(&self) {
        self.pinned.lock().unwrap().clear();
    }

    fn pinned_set(&self) -> HashSet<ExpertKey> {
        self.pinned.lock().unwrap().keys().copied().collect()
    }

    /// Replay deferred read-path accesses into the eviction policy (the
    /// shared cache batches policy touches for lock-free-path hits).
    pub fn note_accesses(&mut self, keys: &[ExpertKey]) {
        for key in keys {
            if self.resident.contains_key(key) {
                self.policy.on_access(*key);
            }
        }
    }

    /// Ensure `key` is resident; on a miss, evict per policy until the
    /// expert fits, call `fetch` to stage the buffers, and charge the
    /// modeled transfer cost.  `blocking` marks misses that stall the
    /// inference thread (vs the prefetch timeline — the cost is charged
    /// either way, but non-blocking transfers are accounted as
    /// overlapped).
    ///
    /// This method only *accounts* the modeled seconds — it never
    /// sleeps, even in `real_sleep` mode, so a shared-cache caller can
    /// hold its write lock across it without serializing concurrent
    /// hits for the transfer duration.  The caller is responsible for
    /// sleeping the returned `transfer_secs` on its own timeline when
    /// `cost_model().real_sleep` is set ([`ExpertCache::ensure`] and
    /// [`super::SharedExpertCache`] both do).
    ///
    /// Returns [`EnsureOutcome::AllPinned`] (without consuming budget or
    /// fetching) when the expert cannot fit because every resident
    /// expert is pinned.
    pub fn try_ensure<F>(
        &mut self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        fetch: F,
    ) -> Result<EnsureOutcome>
    where
        F: FnOnce() -> Result<[DeviceBuffer; 4]>,
    {
        self.try_ensure_by(key, real_bytes, blocking, None, fetch)
    }

    /// [`ExpertCache::try_ensure`] with an explicit staging deadline for
    /// non-blocking fetches: the modeled seconds until this expert's
    /// layer computes ([`crate::memory::fetch_deadline_secs`]).  The
    /// overlap credit is bounded by that deadline — a deep promotion
    /// staged with more lead earns more hideable window.  `None` (and
    /// every `blocking` call) falls back to the transfer's own length,
    /// the one-layer-ahead model's implicit assumption.
    pub fn try_ensure_by<F>(
        &mut self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        deadline_secs: Option<f64>,
        fetch: F,
    ) -> Result<EnsureOutcome>
    where
        F: FnOnce() -> Result<[DeviceBuffer; 4]>,
    {
        if let Some(r) = self.resident.get(&key) {
            self.stats.hits += 1;
            self.policy.on_access(key);
            return Ok(EnsureOutcome::Resident {
                expert: r.clone(),
                hit: true,
                transfer_secs: 0.0,
            });
        }
        let sim_bytes = self.cost.sim_bytes(real_bytes);
        if sim_bytes > self.pool.budget() {
            bail!(
                "expert {key:?} ({sim_bytes} sim bytes) larger than device budget {}",
                self.pool.budget()
            );
        }
        let pinned = self.pinned_set();
        // feasibility first: if the expert cannot fit even after
        // evicting every unpinned resident, report AllPinned WITHOUT
        // evicting — otherwise a doomed attempt would flush warm
        // experts that must then be re-fetched (extra misses and
        // modeled transfers under exactly the contention the shared
        // cache's wait-and-retry path is built for)
        let pinned_bytes: usize =
            pinned.iter().filter_map(|k| self.pool.bytes_of(k)).sum();
        if sim_bytes > self.pool.budget().saturating_sub(pinned_bytes) {
            return Ok(EnsureOutcome::AllPinned);
        }
        // where the expert sits BEFORE this promotion churns the tiers:
        // an SSD-deep key with a store attached is served by a real,
        // verified blob read below
        let from_tier = self.ledger.tier_of(&key);
        while !self.pool.fits(sim_bytes) {
            match self.policy.victim(&pinned) {
                Some(victim) => {
                    self.pool.release(&victim);
                    self.resident.remove(&victim);
                    // the eviction hook: the *actual* policy-chosen
                    // victim demotes down the §6 ladder, so the ledger
                    // can never drift from the cache's eviction order —
                    // and every key that lands on SSD spills its blob
                    // to the on-disk store
                    let spilled = self.ledger.demote(victim);
                    if trace::enabled() {
                        trace::instant(
                            "demote",
                            "ladder",
                            self.trace_pid,
                            vec![
                                ("block", ArgValue::U(victim.block as u64)),
                                ("expert", ArgValue::U(victim.expert as u64)),
                                ("to", ArgValue::S(format!("{:?}", self.ledger.tier_of(&victim)))),
                            ],
                        );
                    }
                    self.spill_to_store(&spilled);
                    self.stats.evictions += 1;
                }
                None => return Ok(EnsureOutcome::AllPinned),
            }
        }
        // measured fetch wall for the promotion event only — the clock
        // is read solely with tracing on, so the traced-off hot path is
        // untouched
        let t_fetch = trace::enabled().then(std::time::Instant::now);
        let parts = self.fetch_parts(key, from_tier, fetch)?;
        match self.pool.reserve(key, sim_bytes) {
            ReserveOutcome::Ok => {}
            other => bail!("pool reserve failed unexpectedly: {other:?}"),
        }
        self.policy.on_insert(key);
        let arc = Arc::new(ResidentExpert { parts });
        self.resident.insert(key, arc.clone());
        self.stats.misses += 1;
        if blocking {
            self.stats.blocking_misses += 1;
        }
        self.stats.transferred_sim_bytes += sim_bytes as u64;
        // accounting only — the caller sleeps (see method docs).  The
        // charge is tier-aware: the ledger knows whether this expert was
        // one PCIe hop away (RAM) or SSD-deep (NVMe + PCIe, ~9x), and
        // those ladder seconds land on the SAME modeled timeline the
        // shared bandwidth window absorbs below — one timeline, no
        // parallel promote accounting
        let secs = self.ledger.promote(key, sim_bytes);
        self.stats.modeled_transfer_secs += secs;
        if let Some(t0) = t_fetch {
            // the ladder promotion event: which tier the expert came
            // from, the modeled ladder seconds charged, and the
            // measured staging wall beside it
            trace::instant(
                "promote",
                "ladder",
                self.trace_pid,
                vec![
                    ("block", ArgValue::U(key.block as u64)),
                    ("expert", ArgValue::U(key.expert as u64)),
                    ("from", ArgValue::S(format!("{from_tier:?}"))),
                    ("modeled_secs", ArgValue::F(secs)),
                    ("measured_secs", ArgValue::F(t0.elapsed().as_secs_f64())),
                    ("blocking", ArgValue::U(blocking as u64)),
                ],
            );
        }
        if !blocking {
            // virtual prefetch timeline: the transfer queues on the
            // (possibly shared) modeled link, and only the share that
            // fits between the link's backlog and the fetch's deadline
            // is hideable.  A burst of prefetches issued in one instant
            // gets the first transfer fully credited and each successor
            // credited less by the queueing delay in front of it — and
            // a deep promotion staged one layer ahead cannot claim more
            // hiding than one layer's window offers.  The credit is
            // bounded by the modeled bandwidth window, not by optimism.
            let deadline = deadline_secs.unwrap_or(secs);
            let credit = self.window.charge(secs, deadline);
            self.stats.overlapped_transfer_secs += credit;
        }
        Ok(EnsureOutcome::Resident { expert: arc, hit: false, transfer_secs: secs })
    }

    /// Produce the staged parts for a miss.  Without a store this is the
    /// caller's `fetch` (host bundle staging).  With a store attached,
    /// an SSD-tier promotion first tries a real on-disk read: a blob
    /// that verifies (length + content hash) stages straight from its
    /// payload; `Corrupt`/`Miss`/an unstageable payload fall back to
    /// bundle re-fabrication (counted).  Every fabrication writes its
    /// blob through to the store so a restarted process — and end-of-run
    /// residents that never demoted to SSD — can promote warm.
    fn fetch_parts<F>(&self, key: ExpertKey, from_tier: Tier, fetch: F) -> Result<[DeviceBuffer; 4]>
    where
        F: FnOnce() -> Result<[DeviceBuffer; 4]>,
    {
        let Some(binding) = self.store.clone() else {
            return fetch();
        };
        if from_tier == Tier::Ssd {
            match binding.store.get(&key) {
                ReadOutcome::Hit(payload) => match (binding.stage)(key, &payload) {
                    Ok(parts) => return Ok(parts),
                    Err(err) => {
                        log::warn!(
                            "expert store: staging verified blob for {key:?} failed \
                             ({err:#}); re-fabricating from the bundle"
                        );
                        binding.store.reject(&key);
                    }
                },
                ReadOutcome::Corrupt | ReadOutcome::Miss => {}
            }
            binding.store.note_refabrication();
        }
        let parts = fetch()?;
        // write-through: content addressing makes re-puts of unchanged
        // experts no-ops, and a failed write degrades the store (a
        // future cold miss), never the answer
        match (binding.spill)(key) {
            Ok(payload) => {
                if let Err(err) = binding.store.put(key, &payload) {
                    log::warn!("expert store: write-through for {key:?} failed: {err:#}");
                }
            }
            Err(err) => log::warn!("expert store: serializing {key:?} failed: {err:#}"),
        }
        Ok(parts)
    }

    /// Write the blobs of keys that just landed on the ledger's SSD tier
    /// (the spill hook of [`crate::memory::ResidencyLedger::demote`]).
    fn spill_to_store(&self, keys: &[ExpertKey]) {
        let Some(binding) = &self.store else { return };
        for key in keys {
            match (binding.spill)(*key) {
                Ok(payload) => {
                    if let Err(err) = binding.store.put(*key, &payload) {
                        log::warn!("expert store: spill of {key:?} failed: {err:#}");
                    }
                }
                Err(err) => log::warn!("expert store: serializing {key:?} failed: {err:#}"),
            }
        }
    }

    /// [`ExpertCache::try_ensure`] for single-owner callers: a fully
    /// pinned budget is an error (nothing can ever unpin concurrently).
    ///
    /// Returns (resident expert, hit?, modeled transfer seconds).
    pub fn ensure<F>(
        &mut self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        fetch: F,
    ) -> Result<(Arc<ResidentExpert>, bool, f64)>
    where
        F: FnOnce() -> Result<[DeviceBuffer; 4]>,
    {
        match self.try_ensure(key, real_bytes, blocking, fetch)? {
            EnsureOutcome::Resident { expert, hit, transfer_secs } => {
                if !hit && self.cost.real_sleep && transfer_secs > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(transfer_secs));
                }
                Ok((expert, hit, transfer_secs))
            }
            EnsureOutcome::AllPinned => bail!(
                "device budget exhausted and every resident expert is pinned \
                 (budget {} used {})",
                self.pool.budget(),
                self.pool.used()
            ),
        }
    }

    /// Drop an expert from the device tier explicitly (it demotes down
    /// the ladder like any eviction — offload, not deletion).
    pub fn invalidate(&mut self, key: &ExpertKey) {
        if self.resident.remove(key).is_some() {
            self.pool.release(key);
            self.policy.on_evict(*key);
            let spilled = self.ledger.demote(*key);
            self.spill_to_store(&spilled);
        }
    }

    /// Drop everything (model switch / reset between bench phases).
    pub fn clear(&mut self) {
        let keys: Vec<ExpertKey> = self.resident.keys().copied().collect();
        for k in keys {
            self.invalidate(&k);
        }
        self.unpin_all();
    }

    /// Keys currently resident (test/diagnostic use).
    pub fn resident_keys(&self) -> Vec<ExpertKey> {
        self.resident.keys().copied().collect()
    }

    /// Internal-consistency check used by the property tests: pool and
    /// resident map must agree exactly, usage must be within budget, and
    /// — the drift-kill invariant — the residency ledger's Device tier
    /// must be *exactly* this cache's resident set (the guarantee the
    /// eviction hook exists for; a modeled side-car ledger could not
    /// hold it).
    pub fn check_invariants(&self) -> Result<()> {
        if self.pool.used() > self.pool.budget() {
            bail!("used {} exceeds budget {}", self.pool.used(), self.pool.budget());
        }
        if self.pool.len() != self.resident.len() {
            bail!(
                "pool regions {} != resident entries {}",
                self.pool.len(),
                self.resident.len()
            );
        }
        for key in self.resident.keys() {
            if self.pool.bytes_of(key).is_none() {
                bail!("resident {key:?} missing from pool");
            }
        }
        self.ledger.check_invariants().map_err(anyhow::Error::msg)?;
        let mut resident: Vec<ExpertKey> = self.resident.keys().copied().collect();
        resident.sort_unstable();
        let ledger_device = self.ledger.device_keys();
        if resident != ledger_device {
            bail!(
                "cache/ledger drift: resident {resident:?} != ledger Device tier \
                 {ledger_device:?}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experts::make_policy;

    #[test]
    fn hit_rate_none_without_traffic() {
        let s = CacheStats::default();
        assert_eq!(s.hit_rate(), None);
        assert!(s.to_string().contains("hit_rate=n/a"));
    }

    #[test]
    fn hit_rate_some_with_traffic() {
        let s = CacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate().unwrap() - 0.75).abs() < 1e-12);
        assert!(s.to_string().contains("hit_rate=75.0%"));
        let all_miss = CacheStats { hits: 0, misses: 5, ..Default::default() };
        assert_eq!(all_miss.hit_rate(), Some(0.0));
    }

    #[test]
    fn exposed_transfer_never_negative() {
        let s = CacheStats {
            modeled_transfer_secs: 1.0,
            overlapped_transfer_secs: 1.5,
            ..Default::default()
        };
        assert_eq!(s.exposed_transfer_secs(), 0.0);
        let s = CacheStats {
            modeled_transfer_secs: 1.0,
            overlapped_transfer_secs: 0.25,
            ..Default::default()
        };
        assert!((s.exposed_transfer_secs() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn overlap_credit_bounded_by_virtual_prefetch_timeline() {
        // Two back-to-back non-blocking fetches with no drain between
        // them: the first transfer is fully credited, the second queues
        // behind it on the modeled link and earns no credit — so total
        // overlapped credit stays at ONE transfer, not two.
        let real = 66_048usize;
        let mut cache = ExpertCache::new(
            1 << 40,
            CostModel::paper_scale(real),
            make_policy("fifo").unwrap(),
        );
        // cold experts are SSD-deep: the miss charge is the full ladder
        let sim = cache.cost_model().sim_bytes(real);
        let secs_one = cache.cost_model().tier_costs().promote_secs(Tier::Ssd, sim);
        assert!(secs_one > 1e-4, "paper-scale transfer must be ms-class");
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        let fetch = || Ok([buf(), buf(), buf(), buf()]);
        cache.ensure(ExpertKey::new(0, 0), real, false, fetch).unwrap();
        cache.ensure(ExpertKey::new(0, 1), real, false, fetch).unwrap();
        let stats = cache.stats();
        assert!((stats.modeled_transfer_secs - 2.0 * secs_one).abs() < 1e-9);
        // deterministic on the modeled link: exactly one transfer of
        // credit (first full, second fully queued)
        assert!(
            (stats.overlapped_transfer_secs - secs_one).abs() < 1e-12,
            "burst credit {} must be exactly one transfer ({secs_one})",
            stats.overlapped_transfer_secs
        );
        assert!(
            stats.exposed_transfer_secs() > 0.4 * secs_one,
            "the queued share must surface as exposed transfer"
        );
        assert!(
            (cache.prefetch_backlog_secs() - 2.0 * secs_one).abs() < 1e-9,
            "both transfers are queued on the link"
        );
    }

    #[test]
    fn reset_transfer_clock_conserves_scheduled_backlog() {
        // the drain-or-carry fix: a stats reset between trace epochs
        // must not silently discard backlog the warmup epoch scheduled
        // — the queued seconds carry into the new epoch and are
        // recorded as carried (conservation: backlog_before == carried
        // + drained, drained == 0 across a reset).
        let real = 66_048usize;
        let mut cache = ExpertCache::new(
            1 << 40,
            CostModel::paper_scale(real),
            make_policy("fifo").unwrap(),
        );
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        let fetch = || Ok([buf(), buf(), buf(), buf()]);
        cache.ensure(ExpertKey::new(0, 0), real, false, fetch).unwrap();
        cache.ensure(ExpertKey::new(0, 1), real, false, fetch).unwrap();
        let backlog_before = cache.prefetch_backlog_secs();
        assert!(backlog_before > 1e-4, "warmup must have scheduled backlog");
        cache.reset_stats();
        let snap = cache.bandwidth_window().snapshot();
        assert!(
            (snap.backlog_secs - backlog_before).abs() < 1e-12,
            "backlog must survive the epoch reset (was {backlog_before}, now {})",
            snap.backlog_secs
        );
        assert!(
            (snap.carried_backlog_secs - backlog_before).abs() < 1e-12,
            "the carried amount must be recorded explicitly"
        );
        assert_eq!(snap.admitted, 0, "per-epoch counters restart");
        // the carried backlog still queues ahead of new-epoch fetches:
        // a fetch whose deadline is below the carried backlog earns no
        // credit in the fresh epoch
        cache.ensure(ExpertKey::new(0, 2), real, false, fetch).unwrap();
        assert_eq!(
            cache.stats().overlapped_transfer_secs,
            0.0,
            "carried backlog must still bound new-epoch credit"
        );
    }

    #[test]
    fn miss_cost_is_tier_aware_and_evictions_demote_the_real_victim() {
        // LRU cache, room for two experts.  The policy's victim (not a
        // FIFO guess) must be the expert that lands in the ledger's RAM
        // tier, and re-fetching it must be charged the cheap RAM hop
        // while cold fetches pay the SSD ladder.
        let real = 1000usize;
        let mut cache = ExpertCache::new(
            2 * real + 8,
            CostModel::physical(real),
            make_policy("lru").unwrap(),
        );
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        let fetch = || Ok([buf(), buf(), buf(), buf()]);
        let k0 = ExpertKey::new(0, 0);
        let k1 = ExpertKey::new(0, 1);
        let k2 = ExpertKey::new(0, 2);
        let costs = cache.cost_model().tier_costs();
        let (_, _, cold) = cache.ensure(k0, real, true, fetch).unwrap();
        assert!((cold - costs.promote_secs(Tier::Ssd, real)).abs() < 1e-15);
        cache.ensure(k1, real, true, fetch).unwrap();
        cache.ensure(k0, real, true, fetch).unwrap(); // hit: k1 is now LRU
        cache.ensure(k2, real, true, fetch).unwrap(); // evicts k1 (NOT k0)
        assert_eq!(cache.tier_of(&k1), Tier::Ram, "policy victim must demote");
        assert_eq!(cache.tier_of(&k0), Tier::Device);
        let (_, hit, from_ram) = cache.ensure(k1, real, true, fetch).unwrap();
        assert!(!hit);
        assert!((from_ram - costs.promote_secs(Tier::Ram, real)).abs() < 1e-15);
        assert!(from_ram < cold, "RAM-resident miss must undercut the SSD ladder");
        // the ladder attribution IS the cache's modeled transfer total
        let h = cache.hierarchy_stats();
        let modeled = cache.stats().modeled_transfer_secs;
        assert!((h.ladder_secs() - modeled).abs() < 1e-12 * modeled.max(1.0));
        assert_eq!(h.promotions_from_ram, 1);
        assert_eq!(h.promotions_from_ssd, 3);
        cache.check_invariants().unwrap();
    }

    #[test]
    fn pins_are_counted_not_boolean() {
        let cache = ExpertCache::new(
            1 << 20,
            CostModel::physical(1000),
            make_policy("fifo").unwrap(),
        );
        let key = ExpertKey::new(0, 0);
        cache.pin(key);
        cache.pin(key);
        cache.unpin(&key);
        // one pin remains: the key must still be in the pinned set
        assert!(cache.pinned_set().contains(&key));
        cache.unpin(&key);
        assert!(!cache.pinned_set().contains(&key));
        // unpinning beyond zero is a no-op, not a panic
        cache.unpin(&key);
    }
}
