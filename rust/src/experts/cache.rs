//! Expert cache: residency of per-expert weights on the simulated GPU
//! tier, with pluggable eviction and transfer-cost accounting.
//!
//! This is the mechanism behind the paper's inference-thread step (2)-c:
//! "load activated experts to GPU and offload inactivated experts to
//! RAM", with "a first-in-first-out scheme applied on experts if no
//! memory budgets remain".  The cache stores the staged PJRT device
//! buffers (4 parts per expert: w1, b1, w2, b2); the host copy always
//! remains in the `WeightStore`, so eviction is free (drop the buffers).

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::experts::policy::EvictionPolicy;
use crate::experts::ExpertKey;
use crate::memory::{CostModel, DevicePool, ReserveOutcome};
use crate::runtime::DeviceBuffer;

/// The four staged parts of one resident expert (w1, b1, w2, b2) in
/// artifact argument order.
pub struct ResidentExpert {
    pub parts: [DeviceBuffer; 4],
}

#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// simulated bytes moved host->device
    pub transferred_sim_bytes: u64,
    /// modeled seconds spent on transfers (== wall time in real_sleep mode)
    pub modeled_transfer_secs: f64,
    /// transfers that happened on the critical path (inference thread
    /// blocked on them) as opposed to prefetched ahead of time
    pub blocking_misses: u64,
}

impl CacheStats {
    /// Hit fraction over all lookups, or `None` when there was no
    /// traffic — a cache that was never consulted has no hit rate, and
    /// reporting `0.0` would read as "0% hits" in reports.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.hits + self.misses;
        if total == 0 {
            None
        } else {
            Some(self.hits as f64 / total as f64)
        }
    }
}

impl fmt::Display for CacheStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "hits={} misses={} (blocking {}) hit_rate={} evictions={} transfer={:.1}MB modeled={:.3}s",
            self.hits,
            self.misses,
            self.blocking_misses,
            crate::metrics::report::fmt_rate(self.hit_rate()),
            self.evictions,
            self.transferred_sim_bytes as f64 / 1e6,
            self.modeled_transfer_secs
        )
    }
}

pub struct ExpertCache {
    pool: DevicePool<ExpertKey>,
    cost: CostModel,
    policy: Box<dyn EvictionPolicy>,
    resident: HashMap<ExpertKey, Arc<ResidentExpert>>,
    pinned: HashSet<ExpertKey>,
    stats: CacheStats,
}

impl ExpertCache {
    /// `budget_sim_bytes` is the simulated device budget (paper scale).
    pub fn new(budget_sim_bytes: usize, cost: CostModel, policy: Box<dyn EvictionPolicy>) -> Self {
        ExpertCache {
            pool: DevicePool::new(budget_sim_bytes),
            cost,
            policy,
            resident: HashMap::new(),
            pinned: HashSet::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
        self.pool.reset_peak();
    }

    pub fn budget(&self) -> usize {
        self.pool.budget()
    }

    pub fn used(&self) -> usize {
        self.pool.used()
    }

    pub fn peak(&self) -> usize {
        self.pool.peak()
    }

    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    pub fn contains(&self, key: &ExpertKey) -> bool {
        self.resident.contains_key(key)
    }

    pub fn get(&self, key: &ExpertKey) -> Option<Arc<ResidentExpert>> {
        self.resident.get(key).cloned()
    }

    /// Pin an expert against eviction (it is about to be used by the
    /// current layer's compute).
    pub fn pin(&mut self, key: ExpertKey) {
        self.pinned.insert(key);
    }

    pub fn unpin(&mut self, key: &ExpertKey) {
        self.pinned.remove(key);
    }

    pub fn unpin_all(&mut self) {
        self.pinned.clear();
    }

    /// Ensure `key` is resident; on a miss, evict per policy until the
    /// expert fits, call `fetch` to stage the buffers, and charge the
    /// modeled transfer cost.  `blocking` marks misses that stall the
    /// inference thread (vs prefetch from the hash-building side).
    ///
    /// Returns (resident expert, hit?, modeled transfer seconds).
    pub fn ensure<F>(
        &mut self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        fetch: F,
    ) -> Result<(Arc<ResidentExpert>, bool, f64)>
    where
        F: FnOnce() -> Result<[DeviceBuffer; 4]>,
    {
        if let Some(r) = self.resident.get(&key) {
            self.stats.hits += 1;
            self.policy.on_access(key);
            return Ok((r.clone(), true, 0.0));
        }
        let sim_bytes = self.cost.sim_bytes(real_bytes);
        if sim_bytes > self.pool.budget() {
            bail!(
                "expert {key:?} ({sim_bytes} sim bytes) larger than device budget {}",
                self.pool.budget()
            );
        }
        while !self.pool.fits(sim_bytes) {
            match self.policy.victim(&self.pinned) {
                Some(victim) => {
                    self.pool.release(&victim);
                    self.resident.remove(&victim);
                    self.stats.evictions += 1;
                }
                None => bail!(
                    "device budget exhausted and every resident expert is pinned \
                     (budget {} used {} need {})",
                    self.pool.budget(),
                    self.pool.used(),
                    sim_bytes
                ),
            }
        }
        let parts = fetch()?;
        match self.pool.reserve(key, sim_bytes) {
            ReserveOutcome::Ok => {}
            other => bail!("pool reserve failed unexpectedly: {other:?}"),
        }
        self.policy.on_insert(key);
        let arc = Arc::new(ResidentExpert { parts });
        self.resident.insert(key, arc.clone());
        self.stats.misses += 1;
        if blocking {
            self.stats.blocking_misses += 1;
        }
        self.stats.transferred_sim_bytes += sim_bytes as u64;
        let secs = self.cost.charge_transfer(sim_bytes);
        self.stats.modeled_transfer_secs += secs;
        Ok((arc, false, secs))
    }

    /// Drop an expert from the device tier explicitly.
    pub fn invalidate(&mut self, key: &ExpertKey) {
        if self.resident.remove(key).is_some() {
            self.pool.release(key);
            self.policy.on_evict(*key);
        }
    }

    /// Drop everything (model switch / reset between bench phases).
    pub fn clear(&mut self) {
        let keys: Vec<ExpertKey> = self.resident.keys().copied().collect();
        for k in keys {
            self.invalidate(&k);
        }
        self.pinned.clear();
    }

    /// Keys currently resident (test/diagnostic use).
    pub fn resident_keys(&self) -> Vec<ExpertKey> {
        self.resident.keys().copied().collect()
    }

    /// Internal-consistency check used by the property tests: pool and
    /// resident map must agree exactly, and usage must be within budget.
    pub fn check_invariants(&self) -> Result<()> {
        if self.pool.used() > self.pool.budget() {
            bail!("used {} exceeds budget {}", self.pool.used(), self.pool.budget());
        }
        if self.pool.len() != self.resident.len() {
            bail!(
                "pool regions {} != resident entries {}",
                self.pool.len(),
                self.resident.len()
            );
        }
        for key in self.resident.keys() {
            if self.pool.bytes_of(key).is_none() {
                bail!("resident {key:?} missing from pool");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_none_without_traffic() {
        let s = CacheStats::default();
        assert_eq!(s.hit_rate(), None);
        assert!(s.to_string().contains("hit_rate=n/a"));
    }

    #[test]
    fn hit_rate_some_with_traffic() {
        let s = CacheStats { hits: 3, misses: 1, ..Default::default() };
        assert!((s.hit_rate().unwrap() - 0.75).abs() < 1e-12);
        assert!(s.to_string().contains("hit_rate=75.0%"));
        let all_miss = CacheStats { hits: 0, misses: 5, ..Default::default() };
        assert_eq!(all_miss.hit_rate(), Some(0.0));
    }
}
