//! The concurrently shared expert cache: read-mostly lookups split from
//! mutation so pool threads don't convoy on one lock.
//!
//! The serving hot path touches the cache from many threads at once —
//! the worker pool's per-expert invocations, the layer-ahead warmer,
//! the request-ahead prefetch stage — and a single coarse
//! `Mutex<ExpertCache>` serialized all of them, hits included.
//! [`SharedExpertCache`] restores concurrency with a small, explicit
//! lock discipline:
//!
//! * **hits** take the `RwLock` **read** lock: any number of threads
//!   resolve warm experts (and pin them) simultaneously;
//! * **misses** (fetch + eviction) take the **write** lock — the only
//!   serialized part, and the part that is genuinely exclusive;
//! * **stats for read-path hits** accumulate in a separate atomic so a
//!   hit never needs `&mut` cache; eviction-policy touches for those
//!   hits are queued in a side buffer and replayed under the next write
//!   lock (FIFO — the paper default — ignores touches entirely; LRU/LFU
//!   see them batched, which can defer a recency update by at most one
//!   miss);
//! * **pins** mutate a dedicated mutex inside [`ExpertCache`] through
//!   `&self`, so pinning a just-resolved expert happens under the same
//!   read lock that resolved it — writers (evictors) are excluded until
//!   the pin is registered.
//!
//! When the budget is completely pinned by in-flight invocations,
//! [`SharedExpertCache::ensure`] waits for an unpin and retries instead
//! of failing — with a worker pool, "every expert pinned" is a
//! transient state that resolves as soon as one invocation completes.
//!
//! **Lock poisoning.** Every lock acquisition here tolerates poisoning
//! (`unwrap_or_else(|e| e.into_inner())`) instead of unwrapping.  A
//! poisoned lock means some thread panicked while holding it; for this
//! cache that is a panicking `fetch` closure, which runs under the
//! write lock in `try_ensure` *before* the ledger is mutated for the
//! new entry — the cache's own transitions are transactional (ledger,
//! policy, and pin state change only after a fetch succeeds), so the
//! data behind a poisoned lock is still structurally sound.  Refusing
//! the guard would turn one failed request into a permanent outage:
//! every later `.unwrap()` on the same lock cascade-panics across the
//! whole worker pool.  `check_invariants` stays available as the cheap
//! recheck, and `poisoned_lock_does_not_cascade` below drives this
//! exact path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

use anyhow::Result;

use crate::experts::cache::{CacheStats, EnsureOutcome, ExpertCache, ResidentExpert};
use crate::experts::ExpertKey;
use crate::runtime::DeviceBuffer;

/// Bound on the deferred-touch queue: on an all-hits steady state no
/// writer ever drains it, so it must not grow with traffic.  When the
/// queue is full new touches are dropped (O(1), no shifting under the
/// mutex) — acceptable staleness for an eviction heuristic (FIFO, the
/// paper default, ignores touches entirely).
const TOUCH_QUEUE_LIMIT: usize = 1024;

use std::sync::Condvar;

pub struct SharedExpertCache {
    inner: RwLock<ExpertCache>,
    /// hits resolved on the read path (not yet in `inner`'s stats)
    read_hits: AtomicU64,
    /// read-path accesses awaiting policy replay under a write lock,
    /// bounded by [`TOUCH_QUEUE_LIMIT`]; skipped entirely when the
    /// eviction policy ignores accesses (`track_touches == false`)
    touched: Mutex<Vec<ExpertKey>>,
    /// whether the eviction policy consumes access notifications
    /// (false for FIFO, the paper default — read-path hits then touch
    /// no shared mutable state beyond one atomic)
    track_touches: bool,
    /// unpin notification for `ensure` callers stalled on a fully
    /// pinned budget: generation counter + condvar, so waiters block
    /// instead of spinning on the write lock
    unpin_gen: Mutex<u64>,
    unpin_cv: Condvar,
}

/// Poison-tolerant mutex acquisition (see the module doc): take the
/// guard even if a holder panicked — the protected state is a counter
/// or bounded queue whose updates are single statements, never left
/// half-applied by an unwind.
fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl SharedExpertCache {
    /// Poison-tolerant read lock (see the module doc for why the state
    /// behind a poisoned lock is still sound).
    fn read_inner(&self) -> RwLockReadGuard<'_, ExpertCache> {
        self.inner.read().unwrap_or_else(|e| e.into_inner())
    }

    /// Poison-tolerant write lock.
    fn write_inner(&self) -> RwLockWriteGuard<'_, ExpertCache> {
        self.inner.write().unwrap_or_else(|e| e.into_inner())
    }

    pub fn new(cache: ExpertCache) -> Self {
        let track_touches = cache.policy_uses_access();
        SharedExpertCache {
            inner: RwLock::new(cache),
            read_hits: AtomicU64::new(0),
            touched: Mutex::new(Vec::new()),
            track_touches,
            unpin_gen: Mutex::new(0),
            unpin_cv: Condvar::new(),
        }
    }

    /// Read access to the underlying cache (planning, diagnostics).
    pub fn read(&self) -> RwLockReadGuard<'_, ExpertCache> {
        self.read_inner()
    }

    /// Attach the on-disk SSD tier (see [`ExpertCache::attach_store`]).
    /// Takes the write lock once; done at construction time, before
    /// serving traffic.
    pub fn attach_store(&self, binding: crate::experts::StoreBinding) {
        self.write_inner().attach_store(binding);
    }

    /// Label ladder trace events with the owning device's trace pid
    /// (see [`ExpertCache::set_trace_pid`]).  Construction-time only.
    pub fn set_trace_pid(&self, pid: u32) {
        self.write_inner().set_trace_pid(pid);
    }

    /// Ensure residency without pinning — the prefetch/warmer entry
    /// point.  `fetch` is `Fn` (not `FnOnce`) because a fully pinned
    /// budget makes the call retry.
    pub fn ensure<F>(
        &self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        fetch: F,
    ) -> Result<(Arc<ResidentExpert>, bool, f64)>
    where
        F: Fn() -> Result<[DeviceBuffer; 4]>,
    {
        self.ensure_impl(key, real_bytes, blocking, false, None, fetch)
    }

    /// Non-blocking staging with an explicit scheduling deadline (the
    /// modeled seconds until the expert's layer computes) — the
    /// depth-window warmer's entry point.  The overlap credit on the
    /// shared [`crate::experts::BandwidthWindow`] is bounded by this
    /// deadline, so a fetch staged with more lead earns more hideable
    /// window (see [`ExpertCache::try_ensure_by`]).
    pub fn ensure_deadline<F>(
        &self,
        key: ExpertKey,
        real_bytes: usize,
        deadline_secs: f64,
        fetch: F,
    ) -> Result<(Arc<ResidentExpert>, bool, f64)>
    where
        F: Fn() -> Result<[DeviceBuffer; 4]>,
    {
        self.ensure_impl(key, real_bytes, false, false, Some(deadline_secs), fetch)
    }

    /// Ensure residency and pin in one atomic step (pin registered
    /// before the lock protecting residency is released) — the compute
    /// entry point.  The caller must [`SharedExpertCache::unpin`] after
    /// the invocation completes.
    pub fn ensure_pinned<F>(
        &self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        fetch: F,
    ) -> Result<(Arc<ResidentExpert>, bool, f64)>
    where
        F: Fn() -> Result<[DeviceBuffer; 4]>,
    {
        self.ensure_impl(key, real_bytes, blocking, true, None, fetch)
    }

    fn ensure_impl<F>(
        &self,
        key: ExpertKey,
        real_bytes: usize,
        blocking: bool,
        pin: bool,
        deadline_secs: Option<f64>,
        fetch: F,
    ) -> Result<(Arc<ResidentExpert>, bool, f64)>
    where
        F: Fn() -> Result<[DeviceBuffer; 4]>,
    {
        // fast path: warm expert under the read lock
        {
            let guard = self.read_inner();
            if let Some(resident) = guard.get(&key) {
                if pin {
                    // still holding the read lock: no evictor can run
                    // until the pin is registered
                    guard.pin(key);
                }
                self.read_hits.fetch_add(1, Ordering::Relaxed);
                if self.track_touches {
                    let mut touched = lock_tolerant(&self.touched);
                    if touched.len() < TOUCH_QUEUE_LIMIT {
                        touched.push(key);
                    }
                }
                return Ok((resident, true, 0.0));
            }
        }
        // slow path: exclusive fetch/eviction; retry while the budget is
        // fully pinned by concurrent invocations
        loop {
            // snapshot the unpin generation BEFORE trying, so an unpin
            // that lands between the failed attempt and the wait below
            // is never missed
            let gen_before = *lock_tolerant(&self.unpin_gen);
            {
                let mut guard = self.write_inner();
                let deferred = std::mem::take(&mut *lock_tolerant(&self.touched));
                guard.note_accesses(&deferred);
                match guard.try_ensure_by(key, real_bytes, blocking, deadline_secs, || fetch())? {
                    EnsureOutcome::Resident { expert, hit, transfer_secs } => {
                        if pin {
                            guard.pin(key);
                        }
                        let sleep = !hit && guard.cost_model().real_sleep && transfer_secs > 0.0;
                        drop(guard);
                        if sleep {
                            // the fetching thread pays the modeled wall
                            // time on ITS timeline, outside the lock —
                            // concurrent hits keep flowing while the
                            // "transfer" is in flight
                            std::thread::sleep(Duration::from_secs_f64(transfer_secs));
                        }
                        return Ok((expert, hit, transfer_secs));
                    }
                    EnsureOutcome::AllPinned => {}
                }
            }
            // every resident expert is pinned by an in-flight
            // invocation; block until one unpins (timeout-bounded as a
            // belt-and-braces backstop)
            let mut gen = lock_tolerant(&self.unpin_gen);
            while *gen == gen_before {
                let (g, timeout) = self
                    .unpin_cv
                    .wait_timeout(gen, Duration::from_millis(1))
                    .unwrap_or_else(|e| e.into_inner());
                gen = g;
                if timeout.timed_out() {
                    break;
                }
            }
        }
    }

    pub fn pin(&self, key: ExpertKey) {
        self.read_inner().pin(key);
    }

    pub fn unpin(&self, key: &ExpertKey) {
        self.read_inner().unpin(key);
        // wake any `ensure` stalled on a fully pinned budget
        *lock_tolerant(&self.unpin_gen) += 1;
        self.unpin_cv.notify_all();
    }

    pub fn contains(&self, key: &ExpertKey) -> bool {
        self.read_inner().contains(key)
    }

    /// Which tier of the §6 ladder `key` sits in right now (tier-aware
    /// prefetch planning reads this under the read lock).
    pub fn tier_of(&self, key: &ExpertKey) -> crate::memory::Tier {
        self.read_inner().tier_of(key)
    }

    /// Snapshot of the underlying residency ledger (per-tier occupancy,
    /// promotions per hop, ladder seconds).
    pub fn hierarchy_stats(&self) -> crate::memory::HierarchyStats {
        self.read_inner().hierarchy_stats()
    }

    /// Merged statistics snapshot: the inner cache's counters plus the
    /// hits resolved on the lock-free read path.
    pub fn stats(&self) -> CacheStats {
        let mut stats = self.read_inner().stats().clone();
        stats.hits += self.read_hits.load(Ordering::Relaxed);
        stats
    }

    pub fn reset_stats(&self) {
        let mut guard = self.write_inner();
        guard.reset_stats();
        self.read_hits.store(0, Ordering::Relaxed);
        lock_tolerant(&self.touched).clear();
    }

    /// The modeled prefetch link this cache charges non-blocking
    /// staging into (shared across every device cache of a box in the
    /// cluster path).
    pub fn bandwidth_window(&self) -> Arc<crate::experts::BandwidthWindow> {
        self.read_inner().bandwidth_window()
    }

    /// Point this cache at a shared bandwidth window (construction
    /// time, before traffic — see [`ExpertCache::share_window`]).
    pub fn share_window(&self, window: Arc<crate::experts::BandwidthWindow>) {
        self.write_inner().share_window(window);
    }

    /// Modeled transfer seconds currently queued on the prefetch link.
    pub fn prefetch_backlog_secs(&self) -> f64 {
        self.read_inner().prefetch_backlog_secs()
    }

    pub fn check_invariants(&self) -> Result<()> {
        self.read_inner().check_invariants()
    }

    pub fn used(&self) -> usize {
        self.read_inner().used()
    }

    pub fn budget(&self) -> usize {
        self.read_inner().budget()
    }

    pub fn peak(&self) -> usize {
        self.read_inner().peak()
    }

    pub fn resident_count(&self) -> usize {
        self.read_inner().resident_count()
    }

    pub fn clear(&self) {
        self.write_inner().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experts::make_policy;
    use crate::memory::CostModel;
    use crate::runtime::stage_expert_parts;
    use crate::testkit;

    fn shared_cache(budget_experts: usize) -> (Arc<crate::runtime::ModelBundle>, SharedExpertCache, usize) {
        let b = testkit::tiny_bundle();
        let block = b.topology.moe_blocks[0];
        let real = b.weights.expert_bytes(block, 0).unwrap();
        let cache = SharedExpertCache::new(ExpertCache::new(
            budget_experts * real + 64,
            CostModel::physical(real),
            make_policy("fifo").unwrap(),
        ));
        (b, cache, real)
    }

    #[test]
    fn read_path_hits_are_counted_and_merged() {
        let (b, cache, real) = shared_cache(4);
        let block = b.topology.moe_blocks[0];
        let key = ExpertKey::new(block, 0);
        let fetch = || stage_expert_parts(&b.engine, &b.weights, block, 0);
        let (_, hit, _) = cache.ensure(key, real, false, fetch).unwrap();
        assert!(!hit, "cold cache must miss");
        let (_, hit, secs) = cache.ensure(key, real, false, fetch).unwrap();
        assert!(hit, "second lookup must hit on the read path");
        assert_eq!(secs, 0.0);
        let stats = cache.stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
        assert!(stats.overlapped_transfer_secs > 0.0, "non-blocking charge is overlapped");
        assert_eq!(stats.exposed_transfer_secs(), 0.0);
    }

    #[test]
    fn fully_pinned_budget_waits_for_unpin_instead_of_failing() {
        let (b, cache, real) = shared_cache(1);
        let block = b.topology.moe_blocks[0];
        let k0 = ExpertKey::new(block, 0);
        let k1 = ExpertKey::new(block, 1);
        cache
            .ensure_pinned(k0, real, true, || stage_expert_parts(&b.engine, &b.weights, block, 0))
            .unwrap();
        std::thread::scope(|s| {
            let unpinner = s.spawn(|| {
                std::thread::sleep(Duration::from_millis(5));
                cache.unpin(&k0);
            });
            // blocks until the concurrent unpin frees the single slot
            let (_, hit, _) = cache
                .ensure_pinned(k1, real, true, || {
                    stage_expert_parts(&b.engine, &b.weights, block, 1)
                })
                .unwrap();
            assert!(!hit);
            unpinner.join().unwrap();
        });
        cache.unpin(&k1);
        cache.check_invariants().unwrap();
        assert!(cache.contains(&k1));
    }

    #[test]
    fn poisoned_lock_does_not_cascade() {
        let (b, cache, real) = shared_cache(2);
        let block = b.topology.moe_blocks[0];
        let k0 = ExpertKey::new(block, 0);
        // a fetch closure that panics does so while `ensure` holds the
        // write lock — the same shape as the server's `inject_panic`
        // hook firing mid-batch — poisoning `inner`
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = cache.ensure(k0, real, true, || panic!("injected fetch panic"));
        }));
        assert!(result.is_err(), "the injected panic must reach its own caller");
        // every accessor below used to cascade-panic on the poisoned
        // lock; the fetch panicked before any ledger mutation, so the
        // cache must still be consistent and must keep serving
        cache.check_invariants().unwrap();
        assert!(!cache.contains(&k0), "failed fetch must not leave a resident entry");
        assert_eq!(cache.resident_count(), 0);
        let (_, hit, _) = cache
            .ensure(k0, real, true, || stage_expert_parts(&b.engine, &b.weights, block, 0))
            .unwrap();
        assert!(!hit, "the retried fetch is a plain miss");
        assert!(cache.contains(&k0));
        cache.check_invariants().unwrap();
    }

    #[test]
    fn concurrent_ensure_storm_preserves_invariants() {
        let (b, cache, real) = shared_cache(3);
        let block = b.topology.moe_blocks[0];
        let e = b.topology.num_experts;
        std::thread::scope(|s| {
            for thread_id in 0..4u64 {
                let cache = &cache;
                let b = &b;
                s.spawn(move || {
                    let mut rng = crate::util::rng::Rng::new(thread_id);
                    for _ in 0..200 {
                        let expert = rng.usize_below(e);
                        let key = ExpertKey::new(block, expert);
                        let (resident, _hit, _secs) = cache
                            .ensure_pinned(key, real, thread_id % 2 == 0, || {
                                stage_expert_parts(&b.engine, &b.weights, block, expert)
                            })
                            .unwrap();
                        // touch the buffers while pinned, then release
                        assert_eq!(resident.parts.len(), 4);
                        cache.unpin(&key);
                    }
                });
            }
        });
        cache.check_invariants().unwrap();
        let stats = cache.stats();
        assert_eq!(stats.hits + stats.misses, 4 * 200);
        assert!(stats.evictions > 0, "eviction pressure never materialized");
    }
}
