//! Cross-layer prefetch bandwidth scheduling: one budgeted, shared
//! bandwidth window for expert staging, and earliest-deadline-first
//! admission into it.
//!
//! PR 5's overlap model gave every cache its own busy-until prefetch
//! clock and staged exactly one layer ahead, so an SSD-deep expert whose
//! ladder time exceeds one layer's compute was exposed on the critical
//! path no matter how early the hash table predicted it.  This module
//! replaces the per-cache clock with a [`BandwidthWindow`] — a modeled
//! backlog queue on the host link that several caches (all the devices
//! of one box) can share — and adds the admission logic that decides
//! *which* planned fetches may occupy it, in *what* order:
//!
//! - every planned fetch carries a **deadline** (the modeled start of
//!   its layer's compute, [`crate::memory::fetch_deadline_secs`]) and a
//!   tier-derived **lead** ([`crate::memory::lead_layers`]: SSD-deep
//!   experts start 2–3 layers ahead, RAM hops 1, device-resident are
//!   skipped);
//! - [`admit_edf`] orders fetches earliest-deadline-first and walks the
//!   projected backlog, deferring low-confidence predictions that could
//!   not arrive in time anyway (so they don't burn window that certain
//!   ones need — they are re-planned just-in-time one layer ahead,
//!   where they are never deferred);
//! - [`BandwidthWindow::charge`] credits only the share of a transfer
//!   that fits between the link's backlog and the fetch's deadline, so
//!   hidden-transfer credit is bounded by the bandwidth window that
//!   actually existed AND by the compute window before need-time — a
//!   9x-ladder SSD promotion staged one layer ahead can no longer claim
//!   full overlap.
//!
//! Everything here is *accounting on the modeled timeline*: admission
//! reorders and defers non-blocking staging only, never what the
//! compute path fetches, so f32 outputs are bit-identical with the
//! scheduler on or off, and the ladder attribution identity
//! (`ladder_secs() == modeled_transfer_secs`) is untouched — the ledger
//! still charges every promotion exactly once.

use std::sync::Mutex;

use crate::experts::ExpertKey;
use crate::memory::Tier;

/// Predictions with top-rank router agreement below this threshold do
/// not get speculative deep staging when the window is already
/// backlogged past their deadline ([`admit_edf`]); they fall back to
/// just-in-time staging one layer ahead.
pub const MIN_CONFIDENCE: f64 = 0.25;

/// One read of the window's counters — what observability publishes.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct WindowSnapshot {
    /// modeled transfer seconds queued on the link, not yet drained
    pub backlog_secs: f64,
    /// backlog carried into the current epoch by
    /// [`BandwidthWindow::carry_epoch`] (the drain-or-carry fix: a stats
    /// reset must not silently discard scheduled work)
    pub carried_backlog_secs: f64,
    /// fetches charged into the window this epoch
    pub admitted: u64,
    /// fetches deferred by [`admit_edf`] for low prediction confidence
    pub deferred_low_confidence: u64,
    /// drain capacity offered to the window this epoch (compute-layer
    /// advances draining the link)
    pub offered_drain_secs: f64,
    /// the share of `offered_drain_secs` that actually drained backlog
    pub used_drain_secs: f64,
}

impl WindowSnapshot {
    /// Fraction of the offered drain capacity the link actually used,
    /// or `None` before any capacity was offered (a window that never
    /// opened has no utilization, and `0.0` would read as "idle").
    pub fn utilization(&self) -> Option<f64> {
        if self.offered_drain_secs > 0.0 {
            Some(self.used_drain_secs / self.offered_drain_secs)
        } else {
            None
        }
    }
}

#[derive(Debug)]
struct WindowState {
    backlog_secs: f64,
    /// occupancy multiplier: modeled seconds per charged transfer
    /// second.  `1.0` models the reference PCIe link; `--host-bw`
    /// scales it (`reference_bw / host_bw`), so a slower host link
    /// backlogs faster without touching the ladder charge itself
    rate: f64,
    carried_backlog_secs: f64,
    admitted: u64,
    deferred_low_confidence: u64,
    offered_drain_secs: f64,
    used_drain_secs: f64,
}

/// The modeled prefetch link as a **budgeted, shared resource**: a
/// backlog queue in modeled seconds that staging charges into and
/// compute-layer advances drain out of.  Wrap it in an `Arc` to share
/// one window across every device cache of a box (the cluster path) —
/// all interior mutability, so charging works through `&self` from
/// several caches at once.
#[derive(Debug)]
pub struct BandwidthWindow {
    state: Mutex<WindowState>,
}

impl Default for BandwidthWindow {
    fn default() -> Self {
        Self::new()
    }
}

impl BandwidthWindow {
    pub fn new() -> Self {
        BandwidthWindow {
            state: Mutex::new(WindowState {
                backlog_secs: 0.0,
                rate: 1.0,
                carried_backlog_secs: 0.0,
                admitted: 0,
                deferred_low_confidence: 0,
                offered_drain_secs: 0.0,
                used_drain_secs: 0.0,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, WindowState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Set the occupancy multiplier (`reference_bw / host_bw`).  Values
    /// `<= 0` are ignored (the reference link stays in effect).
    pub fn set_rate(&self, rate: f64) {
        if rate > 0.0 && rate.is_finite() {
            self.lock().rate = rate;
        }
    }

    pub fn rate(&self) -> f64 {
        self.lock().rate
    }

    /// Charge one non-blocking transfer of `secs` modeled seconds whose
    /// layer compute starts `deadline_secs` from now, and return the
    /// overlap credit: the share of the transfer that fits between the
    /// link's current backlog and the deadline,
    /// `clamp(deadline - backlog, 0, secs)`.  The transfer's occupancy
    /// (`secs * rate`) joins the backlog either way — an uncreditable
    /// fetch still consumes the window behind it.
    pub fn charge(&self, secs: f64, deadline_secs: f64) -> f64 {
        let mut st = self.lock();
        let credit = (deadline_secs - st.backlog_secs).clamp(0.0, secs);
        st.backlog_secs += secs * st.rate;
        st.admitted += 1;
        credit
    }

    /// Offer `secs` of drain capacity (one compute layer advanced):
    /// the link works off up to that much backlog.
    pub fn drain(&self, secs: f64) {
        if secs <= 0.0 {
            return;
        }
        let mut st = self.lock();
        let used = st.backlog_secs.min(secs);
        st.backlog_secs -= used;
        st.offered_drain_secs += secs;
        st.used_drain_secs += used;
    }

    /// Modeled transfer seconds currently queued on the link.
    pub fn backlog_secs(&self) -> f64 {
        self.lock().backlog_secs
    }

    /// Record `n` fetches deferred by confidence-weighted admission.
    pub fn note_deferred(&self, n: u64) {
        self.lock().deferred_low_confidence += n;
    }

    /// Start a new stats epoch, **carrying** the scheduled backlog
    /// forward instead of silently discarding it (the
    /// `reset_transfer_clock` fix): counters zero, the backlog stays
    /// queued, and the carried amount is recorded so conservation is
    /// checkable — `backlog_before == carried + drained` always, with
    /// drained `== 0` here.  Idempotent: a second reset with no traffic
    /// in between re-records the same carry.  Returns the carried
    /// backlog.
    pub fn carry_epoch(&self) -> f64 {
        let mut st = self.lock();
        st.carried_backlog_secs = st.backlog_secs;
        st.admitted = 0;
        st.deferred_low_confidence = 0;
        st.offered_drain_secs = 0.0;
        st.used_drain_secs = 0.0;
        st.backlog_secs
    }

    pub fn snapshot(&self) -> WindowSnapshot {
        let st = self.lock();
        WindowSnapshot {
            backlog_secs: st.backlog_secs,
            carried_backlog_secs: st.carried_backlog_secs,
            admitted: st.admitted,
            deferred_low_confidence: st.deferred_low_confidence,
            offered_drain_secs: st.offered_drain_secs,
            used_drain_secs: st.used_drain_secs,
        }
    }
}

/// What the EDF admission needs to know about a planned fetch —
/// implemented by both the single-device [`super::PlannedFetch`] and
/// the cluster's [`crate::cluster::ClusterFetch`], so one scheduler
/// serves both paths.
pub trait ScheduledFetch {
    fn key(&self) -> ExpertKey;
    fn tier(&self) -> Tier;
    fn token_count(&self) -> usize;
    fn deadline_secs(&self) -> f64;
    fn confidence(&self) -> f64;
    fn layers_ahead(&self) -> usize;
}

/// Outcome of [`admit_edf`]: the admitted fetches in issue order, plus
/// the span/observability summary of the round.
#[derive(Debug)]
pub struct Admission<T> {
    /// fetches to issue, earliest deadline first
    pub admit: Vec<T>,
    /// low-confidence fetches dropped this round (they re-enter the
    /// plan just-in-time at one layer ahead, where they always admit)
    pub deferred: usize,
    /// tightest `deadline - projected backlog` among admitted fetches
    /// (negative = already late), for the `prefetch_stage` span
    pub min_slack_secs: Option<f64>,
    /// deepest staging lead among admitted fetches, in layers
    pub max_lead_layers: usize,
}

/// Order a staging round **earliest-deadline-first** and admit it into
/// the projected window.  Ties break toward higher prediction
/// confidence, then the established within-layer order (deepest tier
/// first, then hottest, then key) — so a low-agreement fetch can never
/// displace a high-agreement one with an earlier-or-equal deadline.
///
/// A fetch is *deferred* (dropped from this round, counted) only when
/// all three hold: it is speculative (`layers_ahead > 1`), its
/// confidence is below [`MIN_CONFIDENCE`], and the projected backlog
/// already exceeds its deadline (zero possible credit — issuing it
/// would only burn window that certain fetches need).  `occupancy`
/// maps a fetch to the modeled seconds it would add to the backlog
/// (`rate`-scaled, matching [`BandwidthWindow::charge`]).
pub fn admit_edf<T: ScheduledFetch>(
    mut plan: Vec<T>,
    backlog_secs: f64,
    occupancy: impl Fn(&T) -> f64,
) -> Admission<T> {
    plan.sort_by(|a, b| {
        a.deadline_secs()
            .total_cmp(&b.deadline_secs())
            .then(b.confidence().total_cmp(&a.confidence()))
            .then(b.tier().cmp(&a.tier()))
            .then(b.token_count().cmp(&a.token_count()))
            .then(a.key().cmp(&b.key()))
    });
    let mut admit = Vec::with_capacity(plan.len());
    let mut deferred = 0usize;
    let mut min_slack: Option<f64> = None;
    let mut max_lead = 0usize;
    let mut projected = backlog_secs;
    for fetch in plan {
        let slack = fetch.deadline_secs() - projected;
        let speculative = fetch.layers_ahead() > 1;
        if speculative && slack <= 0.0 && fetch.confidence() < MIN_CONFIDENCE {
            deferred += 1;
            continue;
        }
        projected += occupancy(&fetch);
        min_slack = Some(min_slack.map_or(slack, |m: f64| m.min(slack)));
        max_lead = max_lead.max(fetch.layers_ahead());
        admit.push(fetch);
    }
    Admission { admit, deferred, min_slack_secs: min_slack, max_lead_layers: max_lead }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone)]
    struct Fetch {
        key: ExpertKey,
        tier: Tier,
        tokens: usize,
        deadline: f64,
        confidence: f64,
        ahead: usize,
    }

    impl ScheduledFetch for Fetch {
        fn key(&self) -> ExpertKey {
            self.key
        }
        fn tier(&self) -> Tier {
            self.tier
        }
        fn token_count(&self) -> usize {
            self.tokens
        }
        fn deadline_secs(&self) -> f64 {
            self.deadline
        }
        fn confidence(&self) -> f64 {
            self.confidence
        }
        fn layers_ahead(&self) -> usize {
            self.ahead
        }
    }

    fn fetch(expert: usize, deadline: f64, confidence: f64, ahead: usize) -> Fetch {
        Fetch {
            key: ExpertKey::new(0, expert),
            tier: Tier::Ssd,
            tokens: 1,
            deadline,
            confidence,
            ahead,
        }
    }

    #[test]
    fn charge_credits_up_to_deadline_and_backlogs_the_rest() {
        let w = BandwidthWindow::new();
        // empty link: a transfer shorter than its deadline is fully hidden
        assert_eq!(w.charge(1.0, 3.0), 1.0);
        // backlog is now 1.0; a same-shape transfer is credited only the
        // remaining window before its deadline
        assert_eq!(w.charge(1.0, 1.5), 0.5);
        // and one whose deadline is already behind the backlog earns zero
        assert_eq!(w.charge(1.0, 1.0), 0.0);
        assert!((w.backlog_secs() - 3.0).abs() < 1e-12);
        let snap = w.snapshot();
        assert_eq!(snap.admitted, 3);
    }

    #[test]
    fn drain_works_off_backlog_and_tracks_utilization() {
        let w = BandwidthWindow::new();
        w.charge(1.0, 1.0);
        w.drain(0.4);
        assert!((w.backlog_secs() - 0.6).abs() < 1e-12);
        // over-draining idles the link: offered > used
        w.drain(1.0);
        assert_eq!(w.backlog_secs(), 0.0);
        let snap = w.snapshot();
        assert!((snap.offered_drain_secs - 1.4).abs() < 1e-12);
        assert!((snap.used_drain_secs - 1.0).abs() < 1e-12);
        assert!((snap.utilization().unwrap() - 1.0 / 1.4).abs() < 1e-12);
    }

    #[test]
    fn utilization_none_before_any_drain() {
        let w = BandwidthWindow::new();
        w.charge(1.0, 1.0);
        assert_eq!(w.snapshot().utilization(), None);
    }

    #[test]
    fn rate_scales_occupancy_not_credit() {
        let w = BandwidthWindow::new();
        w.set_rate(2.0); // half the host bandwidth: occupancy doubles
        assert_eq!(w.charge(1.0, 3.0), 1.0, "credit is in transfer seconds");
        assert!((w.backlog_secs() - 2.0).abs() < 1e-12, "occupancy is rate-scaled");
        // non-positive / non-finite rates are rejected
        w.set_rate(0.0);
        w.set_rate(f64::NAN);
        assert_eq!(w.rate(), 2.0);
    }

    #[test]
    fn carry_epoch_conserves_backlog() {
        let w = BandwidthWindow::new();
        w.charge(2.0, 1.0);
        w.note_deferred(3);
        let backlog_before = w.backlog_secs();
        let carried = w.carry_epoch();
        assert_eq!(carried, backlog_before, "reset must not discard backlog");
        let snap = w.snapshot();
        assert_eq!(snap.backlog_secs, backlog_before, "backlog carried, not dropped");
        assert_eq!(snap.carried_backlog_secs, backlog_before);
        assert_eq!(snap.admitted, 0, "counters restart per epoch");
        assert_eq!(snap.deferred_low_confidence, 0);
        // idempotent: a quiet second reset re-records the same carry
        assert_eq!(w.carry_epoch(), backlog_before);
    }

    #[test]
    fn edf_orders_by_deadline_under_saturation() {
        // a saturated window (backlog past every deadline) must still
        // issue in deadline order — EDF is about order, not optimism
        let plan = vec![
            fetch(2, 3.0, 0.9, 3),
            fetch(0, 1.0, 0.9, 1),
            fetch(1, 2.0, 0.9, 2),
        ];
        let adm = admit_edf(plan, 10.0, |_| 1.0);
        let experts: Vec<usize> = adm.admit.iter().map(|f| f.key.expert).collect();
        assert_eq!(experts, vec![0, 1, 2]);
        assert_eq!(adm.deferred, 0, "confident fetches are never deferred");
        assert!(adm.min_slack_secs.unwrap() < 0.0, "saturated: every slack negative");
    }

    #[test]
    fn low_confidence_never_displaces_earlier_deadlines() {
        // the low-agreement fetch has the LATER deadline; whatever the
        // window state, it must sort after the certain, earlier one
        let plan = vec![fetch(7, 5.0, 0.05, 3), fetch(1, 1.0, 0.95, 1)];
        let adm = admit_edf(plan, 0.0, |_| 10.0);
        assert_eq!(adm.admit[0].key.expert, 1);
    }

    #[test]
    fn speculative_low_confidence_defers_only_when_late() {
        // backlog already past its deadline AND speculative AND
        // low-confidence -> deferred
        let late = fetch(3, 1.0, 0.1, 3);
        let adm = admit_edf(vec![late.clone()], 2.0, |_| 1.0);
        assert!(adm.admit.is_empty());
        assert_eq!(adm.deferred, 1);
        // same fetch one layer ahead (just-in-time) always admits
        let jit = Fetch { ahead: 1, ..late.clone() };
        let adm = admit_edf(vec![jit], 2.0, |_| 1.0);
        assert_eq!(adm.admit.len(), 1);
        assert_eq!(adm.deferred, 0);
        // and a confident speculative fetch admits even when late
        let sure = Fetch { confidence: 0.9, ..late };
        let adm = admit_edf(vec![sure], 2.0, |_| 1.0);
        assert_eq!(adm.admit.len(), 1);
    }

    #[test]
    fn equal_deadlines_break_toward_confidence_then_plan_order() {
        let mut a = fetch(5, 1.0, 0.3, 1);
        a.tier = Tier::Ram;
        let b = fetch(6, 1.0, 0.9, 1); // Ssd
        let c = fetch(4, 1.0, 0.9, 1); // Ssd, lower key
        let adm = admit_edf(vec![a, b, c], 0.0, |_| 0.1);
        let experts: Vec<usize> = adm.admit.iter().map(|f| f.key.expert).collect();
        // confidence first (0.9 before 0.3); among equals, key order
        assert_eq!(experts, vec![4, 6, 5]);
        assert_eq!(adm.max_lead_layers, 1);
    }
}
