//! Prefetch planning: from hash tables + cache state to an ordered
//! fetch plan.
//!
//! The paper's inference thread does "dynamical loading ... right after
//! the finish of inference on the previous batch following the pipeline
//! parallelism mechanism" (§3.1).  The planner decides *what* to load
//! and in *which order*: missing experts only, earliest MoE layer first
//! (the layer the forward pass reaches first), and within a layer by
//! **ladder depth** then heat — an SSD-deep expert's promotion costs
//! the NVMe+PCIe ladder (~9x a RAM-resident one), so it starts
//! earliest; among equals, descending token count (an expert serving
//! more tokens hurts more if it misses).  Pure logic — unit-testable
//! without PJRT.
//!
//! [`plan_prefetch`] plans for one request; [`plan_prefetch_union`]
//! plans for a whole cross-request batch, taking the **union** of every
//! request's predicted expert set so each expert appears (and is
//! fetched, and has its transfer charged) at most once per batch —
//! token counts are summed across requests, so the heat ordering
//! reflects the batch, not any single sentence.
//!
//! ```
//! use sida_moe::coordinator::HashTable;
//! use sida_moe::experts::{make_policy, plan_prefetch, ExpertCache};
//! use sida_moe::memory::CostModel;
//!
//! // two tokens, one MoE layer, k = 1: tokens predicted on experts 3 and 5
//! let table = HashTable::new(0, 2, 1, 1, vec![3, 5], vec![1.0, 1.0], 0.0).unwrap();
//! let cache = ExpertCache::new(1 << 30, CostModel::physical(1 << 20), make_policy("fifo").unwrap());
//! let plan = plan_prefetch(&table, &[1], 1, &[1.0, 1.0], &cache);
//! assert_eq!(plan.len(), 2); // both experts missing from the cold cache
//! ```

use std::collections::BTreeMap;

use crate::coordinator::hash_table::HashTable;
use crate::experts::{ExpertCache, ExpertKey};
use crate::memory::Tier;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlannedFetch {
    pub key: ExpertKey,
    /// tokens routed to this expert (priority weight)
    pub token_count: usize,
    /// where the expert sits in the §6 ladder at planning time —
    /// SSD-deep experts are fetched first (their promotion is ~9x a
    /// RAM-resident one, so starting them earliest maximizes what the
    /// prefetch timeline can hide)
    pub tier: Tier,
}

/// Compute the ordered fetch plan for one request.
pub fn plan_prefetch(
    table: &HashTable,
    moe_blocks: &[usize],
    k_used: usize,
    mask: &[f32],
    cache: &ExpertCache,
) -> Vec<PlannedFetch> {
    plan_prefetch_union(&[(table, mask)], moe_blocks, k_used, cache)
}

/// Compute the ordered fetch plan for a cross-request batch: the union
/// of every `(table, mask)` pair's predicted experts, each at most once,
/// with token counts summed across requests.
pub fn plan_prefetch_union(
    requests: &[(&HashTable, &[f32])],
    moe_blocks: &[usize],
    k_used: usize,
    cache: &ExpertCache,
) -> Vec<PlannedFetch> {
    let mut plan = Vec::new();
    for (layer, &block) in moe_blocks.iter().enumerate() {
        plan.extend(plan_prefetch_layer(requests, block, layer, k_used, cache));
    }
    plan
}

/// Token counts per predicted expert at one MoE layer, summed over
/// every `(table, mask)` request of a batch — THE counting rule every
/// prefetch planner shares (single-device plans here, the cluster
/// router's per-holder plans, activation profiling).  Masked-out
/// tokens never count; ranks beyond the table's `k` are clamped.
pub fn predicted_expert_counts(
    requests: &[(&HashTable, &[f32])],
    layer: usize,
    k_used: usize,
) -> BTreeMap<usize, usize> {
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for &(table, mask) in requests {
        for t in 0..table.seq_len {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for r in 0..k_used.min(table.k) {
                *counts.entry(table.expert_at(t, layer, r)).or_insert(0) += 1;
            }
        }
    }
    counts
}

/// Fetch plan for **one MoE layer** of a (batch of) request(s) — the
/// planning unit of the layer-ahead warmer, which stages layer `j+1`'s
/// union while the inference thread computes layer `j`.  Missing
/// experts only, ordered **deepest tier first** (an SSD-resident
/// expert's promotion costs the NVMe + PCIe ladder, so it must start
/// earliest to hide), then hottest (most routed tokens across the
/// batch) first — hash-prediction value is tier-dependent.
pub fn plan_prefetch_layer(
    requests: &[(&HashTable, &[f32])],
    block: usize,
    layer: usize,
    k_used: usize,
    cache: &ExpertCache,
) -> Vec<PlannedFetch> {
    let counts = predicted_expert_counts(requests, layer, k_used);
    let mut layer_plan: Vec<PlannedFetch> = counts
        .into_iter()
        .filter(|(expert, _)| !cache.contains(&ExpertKey::new(block, *expert)))
        .map(|(expert, token_count)| {
            let key = ExpertKey::new(block, expert);
            PlannedFetch { key, token_count, tier: cache.tier_of(&key) }
        })
        .collect();
    // within a layer: deepest tier first, then hottest experts first
    layer_plan.sort_by(|a, b| {
        b.tier
            .cmp(&a.tier)
            .then(b.token_count.cmp(&a.token_count))
            .then(a.key.cmp(&b.key))
    });
    layer_plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experts::make_policy;
    use crate::memory::CostModel;

    fn table() -> HashTable {
        // L=4, M=2, K=2; layer0 top1: [0,0,1,2], layer1 top1: [3,3,3,4]
        let idx = vec![
            0, 1, 3, 0, //
            0, 1, 3, 0, //
            1, 0, 3, 0, //
            2, 0, 4, 0,
        ];
        let alpha = vec![0.5f32; 16];
        HashTable::new(0, 4, 2, 2, idx, alpha, 0.0).unwrap()
    }

    fn empty_cache() -> ExpertCache {
        ExpertCache::new(1 << 30, CostModel::physical(1000), make_policy("fifo").unwrap())
    }

    #[test]
    fn orders_by_layer_then_heat() {
        let cache = empty_cache();
        let mask = vec![1.0; 4];
        let plan = plan_prefetch(&table(), &[1, 3], 1, &mask, &cache);
        // layer 0 (block 1) first: expert 0 (2 tokens) before 1 and 2
        assert_eq!(plan[0].key, ExpertKey::new(1, 0));
        assert_eq!(plan[0].token_count, 2);
        assert!(plan[..3].iter().all(|p| p.key.block == 1));
        // then layer 1 (block 3): expert 3 (3 tokens) before 4
        assert_eq!(plan[3].key, ExpertKey::new(3, 3));
        assert_eq!(plan[3].token_count, 3);
        assert_eq!(plan.len(), 5);
    }

    #[test]
    fn skips_resident_experts() {
        // mark (1,0) resident by inserting through the public API
        let mut cache = empty_cache();
        // residency requires staged buffers; simulate with the pool-level
        // invariant instead: a fresh cache contains nothing, so compare
        // plan lengths with/without a mask that removes expert 0's tokens
        let mask_all = vec![1.0; 4];
        let plan_all = plan_prefetch(&table(), &[1, 3], 1, &mask_all, &cache);
        let mask_no01 = vec![0.0, 0.0, 1.0, 1.0];
        let plan_masked = plan_prefetch(&table(), &[1, 3], 1, &mask_no01, &cache);
        assert!(plan_masked.len() < plan_all.len());
        let _ = &mut cache;
    }

    #[test]
    fn k_used_expands_the_plan() {
        let cache = empty_cache();
        let mask = vec![1.0; 4];
        let p1 = plan_prefetch(&table(), &[1, 3], 1, &mask, &cache);
        let p2 = plan_prefetch(&table(), &[1, 3], 2, &mask, &cache);
        assert!(p2.len() >= p1.len());
    }

    #[test]
    fn empty_mask_empty_plan() {
        let cache = empty_cache();
        let plan = plan_prefetch(&table(), &[1, 3], 2, &[0.0; 4], &cache);
        assert!(plan.is_empty());
    }

    #[test]
    fn ssd_deep_experts_are_planned_before_hotter_ram_residents() {
        // expert 0 is the layer's hottest (2 tokens) but sits one cheap
        // PCIe hop away in RAM; experts 1 and 2 are SSD-deep.  The plan
        // must start the expensive SSD promotions first.
        let mut cache = empty_cache();
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        let hot = ExpertKey::new(1, 0);
        cache.ensure(hot, 1000, true, || Ok([buf(), buf(), buf(), buf()])).unwrap();
        cache.invalidate(&hot); // demote: hot is now RAM-resident
        assert_eq!(cache.tier_of(&hot), crate::memory::Tier::Ram);
        let mask = vec![1.0; 4];
        let plan = plan_prefetch_layer(&[(&table(), &mask[..])], 1, 0, 1, &cache);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan[0].key, ExpertKey::new(1, 1), "SSD-deep first");
        assert_eq!(plan[1].key, ExpertKey::new(1, 2));
        assert_eq!(plan[2].key, hot, "hot but RAM-resident goes last");
        assert_eq!(plan[2].tier, crate::memory::Tier::Ram);
        assert_eq!(plan[2].token_count, 2);
    }

    #[test]
    fn union_plans_each_expert_once_with_summed_heat() {
        let cache = empty_cache();
        let t = table();
        let mask = vec![1.0; 4];
        let single = plan_prefetch(&t, &[1, 3], 1, &mask, &cache);
        // the same table twice: identical expert set (each once), but
        // every token count doubled
        let union =
            plan_prefetch_union(&[(&t, &mask[..]), (&t, &mask[..])], &[1, 3], 1, &cache);
        assert_eq!(union.len(), single.len(), "union must dedupe experts");
        for (u, s) in union.iter().zip(single.iter()) {
            assert_eq!(u.key, s.key);
            assert_eq!(u.token_count, 2 * s.token_count);
        }
    }

    #[test]
    fn union_merges_disjoint_masks() {
        let cache = empty_cache();
        let t = table();
        // split the sentence across two "requests": first two tokens /
        // last two tokens — the union must equal the full-mask plan set
        let m1 = vec![1.0, 1.0, 0.0, 0.0];
        let m2 = vec![0.0, 0.0, 1.0, 1.0];
        let full = plan_prefetch(&t, &[1, 3], 1, &[1.0; 4], &cache);
        let union = plan_prefetch_union(&[(&t, &m1[..]), (&t, &m2[..])], &[1, 3], 1, &cache);
        let mut fk: Vec<_> = full.iter().map(|p| p.key).collect();
        let mut uk: Vec<_> = union.iter().map(|p| p.key).collect();
        fk.sort();
        uk.sort();
        assert_eq!(fk, uk);
    }
}
