//! Prefetch planning: from hash tables + cache state to an ordered
//! fetch plan.
//!
//! The paper's inference thread does "dynamical loading ... right after
//! the finish of inference on the previous batch following the pipeline
//! parallelism mechanism" (§3.1).  The planner decides *what* to load
//! and in *which order*: missing experts only, earliest MoE layer first
//! (the layer the forward pass reaches first), and within a layer by
//! **ladder depth** then heat — an SSD-deep expert's promotion costs
//! the NVMe+PCIe ladder (~9x a RAM-resident one), so it starts
//! earliest; among equals, descending token count (an expert serving
//! more tokens hurts more if it misses).  Pure logic — unit-testable
//! without PJRT.
//!
//! [`plan_prefetch`] plans for one request; [`plan_prefetch_union`]
//! plans for a whole cross-request batch, taking the **union** of every
//! request's predicted expert set so each expert appears (and is
//! fetched, and has its transfer charged) at most once per batch —
//! token counts are summed across requests, so the heat ordering
//! reflects the batch, not any single sentence.
//!
//! Every planned fetch also carries the cross-layer scheduling
//! metadata the bandwidth scheduler ([`super::admit_edf`]) consumes: a
//! **deadline** (modeled start of its layer's compute,
//! [`crate::memory::fetch_deadline_secs`]), a tier-derived **lead**
//! ([`crate::memory::lead_layers`]: SSD-deep experts want 2–3 layers
//! of head start, RAM hops 1) and the layer's hash-prediction
//! **confidence** (mean top-rank router agreement over the masked
//! tokens — low-agreement layers don't get speculative bandwidth).
//!
//! ```
//! use sida_moe::coordinator::HashTable;
//! use sida_moe::experts::{make_policy, plan_prefetch, ExpertCache};
//! use sida_moe::memory::CostModel;
//!
//! // two tokens, one MoE layer, k = 1: tokens predicted on experts 3 and 5
//! let table = HashTable::new(0, 2, 1, 1, vec![3, 5], vec![1.0, 1.0], 0.0).unwrap();
//! let cache = ExpertCache::new(1 << 30, CostModel::physical(1 << 20), make_policy("fifo").unwrap());
//! let plan = plan_prefetch(&table, &[1], 1, &[1.0, 1.0], &cache, 3);
//! assert_eq!(plan.len(), 2); // both experts missing from the cold cache
//! ```

use std::collections::BTreeMap;

use crate::coordinator::hash_table::HashTable;
use crate::experts::{ExpertCache, ExpertKey};
use crate::memory::Tier;

#[derive(Debug, Clone, PartialEq)]
pub struct PlannedFetch {
    pub key: ExpertKey,
    /// tokens routed to this expert (priority weight)
    pub token_count: usize,
    /// where the expert sits in the §6 ladder at planning time —
    /// SSD-deep experts are fetched first (their promotion is ~9x a
    /// RAM-resident one, so starting them earliest maximizes what the
    /// prefetch timeline can hide)
    pub tier: Tier,
    /// how many layers before its layer's compute this plan stages the
    /// fetch (1 = just-in-time, the one-layer-ahead model)
    pub layers_ahead: usize,
    /// tier-derived staging lead ([`crate::memory::lead_layers`]): how
    /// many layers of head start this tier's ladder seconds want.  The
    /// depth-window warmer stages a fetch early only within its lead
    pub lead_layers: usize,
    /// modeled seconds until this fetch's layer computes, measured from
    /// issue ([`crate::memory::fetch_deadline_secs`] at `layers_ahead`)
    /// — the EDF key, and the bound on the fetch's overlap credit
    pub deadline_secs: f64,
    /// per-layer router-agreement estimate from the hash table (mean
    /// top-rank alpha over masked tokens, `[0, 1]`); low-agreement
    /// predictions don't burn bandwidth that certain ones need
    pub confidence: f64,
}

impl crate::experts::bandwidth::ScheduledFetch for PlannedFetch {
    fn key(&self) -> ExpertKey {
        self.key
    }
    fn tier(&self) -> Tier {
        self.tier
    }
    fn token_count(&self) -> usize {
        self.token_count
    }
    fn deadline_secs(&self) -> f64 {
        self.deadline_secs
    }
    fn confidence(&self) -> f64 {
        self.confidence
    }
    fn layers_ahead(&self) -> usize {
        self.layers_ahead
    }
}

/// Compute the ordered fetch plan for one request.  `max_lead` clamps
/// the tier-derived staging lead (`--prefetch-depth`).
pub fn plan_prefetch(
    table: &HashTable,
    moe_blocks: &[usize],
    k_used: usize,
    mask: &[f32],
    cache: &ExpertCache,
    max_lead: usize,
) -> Vec<PlannedFetch> {
    plan_prefetch_union(&[(table, mask)], moe_blocks, k_used, cache, max_lead)
}

/// Compute the ordered fetch plan for a cross-request batch: the union
/// of every `(table, mask)` pair's predicted experts, each at most once,
/// with token counts summed across requests.  Planned **before compute
/// begins**, so layer `j` is `j + 1` layer windows away — that is each
/// fetch's deadline.
pub fn plan_prefetch_union(
    requests: &[(&HashTable, &[f32])],
    moe_blocks: &[usize],
    k_used: usize,
    cache: &ExpertCache,
    max_lead: usize,
) -> Vec<PlannedFetch> {
    let mut plan = Vec::new();
    for (layer, &block) in moe_blocks.iter().enumerate() {
        plan.extend(plan_prefetch_layer(
            requests, block, layer, k_used, layer + 1, max_lead, cache,
        ));
    }
    plan
}

/// Per-layer hash-prediction confidence: the mean top-rank router
/// agreement (`alpha`) over every masked-in token of the batch, in
/// `[0, 1]`.  An un-predicted layer (no live tokens) reports `1.0` —
/// there is nothing speculative to defer.
pub fn layer_confidence(requests: &[(&HashTable, &[f32])], layer: usize) -> f64 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for &(table, mask) in requests {
        for t in 0..table.seq_len {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            sum += table.alpha_at(t, layer, 0) as f64;
            n += 1;
        }
    }
    if n == 0 {
        1.0
    } else {
        sum / n as f64
    }
}

/// Token counts per predicted expert at one MoE layer, summed over
/// every `(table, mask)` request of a batch — THE counting rule every
/// prefetch planner shares (single-device plans here, the cluster
/// router's per-holder plans, activation profiling).  Masked-out
/// tokens never count; ranks beyond the table's `k` are clamped.
pub fn predicted_expert_counts(
    requests: &[(&HashTable, &[f32])],
    layer: usize,
    k_used: usize,
) -> BTreeMap<usize, usize> {
    let mut counts: BTreeMap<usize, usize> = BTreeMap::new();
    for &(table, mask) in requests {
        for t in 0..table.seq_len {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for r in 0..k_used.min(table.k) {
                *counts.entry(table.expert_at(t, layer, r)).or_insert(0) += 1;
            }
        }
    }
    counts
}

/// Fetch plan for **one MoE layer** of a (batch of) request(s) — the
/// planning unit of the depth-window warmer, which stages layer `j+a`'s
/// union while the inference thread computes layer `j` (`a =
/// layers_ahead`, up to each fetch's tier-derived lead).  Missing
/// experts only, ordered **deepest tier first** (an SSD-resident
/// expert's promotion costs the NVMe + PCIe ladder, so it must start
/// earliest to hide), then hottest (most routed tokens across the
/// batch) first — hash-prediction value is tier-dependent.  Every
/// fetch carries its deadline (`layers_ahead` layer windows), its lead
/// (clamped at `max_lead`, the `--prefetch-depth` knob) and the
/// layer's prediction confidence for EDF admission
/// ([`super::admit_edf`]).
pub fn plan_prefetch_layer(
    requests: &[(&HashTable, &[f32])],
    block: usize,
    layer: usize,
    k_used: usize,
    layers_ahead: usize,
    max_lead: usize,
    cache: &ExpertCache,
) -> Vec<PlannedFetch> {
    let counts = predicted_expert_counts(requests, layer, k_used);
    let experts_in_layer = counts.len();
    let confidence = layer_confidence(requests, layer);
    let costs = cache.cost_model().tier_costs();
    let sim_expert = cache.cost_model().sim_expert_bytes;
    let deadline_secs = crate::memory::fetch_deadline_secs(
        &costs,
        sim_expert,
        experts_in_layer,
        layers_ahead.max(1),
    );
    let mut layer_plan: Vec<PlannedFetch> = counts
        .into_iter()
        .filter(|(expert, _)| !cache.contains(&ExpertKey::new(block, *expert)))
        .map(|(expert, token_count)| {
            let key = ExpertKey::new(block, expert);
            let tier = cache.tier_of(&key);
            PlannedFetch {
                key,
                token_count,
                tier,
                layers_ahead: layers_ahead.max(1),
                lead_layers: crate::memory::lead_layers(
                    &costs,
                    tier,
                    sim_expert,
                    experts_in_layer,
                    max_lead,
                ),
                deadline_secs,
                confidence,
            }
        })
        .collect();
    // within a layer: deepest tier first, then hottest experts first
    layer_plan.sort_by(|a, b| {
        b.tier
            .cmp(&a.tier)
            .then(b.token_count.cmp(&a.token_count))
            .then(a.key.cmp(&b.key))
    });
    layer_plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experts::make_policy;
    use crate::memory::CostModel;

    fn table() -> HashTable {
        // L=4, M=2, K=2; layer0 top1: [0,0,1,2], layer1 top1: [3,3,3,4]
        let idx = vec![
            0, 1, 3, 0, //
            0, 1, 3, 0, //
            1, 0, 3, 0, //
            2, 0, 4, 0,
        ];
        let alpha = vec![0.5f32; 16];
        HashTable::new(0, 4, 2, 2, idx, alpha, 0.0).unwrap()
    }

    fn empty_cache() -> ExpertCache {
        ExpertCache::new(1 << 30, CostModel::physical(1000), make_policy("fifo").unwrap())
    }

    #[test]
    fn orders_by_layer_then_heat() {
        let cache = empty_cache();
        let mask = vec![1.0; 4];
        let plan = plan_prefetch(&table(), &[1, 3], 1, &mask, &cache, 3);
        // layer 0 (block 1) first: expert 0 (2 tokens) before 1 and 2
        assert_eq!(plan[0].key, ExpertKey::new(1, 0));
        assert_eq!(plan[0].token_count, 2);
        assert!(plan[..3].iter().all(|p| p.key.block == 1));
        // then layer 1 (block 3): expert 3 (3 tokens) before 4
        assert_eq!(plan[3].key, ExpertKey::new(3, 3));
        assert_eq!(plan[3].token_count, 3);
        assert_eq!(plan.len(), 5);
    }

    #[test]
    fn skips_resident_experts() {
        // mark (1,0) resident by inserting through the public API
        let mut cache = empty_cache();
        // residency requires staged buffers; simulate with the pool-level
        // invariant instead: a fresh cache contains nothing, so compare
        // plan lengths with/without a mask that removes expert 0's tokens
        let mask_all = vec![1.0; 4];
        let plan_all = plan_prefetch(&table(), &[1, 3], 1, &mask_all, &cache, 3);
        let mask_no01 = vec![0.0, 0.0, 1.0, 1.0];
        let plan_masked = plan_prefetch(&table(), &[1, 3], 1, &mask_no01, &cache, 3);
        assert!(plan_masked.len() < plan_all.len());
        let _ = &mut cache;
    }

    #[test]
    fn k_used_expands_the_plan() {
        let cache = empty_cache();
        let mask = vec![1.0; 4];
        let p1 = plan_prefetch(&table(), &[1, 3], 1, &mask, &cache, 3);
        let p2 = plan_prefetch(&table(), &[1, 3], 2, &mask, &cache, 3);
        assert!(p2.len() >= p1.len());
    }

    #[test]
    fn empty_mask_empty_plan() {
        let cache = empty_cache();
        let plan = plan_prefetch(&table(), &[1, 3], 2, &[0.0; 4], &cache, 3);
        assert!(plan.is_empty());
    }

    #[test]
    fn ssd_deep_experts_are_planned_before_hotter_ram_residents() {
        // expert 0 is the layer's hottest (2 tokens) but sits one cheap
        // PCIe hop away in RAM; experts 1 and 2 are SSD-deep.  The plan
        // must start the expensive SSD promotions first.
        let mut cache = empty_cache();
        let buf = || {
            crate::runtime::DeviceBuffer(
                crate::runtime::Literal::from_f32s(&[1], vec![0.0]).unwrap(),
            )
        };
        let hot = ExpertKey::new(1, 0);
        cache.ensure(hot, 1000, true, || Ok([buf(), buf(), buf(), buf()])).unwrap();
        cache.invalidate(&hot); // demote: hot is now RAM-resident
        assert_eq!(cache.tier_of(&hot), crate::memory::Tier::Ram);
        let mask = vec![1.0; 4];
        let plan = plan_prefetch_layer(&[(&table(), &mask[..])], 1, 0, 1, 1, 3, &cache);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan[0].key, ExpertKey::new(1, 1), "SSD-deep first");
        assert_eq!(plan[1].key, ExpertKey::new(1, 2));
        assert_eq!(plan[2].key, hot, "hot but RAM-resident goes last");
        assert_eq!(plan[2].tier, crate::memory::Tier::Ram);
        assert_eq!(plan[2].token_count, 2);
    }

    #[test]
    fn union_plans_each_expert_once_with_summed_heat() {
        let cache = empty_cache();
        let t = table();
        let mask = vec![1.0; 4];
        let single = plan_prefetch(&t, &[1, 3], 1, &mask, &cache, 3);
        // the same table twice: identical expert set (each once), but
        // every token count doubled
        let union =
            plan_prefetch_union(&[(&t, &mask[..]), (&t, &mask[..])], &[1, 3], 1, &cache, 3);
        assert_eq!(union.len(), single.len(), "union must dedupe experts");
        for (u, s) in union.iter().zip(single.iter()) {
            assert_eq!(u.key, s.key);
            assert_eq!(u.token_count, 2 * s.token_count);
        }
    }

    #[test]
    fn union_merges_disjoint_masks() {
        let cache = empty_cache();
        let t = table();
        // split the sentence across two "requests": first two tokens /
        // last two tokens — the union must equal the full-mask plan set
        let m1 = vec![1.0, 1.0, 0.0, 0.0];
        let m2 = vec![0.0, 0.0, 1.0, 1.0];
        let full = plan_prefetch(&t, &[1, 3], 1, &[1.0; 4], &cache, 3);
        let union =
            plan_prefetch_union(&[(&t, &m1[..]), (&t, &m2[..])], &[1, 3], 1, &cache, 3);
        let mut fk: Vec<_> = full.iter().map(|p| p.key).collect();
        let mut uk: Vec<_> = union.iter().map(|p| p.key).collect();
        fk.sort();
        uk.sort();
        assert_eq!(fk, uk);
    }

    #[test]
    fn plans_carry_scheduling_metadata() {
        let cache = empty_cache();
        let mask = vec![1.0; 4];
        let plan = plan_prefetch(&table(), &[1, 3], 1, &mask, &cache, 3);
        let costs = cache.cost_model().tier_costs();
        let sim = cache.cost_model().sim_expert_bytes;
        for p in &plan {
            // the test table's alpha is uniformly 0.5
            assert!((p.confidence - 0.5).abs() < 1e-6);
            // cold cache: everything is SSD-deep, lead in [1, max_lead]
            assert_eq!(p.tier, crate::memory::Tier::Ssd);
            assert!((1..=3).contains(&p.lead_layers));
        }
        // planned before compute: layer 0 is one window away, layer 1 two
        let l0: Vec<_> = plan.iter().filter(|p| p.key.block == 1).collect();
        let l1: Vec<_> = plan.iter().filter(|p| p.key.block == 3).collect();
        assert!(l0.iter().all(|p| p.layers_ahead == 1));
        assert!(l1.iter().all(|p| p.layers_ahead == 2));
        // deadlines are layer windows: layer 0 has 3 predicted experts
        let w0 = crate::memory::layer_window_secs(&costs, sim, 3);
        assert!((l0[0].deadline_secs - w0).abs() < 1e-12);
        assert!(l1[0].deadline_secs > l0[0].deadline_secs);
    }

    #[test]
    fn confidence_is_masked_mean_alpha() {
        let t = table();
        let full = vec![1.0f32; 4];
        assert!((layer_confidence(&[(&t, &full[..])], 0) - 0.5).abs() < 1e-9);
        // an empty mask has nothing speculative to defer
        let none = vec![0.0f32; 4];
        assert_eq!(layer_confidence(&[(&t, &none[..])], 0), 1.0);
    }
}
