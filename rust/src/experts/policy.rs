//! Eviction policies for the expert cache.
//!
//! The paper uses FIFO (§4.3 footnote: "For fair comparison with
//! baselines, we use FIFO, although other strategies could also be
//! effective") — LRU / LFU / Clock are provided as the ablation that
//! footnote invites (bench `ablation_eviction`).

use std::collections::{HashMap, HashSet, VecDeque};

use crate::experts::ExpertKey;

// `Sync` because the serving path shares `ExpertCache` behind an
// `RwLock` (see `experts::shared`); all in-tree policies are plain data
// mutated through `&mut self`, so the bound costs nothing.
pub trait EvictionPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Whether `on_access` affects this policy's decisions.  FIFO (the
    /// paper default) returns `false`, which lets the shared cache skip
    /// queueing read-path touches entirely.
    fn uses_access(&self) -> bool {
        true
    }
    /// A new key became resident.
    fn on_insert(&mut self, key: ExpertKey);
    /// A resident key was accessed (cache hit).
    fn on_access(&mut self, key: ExpertKey);
    /// Choose a victim among resident keys, skipping pinned ones.
    fn victim(&mut self, pinned: &HashSet<ExpertKey>) -> Option<ExpertKey>;
    /// A key was evicted (by us or externally invalidated).
    fn on_evict(&mut self, key: ExpertKey);
}

pub fn make_policy(name: &str) -> anyhow::Result<Box<dyn EvictionPolicy>> {
    match name {
        "fifo" => Ok(Box::new(FifoPolicy::default())),
        "lru" => Ok(Box::new(LruPolicy::default())),
        "lfu" => Ok(Box::new(LfuPolicy::default())),
        "clock" => Ok(Box::new(ClockPolicy::default())),
        other => anyhow::bail!("unknown eviction policy '{other}' (fifo|lru|lfu|clock)"),
    }
}

// ---------------------------------------------------------------------------

/// First-in-first-out (the paper's choice).
#[derive(Default)]
pub struct FifoPolicy {
    queue: VecDeque<ExpertKey>,
}

impl EvictionPolicy for FifoPolicy {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn uses_access(&self) -> bool {
        false // insertion order only
    }

    fn on_insert(&mut self, key: ExpertKey) {
        self.queue.push_back(key);
    }

    fn on_access(&mut self, _key: ExpertKey) {}

    fn victim(&mut self, pinned: &HashSet<ExpertKey>) -> Option<ExpertKey> {
        // oldest unpinned entry; pinned entries keep their position
        let pos = self.queue.iter().position(|k| !pinned.contains(k))?;
        self.queue.remove(pos)
    }

    fn on_evict(&mut self, key: ExpertKey) {
        if let Some(pos) = self.queue.iter().position(|k| *k == key) {
            self.queue.remove(pos);
        }
    }
}

// ---------------------------------------------------------------------------

/// Least-recently-used.
#[derive(Default)]
pub struct LruPolicy {
    /// access order, most recent at the back
    order: VecDeque<ExpertKey>,
}

impl LruPolicy {
    fn touch(&mut self, key: ExpertKey) {
        if let Some(pos) = self.order.iter().position(|k| *k == key) {
            self.order.remove(pos);
        }
        self.order.push_back(key);
    }
}

impl EvictionPolicy for LruPolicy {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn on_insert(&mut self, key: ExpertKey) {
        self.touch(key);
    }

    fn on_access(&mut self, key: ExpertKey) {
        self.touch(key);
    }

    fn victim(&mut self, pinned: &HashSet<ExpertKey>) -> Option<ExpertKey> {
        let pos = self.order.iter().position(|k| !pinned.contains(k))?;
        self.order.remove(pos)
    }

    fn on_evict(&mut self, key: ExpertKey) {
        if let Some(pos) = self.order.iter().position(|k| *k == key) {
            self.order.remove(pos);
        }
    }
}

// ---------------------------------------------------------------------------

/// Least-frequently-used with FIFO tiebreak.
#[derive(Default)]
pub struct LfuPolicy {
    freq: HashMap<ExpertKey, u64>,
    arrival: VecDeque<ExpertKey>,
}

impl EvictionPolicy for LfuPolicy {
    fn name(&self) -> &'static str {
        "lfu"
    }

    fn on_insert(&mut self, key: ExpertKey) {
        self.freq.insert(key, 1);
        self.arrival.push_back(key);
    }

    fn on_access(&mut self, key: ExpertKey) {
        *self.freq.entry(key).or_insert(0) += 1;
    }

    fn victim(&mut self, pinned: &HashSet<ExpertKey>) -> Option<ExpertKey> {
        let candidate = self
            .arrival
            .iter()
            .filter(|k| !pinned.contains(k))
            .min_by_key(|k| self.freq.get(k).copied().unwrap_or(0))
            .copied()?;
        self.on_evict(candidate);
        Some(candidate)
    }

    fn on_evict(&mut self, key: ExpertKey) {
        self.freq.remove(&key);
        if let Some(pos) = self.arrival.iter().position(|k| *k == key) {
            self.arrival.remove(pos);
        }
    }
}

// ---------------------------------------------------------------------------

/// Clock (second-chance FIFO).
#[derive(Default)]
pub struct ClockPolicy {
    ring: Vec<ExpertKey>,
    referenced: HashMap<ExpertKey, bool>,
    hand: usize,
}

impl EvictionPolicy for ClockPolicy {
    fn name(&self) -> &'static str {
        "clock"
    }

    fn on_insert(&mut self, key: ExpertKey) {
        self.ring.push(key);
        self.referenced.insert(key, false);
    }

    fn on_access(&mut self, key: ExpertKey) {
        if let Some(r) = self.referenced.get_mut(&key) {
            *r = true;
        }
    }

    fn victim(&mut self, pinned: &HashSet<ExpertKey>) -> Option<ExpertKey> {
        if self.ring.iter().all(|k| pinned.contains(k)) {
            return None;
        }
        // at most two sweeps: one clearing reference bits, one taking
        let max_steps = self.ring.len() * 2 + 1;
        for _ in 0..max_steps {
            if self.ring.is_empty() {
                return None;
            }
            self.hand %= self.ring.len();
            let key = self.ring[self.hand];
            if pinned.contains(&key) {
                self.hand += 1;
                continue;
            }
            let referenced = self.referenced.get(&key).copied().unwrap_or(false);
            if referenced {
                self.referenced.insert(key, false);
                self.hand += 1;
            } else {
                self.ring.remove(self.hand);
                self.referenced.remove(&key);
                return Some(key);
            }
        }
        None
    }

    fn on_evict(&mut self, key: ExpertKey) {
        if let Some(pos) = self.ring.iter().position(|k| *k == key) {
            if pos < self.hand {
                self.hand -= 1;
            }
            self.ring.remove(pos);
        }
        self.referenced.remove(&key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(e: usize) -> ExpertKey {
        ExpertKey { block: 1, expert: e }
    }

    #[test]
    fn fifo_order() {
        let mut p = FifoPolicy::default();
        p.on_insert(k(0));
        p.on_insert(k(1));
        p.on_insert(k(2));
        p.on_access(k(0)); // access must not change FIFO order
        let none = HashSet::new();
        assert_eq!(p.victim(&none), Some(k(0)));
        assert_eq!(p.victim(&none), Some(k(1)));
    }

    #[test]
    fn fifo_skips_pinned() {
        let mut p = FifoPolicy::default();
        p.on_insert(k(0));
        p.on_insert(k(1));
        let pinned: HashSet<_> = [k(0)].into_iter().collect();
        assert_eq!(p.victim(&pinned), Some(k(1)));
        assert_eq!(p.victim(&pinned), None);
    }

    #[test]
    fn lru_prefers_stale() {
        let mut p = LruPolicy::default();
        p.on_insert(k(0));
        p.on_insert(k(1));
        p.on_insert(k(2));
        p.on_access(k(0));
        let none = HashSet::new();
        assert_eq!(p.victim(&none), Some(k(1)));
    }

    #[test]
    fn lfu_prefers_cold() {
        let mut p = LfuPolicy::default();
        p.on_insert(k(0));
        p.on_insert(k(1));
        p.on_access(k(0));
        p.on_access(k(0));
        p.on_access(k(1));
        let none = HashSet::new();
        assert_eq!(p.victim(&none), Some(k(1)));
    }

    #[test]
    fn clock_second_chance() {
        let mut p = ClockPolicy::default();
        p.on_insert(k(0));
        p.on_insert(k(1));
        p.on_access(k(0)); // reference bit set -> second chance
        let none = HashSet::new();
        assert_eq!(p.victim(&none), Some(k(1)));
        // k0's bit was left set; next victim clears then takes it
        assert_eq!(p.victim(&none), Some(k(0)));
    }

    #[test]
    fn clock_all_pinned_returns_none() {
        let mut p = ClockPolicy::default();
        p.on_insert(k(0));
        let pinned: HashSet<_> = [k(0)].into_iter().collect();
        assert_eq!(p.victim(&pinned), None);
    }

    #[test]
    fn make_policy_names() {
        for name in ["fifo", "lru", "lfu", "clock"] {
            assert_eq!(make_policy(name).unwrap().name(), name);
        }
        assert!(make_policy("arc").is_err());
    }
}
