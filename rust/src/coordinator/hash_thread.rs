//! Hash-building thread (paper Fig 5, steps (1)-a..c): run the offline-
//! trained hash function on each incoming batch and enqueue the expert
//! hash table.
//!
//! `HashBuilder` wraps the `hash_L{L}` artifact — the LSTM + SparseMax
//! attention predictor lowered to HLO — with its weight literals cached,
//! so a build is a single PJRT dispatch.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::hash_table::HashTable;
use crate::runtime::{literal_i32, Executable, Literal, ModelBundle};

pub struct HashBuilder {
    exe: Arc<Executable>,
    /// hash-entry weight args in artifact order (after ids)
    weight_lits: Vec<Literal>,
    pub seq_len: usize,
    pub m: usize,
    pub k: usize,
}

impl HashBuilder {
    pub fn new(bundle: &ModelBundle, profile: &str) -> Result<Self> {
        let topo = &bundle.topology;
        let seq_len = topo.seq_len(profile)?;
        let exe = bundle.engine.load(&format!("hash_L{seq_len}"))?;
        let w = &bundle.weights;
        let d = topo.d_model;
        // arg order fixed by hashfn.make_entry_hash:
        // ids, tok, pos, compress_w, compress_b,
        // l0_wx, l0_wh, l0_b, l1_wx, l1_wh, l1_b, out_w, out_b
        let pos_full = w.f32_slice("embed.pos")?;
        let pos_lit =
            crate::runtime::literal_from_f32s(&[seq_len, d], &pos_full[..seq_len * d])?;
        let mut weight_lits = vec![w.literal("embed.tok")?, pos_lit];
        for name in [
            "hash.compress_w",
            "hash.compress_b",
            "hash.lstm.0.wx",
            "hash.lstm.0.wh",
            "hash.lstm.0.b",
            "hash.lstm.1.wx",
            "hash.lstm.1.wh",
            "hash.lstm.1.b",
            "hash.out_w",
            "hash.out_b",
        ] {
            weight_lits.push(w.literal(name)?);
        }
        Ok(HashBuilder {
            exe,
            weight_lits,
            seq_len,
            m: topo.num_moe_layers(),
            k: topo.hash.top_k,
        })
    }

    /// Run the predictor on one sentence (batch of 1, padded ids).
    pub fn build(&self, batch_id: u64, ids: &[i32]) -> Result<HashTable> {
        let t0 = Instant::now();
        let ids_lit = literal_i32(&[1, self.seq_len], ids)?;
        let mut args: Vec<&Literal> = Vec::with_capacity(1 + self.weight_lits.len());
        args.push(&ids_lit);
        args.extend(self.weight_lits.iter());
        let out = self.exe.run(&args)?;
        let build_secs = t0.elapsed().as_secs_f64();
        HashTable::from_literals(
            batch_id,
            self.seq_len,
            self.m,
            self.k,
            &out[0],
            &out[1],
            build_secs,
        )
    }
}
