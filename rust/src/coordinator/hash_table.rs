//! The expert hash table H_i (paper Fig 5): per-token, per-MoE-layer
//! predicted expert ids and scaling factors, produced by the
//! hash-building thread and consumed by the inference thread.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

use crate::runtime::{to_f32_vec, to_i32_vec, Literal};

#[derive(Debug, Clone)]
pub struct HashTable {
    pub batch_id: u64,
    pub seq_len: usize,
    /// number of MoE layers (M)
    pub m: usize,
    /// predictions exported per token per layer (K)
    pub k: usize,
    /// [L, M, K] row-major
    pub idx: Vec<i32>,
    /// [L, M, K] student softmax probabilities (approximate alphas)
    pub alpha: Vec<f32>,
    /// wall time the hash-building thread spent producing this table
    pub build_secs: f64,
}

impl HashTable {
    pub fn new(
        batch_id: u64,
        seq_len: usize,
        m: usize,
        k: usize,
        idx: Vec<i32>,
        alpha: Vec<f32>,
        build_secs: f64,
    ) -> Result<Self> {
        if idx.len() != seq_len * m * k || alpha.len() != seq_len * m * k {
            bail!(
                "hash table size mismatch: idx {} alpha {} expected {}",
                idx.len(),
                alpha.len(),
                seq_len * m * k
            );
        }
        Ok(HashTable { batch_id, seq_len, m, k, idx, alpha, build_secs })
    }

    /// Build from the hash artifact's output literals
    /// (idx i32 [1,L,M,K], alpha f32 [1,L,M,K]).
    pub fn from_literals(
        batch_id: u64,
        seq_len: usize,
        m: usize,
        k: usize,
        idx_lit: &Literal,
        alpha_lit: &Literal,
        build_secs: f64,
    ) -> Result<Self> {
        Self::new(
            batch_id,
            seq_len,
            m,
            k,
            to_i32_vec(idx_lit)?,
            to_f32_vec(alpha_lit)?,
            build_secs,
        )
    }

    #[inline]
    fn at(&self, token: usize, layer: usize, rank: usize) -> usize {
        debug_assert!(token < self.seq_len && layer < self.m && rank < self.k);
        (token * self.m + layer) * self.k + rank
    }

    /// Predicted expert for `token` at MoE layer `layer`, rank `rank`.
    pub fn expert_at(&self, token: usize, layer: usize, rank: usize) -> usize {
        self.idx[self.at(token, layer, rank)] as usize
    }

    /// Predicted scaling factor at the same position.
    pub fn alpha_at(&self, token: usize, layer: usize, rank: usize) -> f32 {
        self.alpha[self.at(token, layer, rank)]
    }

    /// Unique experts predicted active at `layer` over masked tokens,
    /// considering the first `k_used` ranks — the prefetch set.
    pub fn predicted_experts(&self, layer: usize, k_used: usize, mask: &[f32]) -> Vec<usize> {
        let mut set = BTreeSet::new();
        for t in 0..self.seq_len {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for r in 0..k_used.min(self.k) {
                set.insert(self.expert_at(t, layer, r));
            }
        }
        set.into_iter().collect()
    }

    /// Sentence-level activation sparsity at `layer` (Fig 4): fraction of
    /// the expert pool NOT activated.
    pub fn idle_ratio(&self, layer: usize, num_experts: usize, mask: &[f32]) -> f64 {
        let active = self.predicted_experts(layer, 1, mask).len();
        1.0 - active as f64 / num_experts as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> HashTable {
        // L=3, M=2, K=2
        let idx = vec![
            0, 1, /* t0 l0 */ 2, 3, /* t0 l1 */
            0, 2, /* t1 l0 */ 2, 0, /* t1 l1 */
            5, 1, /* t2 l0 */ 3, 2, /* t2 l1 */
        ];
        let alpha = vec![
            0.9, 0.1, 0.8, 0.2, //
            0.7, 0.3, 0.6, 0.4, //
            0.5, 0.5, 0.9, 0.1,
        ];
        HashTable::new(7, 3, 2, 2, idx, alpha, 0.001).unwrap()
    }

    #[test]
    fn indexing() {
        let t = table();
        assert_eq!(t.expert_at(0, 0, 0), 0);
        assert_eq!(t.expert_at(0, 1, 1), 3);
        assert_eq!(t.expert_at(2, 0, 0), 5);
        assert!((t.alpha_at(1, 1, 0) - 0.6).abs() < 1e-6);
    }

    #[test]
    fn predicted_set_respects_mask_and_k() {
        let t = table();
        let mask = vec![1.0, 1.0, 0.0]; // token 2 is padding
        assert_eq!(t.predicted_experts(0, 1, &mask), vec![0]);
        assert_eq!(t.predicted_experts(0, 2, &mask), vec![0, 1, 2]);
        let full = vec![1.0, 1.0, 1.0];
        assert_eq!(t.predicted_experts(0, 1, &full), vec![0, 5]);
    }

    #[test]
    fn idle_ratio_matches_active_count() {
        let t = table();
        let full = vec![1.0, 1.0, 1.0];
        // layer 1, top-1 experts: {2, 2, 3} -> 2 active of 8
        let r = t.idle_ratio(1, 8, &full);
        assert!((r - 0.75).abs() < 1e-9);
    }

    #[test]
    fn size_validation() {
        assert!(HashTable::new(0, 3, 2, 2, vec![0; 11], vec![0.0; 12], 0.0).is_err());
    }
}
