//! Open-loop scheduling: replay a timed arrival trace against the SiDA
//! pipeline and measure queueing delay on top of service latency.
//!
//! The closed-loop path (`Pipeline::serve`) measures capacity; this
//! scheduler measures the latency a *load* produces: requests arrive by
//! wall clock (Poisson, bursty, diurnal, or recorded timestamps), wait
//! in the bounded admission queue (`Batcher`), and are served in
//! arrival order.  The reported per-request latency = queueing + hash
//! wait + inference — what a client of the TCP front-end would observe.
//!
//! SLO handling (see `coordinator::batcher` for the mechanisms):
//!
//! * admission control — a [`QueueDelayEstimator`] fed by served
//!   requests predicts the queue delay each arrival would see; an
//!   interactive request whose prediction already exceeds its deadline
//!   is rejected at arrival (`rejected_slo`), a full queue rejects
//!   anything (`rejected`);
//! * shedding — an admitted interactive request whose deadline is
//!   already blown when it reaches the head of the queue is dropped
//!   (`shed`) instead of served late;
//! * accounting — every trace request ends in exactly one bucket:
//!   `served + shed + rejected + rejected_slo == trace.len()`, and
//!   served requests land in per-class latency histograms.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, QueueDelayEstimator};
use crate::coordinator::hash_table::HashTable;
use crate::coordinator::pipeline::{Pipeline, RequestResult, ServeOutcome};
use crate::metrics::ServeStats;
use crate::model::{ForwardHooks, ForwardOptions};
use crate::obs::trace::{self, ArgValue};
use crate::workload::Request;

pub struct OpenLoopReport {
    pub outcome: ServeOutcome,
    /// mean time served requests spent waiting in the admission queue
    pub mean_queueing_secs: f64,
    /// arrivals dropped because the queue was physically full
    pub rejected: u64,
    /// arrivals rejected by admission control (predicted queue delay
    /// already past the class deadline)
    pub rejected_slo: u64,
    /// admitted interactive requests dropped at dequeue with a blown
    /// deadline
    pub shed: u64,
}

/// Replay an arrival-stamped trace.  Requests whose `arrival` has not
/// come yet are waited for; the admission queue is bounded at
/// `queue_cap`, overflowing or SLO-doomed arrivals are rejected, and
/// interactive requests whose deadline is blown before service starts
/// are shed — all counted in the report.
pub fn replay_open_loop(
    pipeline: &Pipeline,
    trace: &[Request],
    queue_cap: usize,
) -> Result<OpenLoopReport> {
    let builder = crate::coordinator::hash_thread::HashBuilder::new(
        &pipeline.bundle,
        &pipeline.profile,
    )?;
    let mut batcher = Batcher::new(queue_cap);
    let mut estimator = QueueDelayEstimator::default();
    let mut pending: Vec<Request> = trace.to_vec();
    pending.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let interactive_offered =
        pending.iter().filter(|r| r.class.is_interactive()).count() as u64;
    // cluster mode: data-aware placement from the trace's own
    // predictions before replay starts (no-op on a single device)
    pipeline.plan_cluster_placement(&pending)?;

    let opts = ForwardOptions {
        want_cls: pipeline.cfg.want_cls,
        want_lm: pipeline.cfg.want_lm,
        ..Default::default()
    };
    let t_start = Instant::now();
    let mut stats = ServeStats::default();
    let mut per_request = Vec::new();
    let mut queueing_total = 0.0;
    let mut rejected_slo = 0u64;
    let mut shed = 0u64;

    while !pending.is_empty() || !batcher.is_empty() {
        let now = t_start.elapsed().as_secs_f64();
        let (_, slo_rej) = batcher.admit_due_controlled(&mut pending, now, &estimator);
        rejected_slo += slo_rej;
        let Some(req) = batcher.next() else {
            // idle until the next arrival
            if let Some(next) = pending.first() {
                let wait = (next.arrival - now).max(0.0);
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(0.05)));
            }
            continue;
        };
        let dequeue_at = t_start.elapsed().as_secs_f64();
        let wait = (dequeue_at - req.arrival).max(0.0);
        if req.class.deadline_secs().is_some_and(|d| wait > d) {
            // already past deadline: serving it cannot meet the SLO and
            // only delays the requests queued behind it
            shed += 1;
            if trace::enabled() {
                trace::instant(
                    "shed",
                    "queue",
                    trace::host_pid(),
                    vec![
                        ("request", ArgValue::U(req.id)),
                        ("wait_secs", ArgValue::F(wait)),
                    ],
                );
            }
            continue;
        }
        queueing_total += wait;
        let t_req = trace::begin();
        if trace::enabled() {
            // the queue wait already elapsed on the modeled arrival
            // timeline; replay it as a span ending now
            let wait_us = (wait * 1e6) as u64;
            trace::complete_at(
                "queue_wait",
                "queue",
                trace::host_pid(),
                t_req.saturating_sub(wait_us),
                wait_us,
                vec![
                    ("request", ArgValue::U(req.id)),
                    ("secs", ArgValue::F(wait)),
                ],
            );
            trace::flow('s', req.id, trace::host_pid());
        }

        // synchronous hash build + forward (the pipelined variant is
        // Pipeline::serve; open-loop measures client-visible latency).
        // `provider()` keeps this path cluster-aware: with
        // `cfg.devices > 1` the forward fans out across the fleet.
        let t_hash = trace::begin();
        let table = builder.build(req.id, &req.ids)?;
        if trace::enabled() {
            trace::complete(
                "hash_build",
                "hash",
                trace::host_pid(),
                t_hash,
                vec![
                    ("request", ArgValue::U(req.id)),
                    ("secs", ArgValue::F(table.build_secs)),
                ],
            );
        }
        // one batch tick per served forward: the fault timeline advances
        // and failures/recoveries replan before this request is routed
        if let Some(router) = &pipeline.cluster {
            router.advance_batch(&pipeline.bundle);
        }
        let trace_ids = [req.id];
        let t_service = trace::begin();
        let t0 = Instant::now();
        let mut provider = pipeline.provider();
        // with prefetch on, the forward runs gated: the depth-window
        // warmer stages up to `cfg.prefetch_depth` layers ahead of
        // compute through the shared bandwidth window, so SLO sweeps
        // can trade prefetch depth against tail latency.  Gating only
        // reorders non-blocking staging — outputs are bit-identical to
        // the ungated forward.
        let out = if pipeline.cfg.prefetch {
            let mask = req.mask();
            let pairs: Vec<(&HashTable, &[f32])> = vec![(&table, &mask[..])];
            pipeline.forward_gated(&pairs, &trace_ids, |hooks| {
                pipeline.runner.forward_hooked(
                    &req.ids,
                    Some((&table, pipeline.cfg.k_used)),
                    &mut provider,
                    opts,
                    hooks,
                )
            })?
        } else {
            pipeline.runner.forward_hooked(
                &req.ids,
                Some((&table, pipeline.cfg.k_used)),
                &mut provider,
                opts,
                ForwardHooks { layer_gate: None, trace_ids: Some(&trace_ids) },
            )?
        };
        let service = t0.elapsed().as_secs_f64();
        estimator.observe(table.build_secs + service);
        let latency = wait + table.build_secs + service;
        if trace::enabled() {
            // the flow end binds to the enclosing slice (`bp:"e"`), so
            // emit it before the service span closes
            trace::flow('f', req.id, trace::host_pid());
            trace::complete(
                "service",
                "serve",
                trace::host_pid(),
                t_service,
                vec![
                    ("request", ArgValue::U(req.id)),
                    ("secs", ArgValue::F(service)),
                ],
            );
            // exact f64 components ride along so the trace reconciles
            // with the reported latency bit-for-bit (tests/obs.rs)
            trace::instant(
                "request_done",
                "serve",
                trace::host_pid(),
                vec![
                    ("request", ArgValue::U(req.id)),
                    ("latency_secs", ArgValue::F(latency)),
                    ("wait_secs", ArgValue::F(wait)),
                    ("hash_secs", ArgValue::F(table.build_secs)),
                    ("service_secs", ArgValue::F(service)),
                ],
            );
        }
        stats.latency.record(latency);
        stats.record_class(&req.class, latency);
        stats.phases.add(&out.times);
        stats.requests += 1;
        stats.hash_build_secs += table.build_secs;
        per_request.push(RequestResult {
            id: req.id,
            latency_secs: latency,
            cls_pred: out.cls_logits.as_ref().map(|v| crate::coordinator::argmax(v)),
            lm_nll: None,
            lm_tokens: None,
            n_tokens: req.n_tokens,
        });
    }
    stats.wall_secs = t_start.elapsed().as_secs_f64();
    pipeline.collect_serving_stats(&mut stats);
    stats.shed = shed;
    stats.rejected = batcher.rejected;
    stats.rejected_slo = rejected_slo;
    // denominator over *offered* interactive traffic: shed and rejected
    // interactive requests count against attainment, not just served
    // ones (record_class counted the served subset; override exactly)
    stats.interactive_offered = interactive_offered;
    let n = stats.requests.max(1) as f64;
    Ok(OpenLoopReport {
        outcome: ServeOutcome { stats, per_request },
        mean_queueing_secs: queueing_total / n,
        rejected: batcher.rejected,
        rejected_slo,
        shed,
    })
}
