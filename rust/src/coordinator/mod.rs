//! The SiDA coordinator — the paper's system contribution (Fig 5,
//! Algorithm 1): a hash-building thread that predicts expert activation
//! ahead of time, a bounded hash-table queue, and an inference thread
//! that serves with routers replaced by hash tables and experts moved
//! between host RAM and a budgeted device tier.

pub mod batcher;
pub mod hash_table;
pub mod hash_thread;
pub mod pipeline;
pub mod scheduler;

pub use batcher::{AdmitOutcome, Batcher};
pub use scheduler::{replay_open_loop, OpenLoopReport};
pub use hash_table::HashTable;
pub use hash_thread::HashBuilder;
pub use pipeline::{argmax, Pipeline, PipelineConfig, RequestResult, ServeOutcome};
