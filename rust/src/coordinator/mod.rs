//! The SiDA coordinator — the paper's system contribution (Fig 5,
//! Algorithm 1): a hash-building thread that predicts expert activation
//! ahead of time, a bounded hash-table queue, and an inference thread
//! that serves with routers replaced by hash tables and experts moved
//! between host RAM and a budgeted device tier.
//!
//! Serving modes:
//!
//! * **batch-1** ([`Pipeline::serve`] with the default
//!   `max_batch = 1`) — the paper's evaluation setting, one sentence
//!   per forward.
//! * **cross-request batched** (`max_batch > 1`, or
//!   [`Pipeline::serve_batched`] directly) — a [`BatchFormer`]
//!   coalesces requests into multi-sentence batches, the prefetch
//!   stage warms the **batch-union** expert set, and every MoE layer
//!   issues one expert invocation per activated expert per batch.
//!   Outputs are bit-identical to batch-1 serving; expert traffic is
//!   amortized across the batch.
//!
//! The open-loop [`scheduler`](crate::coordinator::scheduler) replays
//! timed arrival traces to measure queueing on top of service latency.

pub mod batcher;
pub mod hash_table;
pub mod hash_thread;
pub mod pipeline;
pub mod scheduler;

pub use batcher::{
    AdmitOutcome, BatchFormer, BatchPolicy, Batcher, FormedBatch, QueueDelayEstimator,
};
pub use scheduler::{replay_open_loop, OpenLoopReport};
pub use hash_table::HashTable;
pub use hash_thread::HashBuilder;
pub use pipeline::{argmax, Pipeline, PipelineConfig, RequestResult, ServeOutcome};
