//! Request admission + cross-request batch formation.
//!
//! Two admission structures live here:
//!
//! * [`Batcher`] — the bounded FIFO the paper's batch-1 evaluation uses
//!   (one sentence per forward): arrival-time admission for open-loop
//!   traces, FIFO ordering, and bounded-queue backpressure between the
//!   front-end and the pipeline.
//! * [`BatchFormer`] — the cross-request batch former behind the TCP
//!   server and the batched pipeline: it coalesces requests *from all
//!   connections* into multi-sentence batches, cutting a batch when it
//!   reaches [`BatchPolicy::max_batch`] requests or when the oldest
//!   pending request has waited [`BatchPolicy::max_delay_secs`]
//!   (size/deadline-based forming).  Requests are grouped by profile —
//!   only sentences padded to the same sequence length can share one
//!   forward pass — and FIFO order is preserved within a batch.
//!
//! Time is passed in explicitly (monotonic seconds from any epoch), so
//! deadline behavior is deterministic under test.
//!
//! ```
//! use sida_moe::coordinator::{BatchFormer, BatchPolicy};
//!
//! let policy = BatchPolicy { max_batch: 4, max_delay_secs: 0.010, capacity: 64 };
//! let mut former: BatchFormer<()> = BatchFormer::new(policy);
//! let bundle = sida_moe::testkit::tiny_bundle();
//! for (i, req) in sida_moe::testkit::tiny_trace(&bundle, 2, 0).into_iter().enumerate() {
//!     former.admit(req, (), i as f64 * 0.001);
//! }
//! assert!(former.try_form(0.002).is_none()); // not full, deadline not hit
//! let batch = former.try_form(0.020).unwrap(); // deadline fired: partial batch
//! assert_eq!(batch.requests.len(), 2);
//! ```

use std::collections::VecDeque;

use crate::workload::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    Admitted,
    /// queue full — caller should retry/backpressure
    Rejected,
}

/// Bounded FIFO admission queue (batch size 1 per the paper's setting).
pub struct Batcher {
    queue: VecDeque<Request>,
    capacity: usize,
    pub admitted: u64,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(capacity: usize) -> Self {
        Batcher { queue: VecDeque::new(), capacity, admitted: 0, rejected: 0 }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn admit(&mut self, req: Request) -> AdmitOutcome {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return AdmitOutcome::Rejected;
        }
        self.admitted += 1;
        self.queue.push_back(req);
        AdmitOutcome::Admitted
    }

    /// Next batch (size 1 per the paper's setting).
    pub fn next(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Requests whose arrival time has passed, in arrival order —
    /// open-loop trace replay.
    pub fn admit_due(&mut self, trace: &mut Vec<Request>, now: f64) -> usize {
        let mut n = 0;
        while let Some(first) = trace.first() {
            if first.arrival <= now {
                let req = trace.remove(0);
                if self.admit(req) == AdmitOutcome::Rejected {
                    break;
                }
                n += 1;
            } else {
                break;
            }
        }
        n
    }
}

/// When the [`BatchFormer`] cuts a batch.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// cut as soon as this many requests are pending (>= 1)
    pub max_batch: usize,
    /// cut a partial batch once the oldest pending request has waited
    /// this long — bounds the batching delay a lone request pays
    pub max_delay_secs: f64,
    /// admission-queue bound; requests beyond it are rejected
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_delay_secs: 0.005, capacity: 256 }
    }
}

struct Pending<T> {
    req: Request,
    payload: T,
    enqueued_at: f64,
}

/// A formed multi-request batch.
pub struct FormedBatch<T> {
    /// the coalesced requests with their payloads, FIFO order preserved
    pub requests: Vec<(Request, T)>,
    /// per-request seconds spent waiting for the batch to form, aligned
    /// with `requests`
    pub batching_delays: Vec<f64>,
    /// the `now` at which the batch was cut
    pub formed_at: f64,
}

impl<T> FormedBatch<T> {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Size/deadline-based batch former over a bounded admission queue.
///
/// `T` is an opaque per-request payload carried through forming (the
/// TCP server uses it for the reply channel; the pipeline uses the
/// request's hash table).
pub struct BatchFormer<T> {
    queue: VecDeque<Pending<T>>,
    policy: BatchPolicy,
    pub admitted: u64,
    pub rejected: u64,
    pub batches_formed: u64,
    pub batched_requests: u64,
}

impl<T> BatchFormer<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        let policy = BatchPolicy { max_batch: policy.max_batch.max(1), ..policy };
        BatchFormer {
            queue: VecDeque::new(),
            policy,
            admitted: 0,
            rejected: 0,
            batches_formed: 0,
            batched_requests: 0,
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Admit one request (`now` in monotonic seconds).  Rejected when
    /// the queue holds `capacity` pending requests.
    pub fn admit(&mut self, req: Request, payload: T, now: f64) -> AdmitOutcome {
        if self.queue.len() >= self.policy.capacity {
            self.rejected += 1;
            return AdmitOutcome::Rejected;
        }
        self.admitted += 1;
        self.queue.push_back(Pending { req, payload, enqueued_at: now });
        AdmitOutcome::Admitted
    }

    /// Whether a batch would be cut at `now`: enough pending requests,
    /// or the oldest has exceeded the deadline.
    pub fn ready(&self, now: f64) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        self.queue
            .front()
            .is_some_and(|p| now - p.enqueued_at >= self.policy.max_delay_secs)
    }

    /// When the oldest pending request's deadline fires (absolute time
    /// on the caller's clock), if anything is pending — what a worker
    /// should sleep until.
    pub fn next_deadline(&self) -> Option<f64> {
        self.queue
            .front()
            .map(|p| p.enqueued_at + self.policy.max_delay_secs)
    }

    /// Cut a batch if the policy says so (size reached or deadline
    /// fired), else `None`.
    pub fn try_form(&mut self, now: f64) -> Option<FormedBatch<T>> {
        if !self.ready(now) {
            return None;
        }
        self.form(now)
    }

    /// Cut whatever is pending regardless of the policy (shutdown
    /// drain); still bounded by `max_batch` and profile grouping, so a
    /// long backlog drains as several batches.
    pub fn form_now(&mut self, now: f64) -> Option<FormedBatch<T>> {
        self.form(now)
    }

    fn form(&mut self, now: f64) -> Option<FormedBatch<T>> {
        let first_len = self.queue.front()?.req.ids.len();
        let mut requests = Vec::new();
        let mut batching_delays = Vec::new();
        while requests.len() < self.policy.max_batch {
            // group-by-profile: only same-seq-len sentences can share a
            // forward pass; a different profile starts the next batch
            match self.queue.front() {
                Some(p) if p.req.ids.len() == first_len => {
                    let p = self.queue.pop_front().unwrap();
                    batching_delays.push((now - p.enqueued_at).max(0.0));
                    requests.push((p.req, p.payload));
                }
                _ => break,
            }
        }
        self.batches_formed += 1;
        self.batched_requests += requests.len() as u64;
        Some(FormedBatch { requests, batching_delays, formed_at: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, ids: vec![1, 5, 2, 0], n_tokens: 3, label: 0, arrival }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(10);
        for i in 0..5 {
            assert_eq!(b.admit(req(i, 0.0)), AdmitOutcome::Admitted);
        }
        for i in 0..5 {
            assert_eq!(b.next().unwrap().id, i);
        }
        assert!(b.next().is_none());
    }

    #[test]
    fn capacity_backpressure() {
        let mut b = Batcher::new(2);
        assert_eq!(b.admit(req(0, 0.0)), AdmitOutcome::Admitted);
        assert_eq!(b.admit(req(1, 0.0)), AdmitOutcome::Admitted);
        assert_eq!(b.admit(req(2, 0.0)), AdmitOutcome::Rejected);
        assert_eq!(b.rejected, 1);
        b.next();
        assert_eq!(b.admit(req(2, 0.0)), AdmitOutcome::Admitted);
    }

    #[test]
    fn admit_due_respects_time() {
        let mut b = Batcher::new(10);
        let mut trace = vec![req(0, 0.1), req(1, 0.5), req(2, 2.0)];
        assert_eq!(b.admit_due(&mut trace, 1.0), 2);
        assert_eq!(trace.len(), 1);
        assert_eq!(b.len(), 2);
        assert_eq!(b.admit_due(&mut trace, 3.0), 1);
        assert!(trace.is_empty());
    }

    #[test]
    fn exactly_once_delivery() {
        let mut b = Batcher::new(100);
        let mut seen = std::collections::HashSet::new();
        for i in 0..50 {
            b.admit(req(i, 0.0));
        }
        while let Some(r) = b.next() {
            assert!(seen.insert(r.id), "duplicate {}", r.id);
        }
        assert_eq!(seen.len(), 50);
    }

    fn policy(max_batch: usize, delay: f64, cap: usize) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay_secs: delay, capacity: cap }
    }

    #[test]
    fn size_trigger_forms_full_batch() {
        let mut f: BatchFormer<u32> = BatchFormer::new(policy(3, 10.0, 64));
        for i in 0..5 {
            assert_eq!(f.admit(req(i, 0.0), i as u32, 0.0), AdmitOutcome::Admitted);
        }
        let b = f.try_form(0.0).expect("size reached");
        assert_eq!(b.len(), 3);
        assert_eq!(b.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.requests.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 1, 2]);
        // two left: below size, before deadline -> no batch yet
        assert!(f.try_form(0.0).is_none());
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn deadline_fires_with_partial_batch() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(8, 0.005, 64));
        f.admit(req(0, 0.0), (), 1.000);
        f.admit(req(1, 0.0), (), 1.002);
        assert!(!f.ready(1.004));
        assert!(f.try_form(1.004).is_none());
        assert!((f.next_deadline().unwrap() - 1.005).abs() < 1e-9);
        let b = f.try_form(1.006).expect("deadline fired");
        assert_eq!(b.len(), 2);
        // batching delay measured from each request's own admission
        assert!((b.batching_delays[0] - 0.006).abs() < 1e-9);
        assert!((b.batching_delays[1] - 0.004).abs() < 1e-9);
        assert!(f.is_empty());
        assert_eq!(f.batches_formed, 1);
        assert_eq!(f.batched_requests, 2);
    }

    #[test]
    fn rejection_accounting_under_overflow() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(4, 1.0, 2));
        assert_eq!(f.admit(req(0, 0.0), (), 0.0), AdmitOutcome::Admitted);
        assert_eq!(f.admit(req(1, 0.0), (), 0.0), AdmitOutcome::Admitted);
        assert_eq!(f.admit(req(2, 0.0), (), 0.0), AdmitOutcome::Rejected);
        assert_eq!(f.admit(req(3, 0.0), (), 0.0), AdmitOutcome::Rejected);
        assert_eq!((f.admitted, f.rejected), (2, 2));
        // draining frees capacity again
        let b = f.form_now(0.0).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(f.admit(req(4, 0.0), (), 0.0), AdmitOutcome::Admitted);
    }

    #[test]
    fn profile_grouping_splits_mixed_seq_lens() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(8, 10.0, 64));
        let short = |id| Request { id, ids: vec![1, 5, 2, 0], n_tokens: 3, label: 0, arrival: 0.0 };
        let long = |id| Request { id, ids: vec![1, 5, 5, 5, 5, 5, 2, 0], n_tokens: 7, label: 0, arrival: 0.0 };
        f.admit(short(0), (), 0.0);
        f.admit(short(1), (), 0.0);
        f.admit(long(2), (), 0.0);
        f.admit(long(3), (), 0.0);
        let b1 = f.form_now(0.0).unwrap();
        assert_eq!(b1.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = f.form_now(0.0).unwrap();
        assert_eq!(b2.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(f.form_now(0.0).is_none());
    }

    #[test]
    fn form_now_on_empty_is_none() {
        let mut f: BatchFormer<()> = BatchFormer::new(BatchPolicy::default());
        assert!(f.form_now(0.0).is_none());
        assert_eq!(f.batches_formed, 0);
    }
}
