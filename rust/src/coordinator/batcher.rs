//! Request admission + batching.
//!
//! The paper evaluates at batch size 1 (one sentence per forward), so a
//! "batch" here is a single request; what the batcher contributes is
//! arrival-time admission (open-loop traces), FIFO ordering, and
//! bounded-queue backpressure between the front-end and the pipeline.
//! It also exposes the length-bucketing hook a >1 batch-size deployment
//! would use (group-by-profile), exercised by tests.

use std::collections::VecDeque;

use crate::workload::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    Admitted,
    /// queue full — caller should retry/backpressure
    Rejected,
}

/// Bounded FIFO admission queue.
pub struct Batcher {
    queue: VecDeque<Request>,
    capacity: usize,
    pub admitted: u64,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(capacity: usize) -> Self {
        Batcher { queue: VecDeque::new(), capacity, admitted: 0, rejected: 0 }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn admit(&mut self, req: Request) -> AdmitOutcome {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return AdmitOutcome::Rejected;
        }
        self.admitted += 1;
        self.queue.push_back(req);
        AdmitOutcome::Admitted
    }

    /// Next batch (size 1 per the paper's setting).
    pub fn next(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Requests whose arrival time has passed, in arrival order —
    /// open-loop trace replay.
    pub fn admit_due(&mut self, trace: &mut Vec<Request>, now: f64) -> usize {
        let mut n = 0;
        while let Some(first) = trace.first() {
            if first.arrival <= now {
                let req = trace.remove(0);
                if self.admit(req) == AdmitOutcome::Rejected {
                    break;
                }
                n += 1;
            } else {
                break;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, ids: vec![1, 5, 2, 0], n_tokens: 3, label: 0, arrival }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(10);
        for i in 0..5 {
            assert_eq!(b.admit(req(i, 0.0)), AdmitOutcome::Admitted);
        }
        for i in 0..5 {
            assert_eq!(b.next().unwrap().id, i);
        }
        assert!(b.next().is_none());
    }

    #[test]
    fn capacity_backpressure() {
        let mut b = Batcher::new(2);
        assert_eq!(b.admit(req(0, 0.0)), AdmitOutcome::Admitted);
        assert_eq!(b.admit(req(1, 0.0)), AdmitOutcome::Admitted);
        assert_eq!(b.admit(req(2, 0.0)), AdmitOutcome::Rejected);
        assert_eq!(b.rejected, 1);
        b.next();
        assert_eq!(b.admit(req(2, 0.0)), AdmitOutcome::Admitted);
    }

    #[test]
    fn admit_due_respects_time() {
        let mut b = Batcher::new(10);
        let mut trace = vec![req(0, 0.1), req(1, 0.5), req(2, 2.0)];
        assert_eq!(b.admit_due(&mut trace, 1.0), 2);
        assert_eq!(trace.len(), 1);
        assert_eq!(b.len(), 2);
        assert_eq!(b.admit_due(&mut trace, 3.0), 1);
        assert!(trace.is_empty());
    }

    #[test]
    fn exactly_once_delivery() {
        let mut b = Batcher::new(100);
        let mut seen = std::collections::HashSet::new();
        for i in 0..50 {
            b.admit(req(i, 0.0));
        }
        while let Some(r) = b.next() {
            assert!(seen.insert(r.id), "duplicate {}", r.id);
        }
        assert_eq!(seen.len(), 50);
    }
}
