//! Request admission + cross-request batch formation.
//!
//! Two admission structures live here:
//!
//! * [`Batcher`] — the bounded FIFO the paper's batch-1 evaluation uses
//!   (one sentence per forward): arrival-time admission for open-loop
//!   traces, FIFO ordering, and bounded-queue backpressure between the
//!   front-end and the pipeline.
//! * [`BatchFormer`] — the cross-request batch former behind the TCP
//!   server and the batched pipeline: it coalesces requests *from all
//!   connections* into multi-sentence batches, cutting a batch when it
//!   reaches [`BatchPolicy::max_batch`] requests or when the oldest
//!   pending request has waited [`BatchPolicy::max_delay_secs`]
//!   (size/deadline-based forming).  Requests are grouped by profile —
//!   only sentences padded to the same sequence length can share one
//!   forward pass — and FIFO order is preserved within a lane.
//!
//! The former keeps two lanes keyed on [`SloClass`]: interactive
//! requests cut batches first (they are latency-bound), and any whose
//! deadline is already blown at cut time are shed into
//! [`FormedBatch::shed`] instead of wasting a batch slot.  Batch-lane
//! requests cannot starve: after [`BatchPolicy::batch_aging_cuts`]
//! consecutive cuts that served no batch-lane request while some were
//! pending, the batch lane leads the next cut (aging credit).
//!
//! [`QueueDelayEstimator`] closes the admission loop: an EWMA of recent
//! per-request service seconds times the current queue depth predicts
//! the queue delay a new arrival would see, and interactive requests
//! whose deadline that prediction already exceeds are rejected at
//! submit time rather than shed later.
//!
//! Time is passed in explicitly (monotonic seconds from any epoch), so
//! deadline behavior is deterministic under test.
//!
//! ```
//! use sida_moe::coordinator::{BatchFormer, BatchPolicy};
//!
//! let policy = BatchPolicy {
//!     max_batch: 4,
//!     max_delay_secs: 0.010,
//!     capacity: 64,
//!     ..Default::default()
//! };
//! let mut former: BatchFormer<()> = BatchFormer::new(policy);
//! let bundle = sida_moe::testkit::tiny_bundle();
//! for (i, req) in sida_moe::testkit::tiny_trace(&bundle, 2, 0).into_iter().enumerate() {
//!     former.admit(req, (), i as f64 * 0.001);
//! }
//! assert!(former.try_form(0.002).is_none()); // not full, deadline not hit
//! let batch = former.try_form(0.020).unwrap(); // deadline fired: partial batch
//! assert_eq!(batch.requests.len(), 2);
//! ```

use std::collections::VecDeque;

use crate::workload::{Request, SloClass};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitOutcome {
    Admitted,
    /// queue full — caller should retry/backpressure
    Rejected,
}

/// Bounded FIFO admission queue (batch size 1 per the paper's setting).
pub struct Batcher {
    queue: VecDeque<Request>,
    capacity: usize,
    pub admitted: u64,
    pub rejected: u64,
}

impl Batcher {
    pub fn new(capacity: usize) -> Self {
        Batcher { queue: VecDeque::new(), capacity, admitted: 0, rejected: 0 }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn admit(&mut self, req: Request) -> AdmitOutcome {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return AdmitOutcome::Rejected;
        }
        self.admitted += 1;
        self.queue.push_back(req);
        AdmitOutcome::Admitted
    }

    /// Next batch (size 1 per the paper's setting).
    pub fn next(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Requests whose arrival time has passed, in arrival order —
    /// open-loop trace replay.  Every due request is either admitted or
    /// shed (counted in `rejected`): an open-loop client does not
    /// politely retry, so a full queue drops the arrival rather than
    /// silently deferring it — and earlier versions of this loop leaked
    /// the popped head on rejection.  Returns the number admitted.
    pub fn admit_due(&mut self, trace: &mut Vec<Request>, now: f64) -> usize {
        let due = trace.iter().take_while(|r| r.arrival <= now).count();
        let mut n = 0;
        for req in trace.drain(..due) {
            if self.admit(req) == AdmitOutcome::Admitted {
                n += 1;
            }
        }
        n
    }

    /// [`admit_due`](Self::admit_due) with SLO admission control: an
    /// interactive request whose predicted queue delay already exceeds
    /// its deadline is rejected up front (cheaper than serving it past
    /// its SLO or shedding it at cut time).  Returns
    /// `(admitted, slo_rejected)`; capacity rejects still land in
    /// `self.rejected`.
    pub fn admit_due_controlled(
        &mut self,
        trace: &mut Vec<Request>,
        now: f64,
        estimator: &QueueDelayEstimator,
    ) -> (usize, u64) {
        let due = trace.iter().take_while(|r| r.arrival <= now).count();
        let mut admitted = 0;
        let mut slo_rejected = 0u64;
        for req in trace.drain(..due) {
            if !estimator.admits(&req.class, self.queue.len()) {
                slo_rejected += 1;
                continue;
            }
            if self.admit(req) == AdmitOutcome::Admitted {
                admitted += 1;
            }
        }
        (admitted, slo_rejected)
    }
}

/// Predicts the queue delay a newly-arrived request would experience,
/// from an EWMA of recent per-request service seconds multiplied by the
/// current queue depth.  Before the first observation it predicts zero
/// delay, i.e. admits everything — the estimator must learn from served
/// traffic before it can reject any.
#[derive(Debug, Clone, Default)]
pub struct QueueDelayEstimator {
    ewma_service_secs: f64,
    observations: u64,
}

impl QueueDelayEstimator {
    const ALPHA: f64 = 0.2;

    /// Feed one per-request service-time observation (for a batch of
    /// `n`, feed `infer_secs / n`).
    pub fn observe(&mut self, service_secs: f64) {
        if !service_secs.is_finite() || service_secs < 0.0 {
            return;
        }
        if self.observations == 0 {
            self.ewma_service_secs = service_secs;
        } else {
            self.ewma_service_secs =
                Self::ALPHA * service_secs + (1.0 - Self::ALPHA) * self.ewma_service_secs;
        }
        self.observations += 1;
    }

    /// Current EWMA of per-request service seconds (0 before any
    /// observation).
    pub fn service_secs(&self) -> f64 {
        self.ewma_service_secs
    }

    /// Predicted queueing delay at the given queue depth.
    pub fn estimated_delay_secs(&self, queue_depth: usize) -> f64 {
        self.ewma_service_secs * queue_depth as f64
    }

    /// Admission decision: batch-lane requests always pass; interactive
    /// requests pass while the predicted queue delay fits the deadline.
    pub fn admits(&self, class: &SloClass, queue_depth: usize) -> bool {
        match class.deadline_secs() {
            Some(deadline) => self.estimated_delay_secs(queue_depth) <= deadline,
            None => true,
        }
    }
}

/// When the [`BatchFormer`] cuts a batch.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// cut as soon as this many requests are pending (>= 1)
    pub max_batch: usize,
    /// cut a partial batch once the oldest pending request has waited
    /// this long — bounds the batching delay a lone request pays
    pub max_delay_secs: f64,
    /// admission-queue bound; requests beyond it are rejected
    pub capacity: usize,
    /// aging credit: after this many consecutive cuts that served no
    /// batch-lane request while some were pending, the batch lane leads
    /// the next cut (prevents starvation under interactive load)
    pub batch_aging_cuts: u32,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_delay_secs: 0.005, capacity: 256, batch_aging_cuts: 4 }
    }
}

struct Pending<T> {
    req: Request,
    payload: T,
    enqueued_at: f64,
}

/// A formed multi-request batch.
pub struct FormedBatch<T> {
    /// the coalesced requests with their payloads, FIFO order preserved
    pub requests: Vec<(Request, T)>,
    /// per-request seconds spent waiting for the batch to form, aligned
    /// with `requests`
    pub batching_delays: Vec<f64>,
    /// interactive requests whose deadline was already blown at cut
    /// time: removed from the queue without serving — the caller owes
    /// each a `{"error":"deadline"}` reply
    pub shed: Vec<(Request, T)>,
    /// the `now` at which the batch was cut
    pub formed_at: f64,
}

impl<T> FormedBatch<T> {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Size/deadline-based batch former over a bounded admission queue,
/// with one lane per [`SloClass`] (see module docs for the lane and
/// shedding rules).
///
/// `T` is an opaque per-request payload carried through forming (the
/// TCP server uses it for the reply channel; the pipeline uses the
/// request's hash table).
pub struct BatchFormer<T> {
    /// latency-bound lane: leads every cut (unless the aging credit
    /// hands the lead to the batch lane)
    interactive: VecDeque<Pending<T>>,
    /// throughput lane: fills leftover batch slots, protected from
    /// starvation by the aging credit
    batch_lane: VecDeque<Pending<T>>,
    policy: BatchPolicy,
    /// consecutive cuts that served no batch-lane request while some
    /// were pending
    starved_cuts: u32,
    pub admitted: u64,
    pub rejected: u64,
    /// interactive requests dropped at cut time with a blown deadline
    pub shed: u64,
    pub batches_formed: u64,
    pub batched_requests: u64,
}

impl<T> BatchFormer<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        let policy = BatchPolicy { max_batch: policy.max_batch.max(1), ..policy };
        BatchFormer {
            interactive: VecDeque::new(),
            batch_lane: VecDeque::new(),
            policy,
            starved_cuts: 0,
            admitted: 0,
            rejected: 0,
            shed: 0,
            batches_formed: 0,
            batched_requests: 0,
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn len(&self) -> usize {
        self.interactive.len() + self.batch_lane.len()
    }

    pub fn is_empty(&self) -> bool {
        self.interactive.is_empty() && self.batch_lane.is_empty()
    }

    fn oldest_enqueued(&self) -> Option<f64> {
        let a = self.interactive.front().map(|p| p.enqueued_at);
        let b = self.batch_lane.front().map(|p| p.enqueued_at);
        match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, y) => x.or(y),
        }
    }

    /// Admit one request (`now` in monotonic seconds).  Rejected when
    /// the two lanes together hold `capacity` pending requests.
    pub fn admit(&mut self, req: Request, payload: T, now: f64) -> AdmitOutcome {
        if self.len() >= self.policy.capacity {
            self.rejected += 1;
            return AdmitOutcome::Rejected;
        }
        self.admitted += 1;
        let pending = Pending { req, payload, enqueued_at: now };
        if pending.req.class.is_interactive() {
            self.interactive.push_back(pending);
        } else {
            self.batch_lane.push_back(pending);
        }
        AdmitOutcome::Admitted
    }

    /// Whether a batch would be cut at `now`: enough pending requests,
    /// or the oldest (across both lanes) has exceeded the deadline.
    pub fn ready(&self, now: f64) -> bool {
        if self.len() >= self.policy.max_batch {
            return true;
        }
        self.oldest_enqueued()
            .is_some_and(|t| now - t >= self.policy.max_delay_secs)
    }

    /// When the oldest pending request's deadline fires (absolute time
    /// on the caller's clock), if anything is pending — what a worker
    /// should sleep until.
    pub fn next_deadline(&self) -> Option<f64> {
        self.oldest_enqueued().map(|t| t + self.policy.max_delay_secs)
    }

    /// Cut a batch if the policy says so (size reached or deadline
    /// fired), else `None`.
    pub fn try_form(&mut self, now: f64) -> Option<FormedBatch<T>> {
        if !self.ready(now) {
            return None;
        }
        self.form(now)
    }

    /// Cut whatever is pending regardless of the policy (shutdown
    /// drain); still bounded by `max_batch` and profile grouping, so a
    /// long backlog drains as several batches.
    pub fn form_now(&mut self, now: f64) -> Option<FormedBatch<T>> {
        self.form(now)
    }

    fn form(&mut self, now: f64) -> Option<FormedBatch<T>> {
        if self.is_empty() {
            return None;
        }
        // shed interactive requests whose deadline is already blown:
        // serving them cannot meet the SLO and only displaces requests
        // that can still make theirs (scan the whole lane — protocol
        // clients may carry per-request deadlines)
        let mut shed = Vec::new();
        let mut keep = VecDeque::with_capacity(self.interactive.len());
        while let Some(p) = self.interactive.pop_front() {
            let deadline = p.req.class.deadline_secs().unwrap_or(f64::INFINITY);
            if now - p.req.arrival > deadline {
                shed.push((p.req, p.payload));
            } else {
                keep.push_back(p);
            }
        }
        self.interactive = keep;
        self.shed += shed.len() as u64;

        // aging credit: the batch lane leads this cut if it has been
        // passed over too many times in a row
        let batch_leads = self.starved_cuts >= self.policy.batch_aging_cuts
            && !self.batch_lane.is_empty();
        let max_batch = self.policy.max_batch;
        let (lead, tail) = if batch_leads {
            (&mut self.batch_lane, &mut self.interactive)
        } else {
            (&mut self.interactive, &mut self.batch_lane)
        };
        let first_len = match lead.front().or(tail.front()).map(|p| p.req.ids.len()) {
            Some(l) => l,
            None => {
                // everything pending was shed: no batch to run, but the
                // caller still owes the shed requests their replies
                if shed.is_empty() {
                    return None;
                }
                return Some(FormedBatch {
                    requests: Vec::new(),
                    batching_delays: Vec::new(),
                    shed,
                    formed_at: now,
                });
            }
        };
        let mut requests = Vec::new();
        let mut batching_delays = Vec::new();
        let mut taken = [0usize; 2];
        for (i, lane) in [lead, tail].into_iter().enumerate() {
            while requests.len() < max_batch {
                // group-by-profile: only same-seq-len sentences can
                // share a forward pass; a different profile starts the
                // next batch
                match lane.front() {
                    Some(p) if p.req.ids.len() == first_len => {
                        let p = lane.pop_front().unwrap();
                        batching_delays.push((now - p.enqueued_at).max(0.0));
                        requests.push((p.req, p.payload));
                        taken[i] += 1;
                    }
                    _ => break,
                }
            }
        }
        let batch_taken = if batch_leads { taken[0] } else { taken[1] };
        if batch_taken == 0 && !self.batch_lane.is_empty() {
            self.starved_cuts += 1;
        } else {
            self.starved_cuts = 0;
        }
        if !requests.is_empty() {
            self.batches_formed += 1;
            self.batched_requests += requests.len() as u64;
        }
        Some(FormedBatch { requests, batching_delays, shed, formed_at: now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64) -> Request {
        Request {
            id,
            ids: vec![1, 5, 2, 0],
            n_tokens: 3,
            label: 0,
            arrival,
            class: SloClass::Batch,
        }
    }

    fn ireq(id: u64, arrival: f64, deadline_secs: f64) -> Request {
        Request {
            class: SloClass::Interactive { deadline_secs },
            ..req(id, arrival)
        }
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(10);
        for i in 0..5 {
            assert_eq!(b.admit(req(i, 0.0)), AdmitOutcome::Admitted);
        }
        for i in 0..5 {
            assert_eq!(b.next().unwrap().id, i);
        }
        assert!(b.next().is_none());
    }

    #[test]
    fn capacity_backpressure() {
        let mut b = Batcher::new(2);
        assert_eq!(b.admit(req(0, 0.0)), AdmitOutcome::Admitted);
        assert_eq!(b.admit(req(1, 0.0)), AdmitOutcome::Admitted);
        assert_eq!(b.admit(req(2, 0.0)), AdmitOutcome::Rejected);
        assert_eq!(b.rejected, 1);
        b.next();
        assert_eq!(b.admit(req(2, 0.0)), AdmitOutcome::Admitted);
    }

    #[test]
    fn admit_due_respects_time() {
        let mut b = Batcher::new(10);
        let mut trace = vec![req(0, 0.1), req(1, 0.5), req(2, 2.0)];
        assert_eq!(b.admit_due(&mut trace, 1.0), 2);
        assert_eq!(trace.len(), 1);
        assert_eq!(b.len(), 2);
        assert_eq!(b.admit_due(&mut trace, 3.0), 1);
        assert!(trace.is_empty());
    }

    #[test]
    fn exactly_once_delivery() {
        let mut b = Batcher::new(100);
        let mut seen = std::collections::HashSet::new();
        for i in 0..50 {
            b.admit(req(i, 0.0));
        }
        while let Some(r) = b.next() {
            assert!(seen.insert(r.id), "duplicate {}", r.id);
        }
        assert_eq!(seen.len(), 50);
    }

    fn policy(max_batch: usize, delay: f64, cap: usize) -> BatchPolicy {
        BatchPolicy { max_batch, max_delay_secs: delay, capacity: cap, ..Default::default() }
    }

    #[test]
    fn admit_due_sheds_overflow_instead_of_retrying() {
        // 3 due requests into a queue with 1 free slot: one admitted,
        // two counted rejected, none left behind for an implicit retry
        let mut b = Batcher::new(1);
        let mut trace = vec![req(0, 0.0), req(1, 0.1), req(2, 0.2), req(3, 9.0)];
        assert_eq!(b.admit_due(&mut trace, 1.0), 1);
        assert_eq!(b.rejected, 2, "every due overflow request must be counted");
        assert_eq!(trace.len(), 1, "only the not-yet-due request may remain");
        assert_eq!(trace[0].id, 3);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn size_trigger_forms_full_batch() {
        let mut f: BatchFormer<u32> = BatchFormer::new(policy(3, 10.0, 64));
        for i in 0..5 {
            assert_eq!(f.admit(req(i, 0.0), i as u32, 0.0), AdmitOutcome::Admitted);
        }
        let b = f.try_form(0.0).expect("size reached");
        assert_eq!(b.len(), 3);
        assert_eq!(b.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.requests.iter().map(|(_, p)| *p).collect::<Vec<_>>(), vec![0, 1, 2]);
        // two left: below size, before deadline -> no batch yet
        assert!(f.try_form(0.0).is_none());
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn deadline_fires_with_partial_batch() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(8, 0.005, 64));
        f.admit(req(0, 0.0), (), 1.000);
        f.admit(req(1, 0.0), (), 1.002);
        assert!(!f.ready(1.004));
        assert!(f.try_form(1.004).is_none());
        assert!((f.next_deadline().unwrap() - 1.005).abs() < 1e-9);
        let b = f.try_form(1.006).expect("deadline fired");
        assert_eq!(b.len(), 2);
        // batching delay measured from each request's own admission
        assert!((b.batching_delays[0] - 0.006).abs() < 1e-9);
        assert!((b.batching_delays[1] - 0.004).abs() < 1e-9);
        assert!(f.is_empty());
        assert_eq!(f.batches_formed, 1);
        assert_eq!(f.batched_requests, 2);
    }

    #[test]
    fn rejection_accounting_under_overflow() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(4, 1.0, 2));
        assert_eq!(f.admit(req(0, 0.0), (), 0.0), AdmitOutcome::Admitted);
        assert_eq!(f.admit(req(1, 0.0), (), 0.0), AdmitOutcome::Admitted);
        assert_eq!(f.admit(req(2, 0.0), (), 0.0), AdmitOutcome::Rejected);
        assert_eq!(f.admit(req(3, 0.0), (), 0.0), AdmitOutcome::Rejected);
        assert_eq!((f.admitted, f.rejected), (2, 2));
        // draining frees capacity again
        let b = f.form_now(0.0).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(f.admit(req(4, 0.0), (), 0.0), AdmitOutcome::Admitted);
    }

    #[test]
    fn profile_grouping_splits_mixed_seq_lens() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(8, 10.0, 64));
        let short = |id| req(id, 0.0);
        let long = |id| Request { ids: vec![1, 5, 5, 5, 5, 5, 2, 0], n_tokens: 7, ..req(id, 0.0) };
        f.admit(short(0), (), 0.0);
        f.admit(short(1), (), 0.0);
        f.admit(long(2), (), 0.0);
        f.admit(long(3), (), 0.0);
        let b1 = f.form_now(0.0).unwrap();
        assert_eq!(b1.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![0, 1]);
        let b2 = f.form_now(0.0).unwrap();
        assert_eq!(b2.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(f.form_now(0.0).is_none());
    }

    #[test]
    fn form_now_on_empty_is_none() {
        let mut f: BatchFormer<()> = BatchFormer::new(BatchPolicy::default());
        assert!(f.form_now(0.0).is_none());
        assert_eq!(f.batches_formed, 0);
    }

    #[test]
    fn interactive_lane_cuts_first() {
        // batch-lane requests arrived earlier, but the interactive lane
        // leads the cut; leftover slots fill from the batch lane FIFO
        let mut f: BatchFormer<u32> = BatchFormer::new(policy(3, 10.0, 64));
        f.admit(req(0, 0.0), 0, 0.000);
        f.admit(req(1, 0.0), 1, 0.001);
        f.admit(ireq(2, 0.002, 5.0), 2, 0.002);
        f.admit(ireq(3, 0.003, 5.0), 3, 0.003);
        let b = f.form_now(0.004).unwrap();
        assert_eq!(
            b.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(),
            vec![2, 3, 0],
            "interactive first, then oldest batch-lane"
        );
        assert!(b.shed.is_empty());
        let b2 = f.form_now(0.005).unwrap();
        assert_eq!(b2.requests.len(), 1);
        assert_eq!(b2.requests[0].0.id, 1);
    }

    #[test]
    fn batch_lane_never_starves_via_aging_credit() {
        // keep the interactive lane saturated: after `batch_aging_cuts`
        // cuts that skip the batch lane, it must lead a cut
        let mut f: BatchFormer<()> = BatchFormer::new(BatchPolicy {
            max_batch: 1,
            max_delay_secs: 10.0,
            capacity: 64,
            batch_aging_cuts: 2,
        });
        f.admit(req(0, 0.0), (), 0.0);
        let mut served_batch_lane = None;
        for cut in 0..10u64 {
            f.admit(ireq(100 + cut, 0.0, 100.0), (), 0.0);
            let b = f.form_now(0.01).unwrap();
            assert_eq!(b.requests.len(), 1);
            if b.requests[0].0.id == 0 {
                served_batch_lane = Some(cut);
                break;
            }
        }
        let cut = served_batch_lane.expect("batch-lane request starved across 10 cuts");
        assert_eq!(cut, 2, "aging credit of 2 must hand over the 3rd cut, not cut {cut}");
    }

    #[test]
    fn blown_interactive_requests_are_shed_at_cut() {
        let mut f: BatchFormer<u32> = BatchFormer::new(policy(4, 10.0, 64));
        f.admit(ireq(0, 0.0, 0.010), 0, 0.0); // deadline 10 ms: blown at cut
        f.admit(ireq(1, 0.0, 10.0), 1, 0.0); // generous deadline: served
        f.admit(req(2, 0.0), 2, 0.0);
        let b = f.form_now(0.100).unwrap();
        assert_eq!(b.shed.len(), 1);
        assert_eq!(b.shed[0].0.id, 0);
        assert_eq!(
            b.requests.iter().map(|(r, _)| r.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
        assert_eq!(f.shed, 1);
        assert_eq!(f.batched_requests, 2);
    }

    #[test]
    fn all_blown_cut_returns_shed_only_batch() {
        let mut f: BatchFormer<()> = BatchFormer::new(policy(4, 10.0, 64));
        f.admit(ireq(0, 0.0, 0.001), (), 0.0);
        f.admit(ireq(1, 0.0, 0.001), (), 0.0);
        let b = f.form_now(1.0).expect("shed-only cut must still surface the shed");
        assert!(b.requests.is_empty());
        assert_eq!(b.shed.len(), 2);
        assert_eq!(f.batches_formed, 0, "a shed-only cut is not a formed batch");
        assert!(f.is_empty());
        assert!(f.form_now(2.0).is_none());
    }

    #[test]
    fn estimator_learns_and_gates_interactive_only() {
        let mut est = QueueDelayEstimator::default();
        let interactive = SloClass::Interactive { deadline_secs: 0.05 };
        // before any observation: everything admitted at any depth
        assert!(est.admits(&interactive, 10_000));
        est.observe(0.010);
        assert!((est.service_secs() - 0.010).abs() < 1e-12);
        // 10 ms per request x depth 10 = 100 ms > 50 ms deadline
        assert!(!est.admits(&interactive, 10));
        assert!(est.admits(&interactive, 4));
        // the batch lane is never gated
        assert!(est.admits(&SloClass::Batch, 10_000));
        // EWMA tracks, garbage observations are ignored
        est.observe(f64::NAN);
        est.observe(-1.0);
        assert!((est.service_secs() - 0.010).abs() < 1e-12);
        for _ in 0..200 {
            est.observe(0.001);
        }
        assert!(est.service_secs() < 0.002, "EWMA must converge toward recent service");
        assert!(est.admits(&interactive, 10));
    }

    #[test]
    fn admit_due_controlled_rejects_doomed_interactive() {
        let mut b = Batcher::new(64);
        let mut est = QueueDelayEstimator::default();
        est.observe(0.010);
        // preload queue depth 10 -> predicted delay 100 ms
        for i in 0..10 {
            b.admit(req(i, 0.0));
        }
        let mut trace = vec![
            ireq(100, 0.0, 0.050), // doomed: 100 ms predicted > 50 ms deadline
            req(101, 0.0),         // batch lane: always admitted
            ireq(102, 0.0, 1.0),   // generous deadline: admitted
        ];
        let (admitted, slo_rejected) = b.admit_due_controlled(&mut trace, 1.0, &est);
        assert_eq!((admitted, slo_rejected), (2, 1));
        assert!(trace.is_empty());
        assert_eq!(b.rejected, 0, "SLO rejects must not count as capacity rejects");
    }
}
