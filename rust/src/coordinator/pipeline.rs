//! The SiDA serving pipeline (paper Fig 5 + Algorithm 1).
//!
//! Three OS threads realize the paper's design, plus a per-forward
//! layer-ahead warmer:
//!
//! ```text
//! hash-building thread   runs the hash artifact on batch X_j, pushes
//!                        H_j onto the bounded hash-table queue
//! prefetch stage         pops (X_i, H_i) and warms the FIRST MoE
//!                        layer's predicted experts while the previous
//!                        request computes (request-ahead overlap)
//! layer-ahead warmer     spawned per forward: while the inference
//!                        thread computes MoE layer j, stages layer
//!                        j+1's predicted union — the paper's
//!                        "dynamical loading ... following the pipeline
//!                        parallelism mechanism" (§3.1) at layer
//!                        granularity.  The forward gates each MoE
//!                        layer on its warm-up, so every fetch lands on
//!                        the overlapped prefetch timeline and the
//!                        critical path pays only exposed transfer.
//! inference thread       forwards X_i with the hash table replacing
//!                        every router (routers never execute); the
//!                        gathered per-expert invocations of each MoE
//!                        layer run concurrently on the runner's
//!                        worker pool
//! ```
//!
//! The inference thread "never idles except at the very beginning"
//! (paper §3.1) because a hash build + prefetch is faster than a forward
//! pass; the bounded queue provides the backpressure that keeps the
//! pipeline stable.
//!
//! With `PipelineConfig::max_batch > 1` the middle stage becomes a
//! batch former: consecutive requests are coalesced, the layer-ahead
//! warmer stages the **batch-union** expert set layer by layer, and the
//! inference thread serves each batch with a single cross-request
//! `forward_batch` — one expert invocation per activated expert per
//! batch, bit-identical outputs to batch-1 serving.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cluster::{ClusterConfig, ClusterFetch, ClusterRouter};
use crate::coordinator::hash_table::HashTable;
use crate::coordinator::hash_thread::HashBuilder;
use crate::experts::{
    make_policy, plan_prefetch_layer, ExpertCache, PlannedFetch, SharedExpertCache,
};
use crate::memory::CostModel;
use crate::metrics::ServeStats;
use crate::model::{BatchItem, ExpertProvider, ForwardHooks, ForwardOptions, ModelRunner};
use crate::obs::trace::{self, ArgValue};
use crate::runtime::ModelBundle;
use crate::util::pool::WorkerPool;
use crate::util::sync::LayerGate;
use crate::workload::Request;

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// experts consumed per token from the hash table (paper §4: top-1
    /// for SST2, top-3 for MRPC/MultiRC)
    pub k_used: usize,
    /// simulated device budget in bytes for expert weights
    pub budget_sim_bytes: usize,
    /// eviction policy name (paper default: fifo)
    pub policy: String,
    /// modeled host-RAM tier budget in bytes (`--ram-budget`): device
    /// evictions demote into this window of the §6 ladder; overflow
    /// falls to unbounded SSD, and a later miss on an SSD-deep expert
    /// pays the NVMe+PCIe ladder (~9x a RAM-resident one).  Per device
    /// in cluster mode, like `budget_sim_bytes`.
    pub ram_budget_bytes: usize,
    /// the RAM window's own eviction policy (`--ram-policy`)
    pub ram_policy: String,
    /// on-disk expert store directory (`--store-dir`): the SSD tier
    /// becomes a real, content-addressed blob store — demotions write
    /// hash-named blobs, SSD promotions read + verify them on a
    /// measured timeline beside the modeled one, and reopening an
    /// existing directory pre-seeds the ledger so a restarted process
    /// serves warm.  Empty = modeled-only SSD tier.  Single-device
    /// serving only (cluster devices run store-less).
    pub store_dir: String,
    /// on-disk store byte budget (`--ssd-budget`, 0 = unbounded):
    /// overflow reclaims oldest-written blobs first
    pub ssd_budget_bytes: usize,
    /// sleep modeled transfer time on the critical path
    pub real_sleep: bool,
    /// run the prefetch stages (request-ahead + layer-ahead warmer);
    /// false = fetch on demand at compute time, an ablation that shows
    /// what the look-ahead buys
    pub prefetch: bool,
    /// staging depth of the cross-layer prefetch scheduler
    /// (`--prefetch-depth`): how many layers ahead of compute the
    /// depth-window warmer may probe, and the clamp on every fetch's
    /// tier-derived lead ([`crate::memory::lead_layers`]).  `1` is the
    /// PR 5 one-layer-ahead baseline; the default `3` lets SSD-deep
    /// promotions start 2–3 layers early, bounded by their ladder time
    pub prefetch_depth: usize,
    /// modeled host-link bandwidth for expert staging in bytes/sec
    /// (`--host-bw`; `0` = the reference PCIe link of the cost model).
    /// A slower link inflates the shared bandwidth window's occupancy
    /// (`reference_bw / host_bw`), so the same staging plan backlogs it
    /// faster — the ladder charge per transfer is untouched
    pub host_bw: f64,
    /// hash-table queue depth
    pub queue_depth: usize,
    /// requests coalesced per forward pass (1 = the paper's batch-1
    /// setting; > 1 enables cross-request batching: one expert
    /// invocation per activated expert per batch, batch-union prefetch)
    pub max_batch: usize,
    /// worker-pool width for concurrent expert execution
    /// (0 = auto-size from the machine / `SIDA_POOL_THREADS`)
    pub pool_threads: usize,
    /// modeled devices to serve across (1 = the paper's single-device
    /// setting; > 1 enables expert parallelism: data-aware placement,
    /// hot-expert replication, per-device caches — see
    /// [`crate::cluster`]).  `budget_sim_bytes` is then **per device**.
    pub devices: usize,
    /// hottest experts per MoE layer replicated across the fleet
    /// (cluster mode only)
    pub replicate_top: usize,
    /// availability floor (`--min-replicas`): every predicted-hot
    /// expert placed on at least this many devices, best-effort under
    /// capacity (cluster mode only; 1 = no floor)
    pub min_replicas: usize,
    /// deterministic fault schedule on the batch-tick timeline
    /// (`--fault-plan`, [`crate::cluster::FaultPlan`] grammar; cluster
    /// mode only, empty = fault-free)
    pub fault_plan: String,
    pub want_lm: bool,
    pub want_cls: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            k_used: 1,
            budget_sim_bytes: 8 << 30,
            policy: "fifo".into(),
            ram_budget_bytes: crate::memory::DEFAULT_RAM_BUDGET,
            ram_policy: "fifo".into(),
            store_dir: String::new(),
            ssd_budget_bytes: 0,
            real_sleep: false,
            prefetch: true,
            prefetch_depth: 3,
            host_bw: 0.0,
            queue_depth: 8,
            max_batch: 1,
            pool_threads: 0,
            devices: 1,
            replicate_top: 1,
            min_replicas: 1,
            fault_plan: String::new(),
            want_lm: false,
            want_cls: false,
        }
    }
}

/// Result of serving one trace through the pipeline.
pub struct ServeOutcome {
    pub stats: ServeStats,
    /// per-request (id, latency, cls_argmax, lm_nll-sum, token count)
    pub per_request: Vec<RequestResult>,
}

#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub latency_secs: f64,
    pub cls_pred: Option<usize>,
    pub lm_nll: Option<f64>,
    pub lm_tokens: Option<f64>,
    pub n_tokens: usize,
}

/// The SiDA serving pipeline: hash-building thread, optional prefetch
/// stages, inference thread — with batch-1 (`serve`, paper setting) and
/// cross-request batched (`max_batch > 1`) modes.
///
/// ```
/// use sida_moe::coordinator::{Pipeline, PipelineConfig};
///
/// let bundle = sida_moe::testkit::tiny_bundle();
/// let requests = sida_moe::testkit::tiny_trace(&bundle, 3, 0);
/// let pipeline =
///     Pipeline::new(bundle, sida_moe::testkit::TINY_PROFILE, PipelineConfig::default()).unwrap();
/// let outcome = pipeline.serve(&requests).unwrap();
/// assert_eq!(outcome.stats.requests, 3);
/// assert_eq!(outcome.stats.blocking_misses, 0); // prefetch kept the critical path clean
/// ```
pub struct Pipeline {
    pub bundle: Arc<ModelBundle>,
    pub runner: Arc<ModelRunner>,
    /// single-device expert cache (the serving residency tier when
    /// `cfg.devices == 1`; cluster mode uses per-device caches instead)
    pub cache: Arc<SharedExpertCache>,
    /// the device fleet + router when `cfg.devices > 1`
    pub cluster: Option<Arc<ClusterRouter>>,
    pub cfg: PipelineConfig,
    pub profile: String,
}

impl Pipeline {
    pub fn new(bundle: Arc<ModelBundle>, profile: &str, cfg: PipelineConfig) -> Result<Self> {
        let pool = WorkerPool::from_config(cfg.pool_threads);
        let runner = Arc::new(ModelRunner::with_pool(bundle.clone(), profile, pool)?);
        let real_expert_bytes = bundle.weights.expert_bytes(bundle.topology.moe_blocks[0], 0)?;
        let cost = CostModel::paper_scale(real_expert_bytes).with_real_sleep(cfg.real_sleep);
        let mut core = ExpertCache::with_hierarchy(
            cfg.budget_sim_bytes,
            cost,
            make_policy(&cfg.policy)?,
            cfg.ram_budget_bytes,
            make_policy(&cfg.ram_policy)?,
        );
        if !cfg.store_dir.is_empty() {
            if cfg.devices > 1 {
                anyhow::bail!(
                    "--store-dir applies to single-device serving \
                     (cluster devices run store-less)"
                );
            }
            let store = crate::memory::ExpertStore::open(
                std::path::Path::new(&cfg.store_dir),
                cfg.ssd_budget_bytes as u64,
            )?;
            core.attach_store(crate::experts::bind_store(&bundle, store));
        }
        let cache = Arc::new(SharedExpertCache::new(core));
        if cfg.host_bw > 0.0 {
            // occupancy multiplier of the shared staging window: a link
            // at half the reference bandwidth backlogs twice as fast
            cache
                .bandwidth_window()
                .set_rate(CostModel::paper_scale(real_expert_bytes).h2d_bandwidth / cfg.host_bw);
        }
        let cluster = if cfg.devices > 1 {
            Some(Arc::new(ClusterRouter::new(
                &bundle,
                &ClusterConfig {
                    devices: cfg.devices,
                    replicate_top: cfg.replicate_top,
                    min_replicas: cfg.min_replicas,
                    fault_plan: cfg.fault_plan.clone(),
                    budget_per_device: cfg.budget_sim_bytes,
                    policy: cfg.policy.clone(),
                    real_sleep: cfg.real_sleep,
                    host_ram_budget: cfg.ram_budget_bytes,
                    ram_policy: cfg.ram_policy.clone(),
                    host_bw: cfg.host_bw,
                    ..ClusterConfig::default()
                },
            )?))
        } else {
            None
        };
        Ok(Pipeline {
            bundle,
            runner,
            cache,
            cluster,
            cfg,
            profile: profile.to_string(),
        })
    }

    /// The expert provider serving this pipeline: the shared
    /// single-device cache, or the cluster router in multi-device mode.
    pub(crate) fn provider(&self) -> ExpertProvider<'_> {
        match &self.cluster {
            Some(router) => ExpertProvider::Cluster { router, blocking: true },
            None => ExpertProvider::Shared { cache: &self.cache, blocking: true },
        }
    }

    /// Who the prefetch stages warm (see [`WarmTarget`]).
    fn warm_target(&self) -> WarmTarget {
        match &self.cluster {
            Some(router) => WarmTarget::Cluster { router: router.clone() },
            None => WarmTarget::Single { cache: self.cache.clone() },
        }
    }

    /// Data-aware placement from a sample of the trace's own hash
    /// predictions: build tables for the first few requests, fold them
    /// into the activation profile, and (re)plan homes + replicas.  The
    /// sampled tables are rebuilt by the hash thread during serving —
    /// a deliberate, cheap double build (profiling pass), not a cache.
    /// No-op on a single-device pipeline; the open-loop scheduler calls
    /// this too before replaying a trace.
    pub(crate) fn plan_cluster_placement(&self, requests: &[Request]) -> Result<()> {
        let Some(router) = &self.cluster else {
            return Ok(());
        };
        const SAMPLE: usize = 8;
        let builder = HashBuilder::new(&self.bundle, &self.profile)?;
        for req in requests.iter().take(SAMPLE) {
            let table = builder.build(req.id, &req.ids)?;
            let mask = req.mask();
            router.observe(&[(&table, &mask[..])], self.cfg.k_used);
        }
        router.replan_now(&self.bundle);
        Ok(())
    }

    /// Reset every serving counter (single-device cache and, in cluster
    /// mode, every device cache + the router's totals) — between bench
    /// warmup and measurement.
    pub fn reset_serving_stats(&self) {
        self.cache.reset_stats();
        if let Some(router) = &self.cluster {
            router.reset_stats();
        }
    }

    /// Serve a closed-loop trace; returns aggregate + per-request stats.
    ///
    /// With `cfg.max_batch > 1` this runs the cross-request batched
    /// path ([`Pipeline::serve_batched`]); the default is the paper's
    /// batch-1 pipeline.
    pub fn serve(&self, requests: &[Request]) -> Result<ServeOutcome> {
        if self.cfg.max_batch > 1 {
            return self.serve_batched(requests);
        }
        self.plan_cluster_placement(requests)?;
        let builder = HashBuilder::new(&self.bundle, &self.profile)?;
        let (tx, rx): (
            SyncSender<(Request, HashTable)>,
            Receiver<(Request, HashTable)>,
        ) = sync_channel(self.cfg.queue_depth);

        let reqs = requests.to_vec();
        let t_start = Instant::now();

        // ---- hash-building thread -------------------------------------
        let hash_handle = std::thread::Builder::new()
            .name("sida-hash".into())
            .spawn(move || -> Result<f64> {
                let mut total_build = 0.0;
                for req in reqs {
                    let t_hash = trace::begin();
                    let table = builder.build(req.id, &req.ids)?;
                    total_build += table.build_secs;
                    if trace::enabled() {
                        trace::complete(
                            "hash_build",
                            "hash",
                            trace::host_pid(),
                            t_hash,
                            vec![
                                ("request", ArgValue::U(req.id)),
                                ("secs", ArgValue::F(table.build_secs)),
                            ],
                        );
                    }
                    if tx.send((req, table)).is_err() {
                        break; // inference side hung up
                    }
                }
                Ok(total_build)
            })
            .expect("spawn hash thread");

        // ---- request-ahead prefetch stage (optional) ------------------
        // Warms the FIRST MoE layer before handing the request to
        // inference (so a cold start pays one layer of transfer, not
        // all of them), then keeps warming the deeper layers AFTER the
        // hand-off — overlapped with the request's own early compute
        // and with any previous request still in flight.  The
        // per-forward layer-ahead warmer backstops whatever this stage
        // has not finished (or what eviction took back).
        let (ptx, prx): (
            SyncSender<(Request, HashTable)>,
            Receiver<(Request, HashTable)>,
        ) = sync_channel(self.cfg.queue_depth);
        let prefetch_handle = if self.cfg.prefetch {
            let target = self.warm_target();
            let bundle = self.bundle.clone();
            let k_used = self.cfg.k_used;
            let depth = self.cfg.prefetch_depth.max(1);
            let moe_blocks = self.bundle.topology.moe_blocks.clone();
            Some(
                std::thread::Builder::new()
                    .name("sida-prefetch".into())
                    .spawn(move || -> Result<()> {
                        while let Ok((req, table)) = rx.recv() {
                            let mask = req.mask();
                            let deeper = {
                                let pairs: Vec<(&HashTable, &[f32])> =
                                    vec![(&table, &mask[..])];
                                target.warm_layer(
                                    &bundle, &pairs, moe_blocks[0], 0, k_used, 1, depth,
                                )?;
                                target.plan_deeper(&pairs, &moe_blocks, k_used, depth)
                            };
                            if ptx.send((req, table)).is_err() {
                                break;
                            }
                            target.fetch_deeper(&bundle, &deeper)?;
                        }
                        Ok(())
                    })
                    .expect("spawn prefetch thread"),
            )
        } else {
            // pass-through
            let rx_moved = rx;
            Some(
                std::thread::Builder::new()
                    .name("sida-passthrough".into())
                    .spawn(move || -> Result<()> {
                        while let Ok(item) = rx_moved.recv() {
                            if ptx.send(item).is_err() {
                                break;
                            }
                        }
                        Ok(())
                    })
                    .expect("spawn passthrough thread"),
            )
        };

        // ---- inference thread (this thread) ----------------------------
        let mut stats = ServeStats::default();
        let mut per_request = Vec::new();
        let opts = ForwardOptions {
            invoke_all: false,
            fixed_bucket: false,
            want_lm: self.cfg.want_lm,
            want_cls: self.cfg.want_cls,
        };
        while let Ok((req, table)) = prx.recv() {
            // one batch tick per forward: the fault timeline advances,
            // and a device failing/recovering on this tick replans
            // before any routing decision for the batch
            if let Some(router) = &self.cluster {
                router.advance_batch(&self.bundle);
            }
            let trace_ids = [req.id];
            let t_req = trace::begin();
            if trace::enabled() {
                trace::flow('s', req.id, trace::host_pid());
            }
            let t0 = Instant::now();
            let mut provider = self.provider();
            let out = if self.cfg.prefetch {
                let mask = req.mask();
                let pairs: Vec<(&HashTable, &[f32])> = vec![(&table, &mask[..])];
                self.forward_gated(&pairs, &trace_ids, |hooks| {
                    self.runner.forward_hooked(
                        &req.ids,
                        Some((&table, self.cfg.k_used)),
                        &mut provider,
                        opts,
                        hooks,
                    )
                })?
            } else {
                self.runner.forward_hooked(
                    &req.ids,
                    Some((&table, self.cfg.k_used)),
                    &mut provider,
                    opts,
                    ForwardHooks { layer_gate: None, trace_ids: Some(&trace_ids) },
                )?
            };
            let latency = t0.elapsed().as_secs_f64();
            if trace::enabled() {
                trace::flow('f', req.id, trace::host_pid());
                trace::complete(
                    "request",
                    "serve",
                    trace::host_pid(),
                    t_req,
                    vec![
                        ("request", ArgValue::U(req.id)),
                        ("latency_secs", ArgValue::F(latency)),
                    ],
                );
            }
            stats.latency.record(latency);
            stats.record_class(&req.class, latency);
            stats.phases.add(&out.times);
            stats.requests += 1;
            stats.hash_build_secs += table.build_secs;

            let cls_pred = out.cls_logits.as_ref().map(|v| argmax(v));
            let (lm_nll, lm_tokens) = match (&out.lm_logits, self.cfg.want_lm) {
                (Some(logits), true) => {
                    let (nll, cnt) = self.runner.lm_nll(logits, &req.ids)?;
                    (Some(nll), Some(cnt))
                }
                _ => (None, None),
            };
            per_request.push(RequestResult {
                id: req.id,
                latency_secs: latency,
                cls_pred,
                lm_nll,
                lm_tokens,
                n_tokens: req.n_tokens,
            });
        }
        stats.wall_secs = t_start.elapsed().as_secs_f64();
        stats.batches = stats.requests; // batch-1: one forward per request

        if let Some(h) = prefetch_handle {
            h.join().expect("prefetch thread panicked")?;
        }
        let _hash_secs = hash_handle.join().expect("hash thread panicked")?;

        self.collect_serving_stats(&mut stats);
        Ok(ServeOutcome { stats, per_request })
    }

    /// Serve a closed-loop trace with cross-request batching: the hash
    /// thread builds tables per sentence as usual, a forming stage
    /// coalesces up to `cfg.max_batch` consecutive requests and warms
    /// the first MoE layer's **batch-union** expert set, and the
    /// inference thread issues one [`ModelRunner::forward_batch`] per
    /// formed batch — one (pooled) expert invocation per activated
    /// expert per batch, the deeper layers staged layer-ahead while the
    /// batch computes.
    ///
    /// Per-request latency is the shared forward time of the batch the
    /// request rode in (all requests of a batch complete together).
    pub fn serve_batched(&self, requests: &[Request]) -> Result<ServeOutcome> {
        self.plan_cluster_placement(requests)?;
        let builder = HashBuilder::new(&self.bundle, &self.profile)?;
        let (tx, rx): (
            SyncSender<(Request, HashTable)>,
            Receiver<(Request, HashTable)>,
        ) = sync_channel(self.cfg.queue_depth);

        let reqs = requests.to_vec();
        let t_start = Instant::now();

        // ---- hash-building thread (unchanged from batch-1) ------------
        let hash_handle = std::thread::Builder::new()
            .name("sida-hash".into())
            .spawn(move || -> Result<f64> {
                let mut total_build = 0.0;
                for req in reqs {
                    let t_hash = trace::begin();
                    let table = builder.build(req.id, &req.ids)?;
                    total_build += table.build_secs;
                    if trace::enabled() {
                        trace::complete(
                            "hash_build",
                            "hash",
                            trace::host_pid(),
                            t_hash,
                            vec![
                                ("request", ArgValue::U(req.id)),
                                ("secs", ArgValue::F(table.build_secs)),
                            ],
                        );
                    }
                    if tx.send((req, table)).is_err() {
                        break; // inference side hung up
                    }
                }
                Ok(total_build)
            })
            .expect("spawn hash thread");

        // ---- batch former + first-layer batch-union prefetch ----------
        let (ptx, prx): (
            SyncSender<Vec<(Request, HashTable)>>,
            Receiver<Vec<(Request, HashTable)>>,
        ) = sync_channel(self.cfg.queue_depth);
        let former_handle = {
            let target = self.warm_target();
            let bundle = self.bundle.clone();
            let k_used = self.cfg.k_used;
            let depth = self.cfg.prefetch_depth.max(1);
            let max_batch = self.cfg.max_batch.max(1);
            let prefetch = self.cfg.prefetch;
            let moe_blocks = self.bundle.topology.moe_blocks.clone();
            std::thread::Builder::new()
                .name("sida-batch-former".into())
                .spawn(move || -> Result<()> {
                    let mut pending: Vec<(Request, HashTable)> = Vec::new();
                    loop {
                        match rx.recv() {
                            Ok(item) => {
                                pending.push(item);
                                if pending.len() >= max_batch {
                                    let batch = std::mem::take(&mut pending);
                                    let deeper = if prefetch {
                                        Some(stage_batch_prefetch(
                                            &bundle, &target, &batch, &moe_blocks, k_used, depth,
                                        )?)
                                    } else {
                                        None
                                    };
                                    if ptx.send(batch).is_err() {
                                        return Ok(());
                                    }
                                    if let Some(plan) = deeper {
                                        target.fetch_deeper(&bundle, &plan)?;
                                    }
                                }
                            }
                            Err(_) => break, // hash thread done
                        }
                    }
                    if !pending.is_empty() {
                        let deeper = if prefetch {
                            Some(stage_batch_prefetch(
                                &bundle, &target, &pending, &moe_blocks, k_used, depth,
                            )?)
                        } else {
                            None
                        };
                        if ptx.send(pending).is_err() {
                            return Ok(());
                        }
                        if let Some(plan) = deeper {
                            target.fetch_deeper(&bundle, &plan)?;
                        }
                    }
                    Ok(())
                })
                .expect("spawn batch-former thread")
        };

        // ---- inference thread (this thread) ----------------------------
        let mut stats = ServeStats::default();
        let mut per_request = Vec::new();
        let opts = ForwardOptions {
            invoke_all: false,
            fixed_bucket: false,
            want_lm: self.cfg.want_lm,
            want_cls: self.cfg.want_cls,
        };
        while let Ok(batch) = prx.recv() {
            // one batch tick per formed batch (see `serve`)
            if let Some(router) = &self.cluster {
                router.advance_batch(&self.bundle);
            }
            let trace_ids: Vec<u64> = batch.iter().map(|(req, _)| req.id).collect();
            let t_batch = trace::begin();
            if trace::enabled() {
                for &rid in &trace_ids {
                    trace::flow('s', rid, trace::host_pid());
                }
            }
            let t0 = Instant::now();
            let masks: Vec<Vec<f32>> = batch.iter().map(|(req, _)| req.mask()).collect();
            let items: Vec<BatchItem<'_>> = batch
                .iter()
                .map(|(req, table)| BatchItem {
                    ids: &req.ids[..],
                    hash: Some((table, self.cfg.k_used)),
                })
                .collect();
            let mut provider = self.provider();
            let out = if self.cfg.prefetch {
                let pairs: Vec<(&HashTable, &[f32])> = batch
                    .iter()
                    .zip(masks.iter())
                    .map(|((_, table), mask)| (table, mask.as_slice()))
                    .collect();
                self.forward_gated(&pairs, &trace_ids, |hooks| {
                    self.runner.forward_batch_hooked(&items, &mut provider, opts, hooks)
                })?
            } else {
                self.runner.forward_batch_hooked(
                    &items,
                    &mut provider,
                    opts,
                    ForwardHooks { layer_gate: None, trace_ids: Some(&trace_ids) },
                )?
            };
            let secs = t0.elapsed().as_secs_f64();
            if trace::enabled() {
                for &rid in &trace_ids {
                    trace::flow('f', rid, trace::host_pid());
                }
                trace::complete(
                    "batch",
                    "serve",
                    trace::host_pid(),
                    t_batch,
                    vec![
                        ("requests", ArgValue::U(trace_ids.len() as u64)),
                        ("secs", ArgValue::F(secs)),
                    ],
                );
            }
            stats.batches += 1;
            stats.phases.add(&out.times);
            for ((req, table), fo) in batch.iter().zip(out.outputs.iter()) {
                stats.latency.record(secs);
                stats.record_class(&req.class, secs);
                stats.requests += 1;
                stats.hash_build_secs += table.build_secs;
                let cls_pred = fo.cls_logits.as_ref().map(|v| argmax(v));
                let (lm_nll, lm_tokens) = match (&fo.lm_logits, self.cfg.want_lm) {
                    (Some(logits), true) => {
                        let (nll, cnt) = self.runner.lm_nll(logits, &req.ids)?;
                        (Some(nll), Some(cnt))
                    }
                    _ => (None, None),
                };
                per_request.push(RequestResult {
                    id: req.id,
                    latency_secs: secs,
                    cls_pred,
                    lm_nll,
                    lm_tokens,
                    n_tokens: req.n_tokens,
                });
            }
        }
        stats.wall_secs = t_start.elapsed().as_secs_f64();

        former_handle.join().expect("batch-former thread panicked")?;
        let _hash_secs = hash_handle.join().expect("hash thread panicked")?;

        self.collect_serving_stats(&mut stats);
        Ok(ServeOutcome { stats, per_request })
    }

    /// See [`run_gated_forward`].
    pub(crate) fn forward_gated<T>(
        &self,
        pairs: &[(&HashTable, &[f32])],
        trace_ids: &[u64],
        body: impl FnOnce(ForwardHooks<'_>) -> Result<T>,
    ) -> Result<T> {
        run_gated_forward(
            &self.bundle,
            &self.warm_target(),
            pairs,
            &self.bundle.topology.moe_blocks,
            self.cfg.k_used,
            self.cfg.prefetch_depth,
            trace_ids,
            body,
        )
    }

    /// Publish the pipeline's live serving-tier counters (cache,
    /// hierarchy ladder, cluster devices) into a metrics registry —
    /// what the `--metrics-interval` snapshot thread reads mid-run.
    /// Request-level series stay at their defaults until the final
    /// publish at end of serve.
    pub fn publish_live_metrics(&self, reg: &crate::obs::Registry) {
        let mut stats = ServeStats::default();
        self.collect_serving_stats(&mut stats);
        crate::obs::publish::publish_serve_stats(reg, &stats);
    }

    /// Fold the serving-tier counters into `stats`: the single shared
    /// cache, or — in cluster mode — the aggregate over every device
    /// cache plus the full per-device [`crate::cluster::ClusterStats`].
    pub(crate) fn collect_serving_stats(&self, stats: &mut ServeStats) {
        match &self.cluster {
            None => {
                let cs = self.cache.stats();
                stats.cache_hits = cs.hits;
                stats.cache_misses = cs.misses;
                stats.blocking_misses = cs.blocking_misses;
                stats.evictions = cs.evictions;
                stats.transferred_bytes = cs.transferred_sim_bytes;
                stats.modeled_transfer_secs = cs.modeled_transfer_secs;
                stats.overlapped_transfer_secs = cs.overlapped_transfer_secs;
                stats.peak_device_bytes = self.cache.peak();
                stats.budget_bytes = self.cache.budget();
                stats.hierarchy = self.cache.hierarchy_stats();
            }
            Some(router) => {
                let cs = router.stats();
                for d in &cs.devices {
                    stats.cache_hits += d.cache.hits;
                    stats.cache_misses += d.cache.misses;
                    stats.blocking_misses += d.cache.blocking_misses;
                    stats.evictions += d.cache.evictions;
                    stats.transferred_bytes += d.cache.transferred_sim_bytes;
                    stats.modeled_transfer_secs += d.cache.modeled_transfer_secs;
                    stats.overlapped_transfer_secs += d.cache.overlapped_transfer_secs;
                }
                stats.hierarchy = cs.hierarchy_total();
                // the per-device view: the worst device's peak is what
                // each modeled accelerator must provision
                stats.peak_device_bytes = cs.max_device_peak_bytes();
                stats.budget_bytes = router.device_set().budget_per_device;
                stats.cluster = Some(cs);
            }
        }
        // the shared staging window (one per box: the single cache's, or
        // the one every cluster device charges into)
        let snap = match &self.cluster {
            None => self.cache.bandwidth_window().snapshot(),
            Some(router) => router.bandwidth_window().snapshot(),
        };
        stats.prefetch_backlog_secs = snap.backlog_secs;
        stats.prefetch_carried_backlog_secs = snap.carried_backlog_secs;
        stats.prefetch_admitted = snap.admitted;
        stats.prefetch_deferred = snap.deferred_low_confidence;
        stats.prefetch_window_utilization = snap.utilization();
    }
}

/// Who the prefetch stages and the layer-ahead warmer stage experts
/// into: the single shared cache, or the cluster fleet (each expert on
/// its holder devices).  Owns `Arc`s so prefetch threads can move it.
#[derive(Clone)]
pub(crate) enum WarmTarget {
    Single { cache: Arc<SharedExpertCache> },
    Cluster { router: Arc<ClusterRouter> },
}

/// A deferred fetch plan for the MoE layers after the first —
/// planned before the request is handed to inference, fetched after.
pub(crate) enum DeeperPlan {
    Single(Vec<PlannedFetch>),
    Cluster(Vec<ClusterFetch>),
}

impl WarmTarget {
    /// The shared staging bandwidth window this target charges
    /// non-blocking fetches into (one per box).
    pub(crate) fn bandwidth_window(&self) -> Arc<crate::experts::BandwidthWindow> {
        match self {
            WarmTarget::Single { cache } => cache.bandwidth_window(),
            WarmTarget::Cluster { router } => router.bandwidth_window(),
        }
    }

    /// Modeled staging window of one MoE layer of this batch
    /// ([`crate::memory::layer_window_secs`] at the layer's predicted
    /// expert count) — what a compute-layer advance drains from the
    /// shared window.
    pub(crate) fn layer_window_secs(
        &self,
        pairs: &[(&HashTable, &[f32])],
        layer: usize,
        k_used: usize,
    ) -> f64 {
        let experts = crate::experts::predicted_expert_counts(pairs, layer, k_used).len();
        let (costs, sim) = match self {
            WarmTarget::Single { cache } => {
                let guard = cache.read();
                let cm = guard.cost_model();
                (cm.tier_costs(), cm.sim_expert_bytes)
            }
            WarmTarget::Cluster { router } => router.staging_costs(),
        };
        crate::memory::layer_window_secs(&costs, sim, experts)
    }

    /// Warm one MoE layer's predicted union (non-blocking, prefetch
    /// timeline) wherever this target stages experts.  `layers_ahead`
    /// sets the fetches' deadlines; `max_lead` clamps their tier lead
    /// (`--prefetch-depth`).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn warm_layer(
        &self,
        bundle: &ModelBundle,
        pairs: &[(&HashTable, &[f32])],
        block: usize,
        layer: usize,
        k_used: usize,
        layers_ahead: usize,
        max_lead: usize,
    ) -> Result<()> {
        match self {
            WarmTarget::Single { cache } => {
                warm_layer(bundle, cache, pairs, block, layer, k_used, layers_ahead, max_lead)
            }
            WarmTarget::Cluster { router } => {
                router.warm_layer(bundle, pairs, block, layer, k_used, layers_ahead, max_lead)
            }
        }
    }

    /// Fetch plan for every MoE layer after the first, planned before
    /// compute begins (layer `j` is `j + 1` layer windows away).  Only
    /// fetches whose tier-derived lead covers that distance are staged
    /// this early — the rest wait for the depth-window warmer to reach
    /// them just-in-time (at `--prefetch-depth 1` nothing qualifies and
    /// this plan is empty: the one-layer-ahead baseline).
    pub(crate) fn plan_deeper(
        &self,
        pairs: &[(&HashTable, &[f32])],
        moe_blocks: &[usize],
        k_used: usize,
        max_lead: usize,
    ) -> DeeperPlan {
        match self {
            WarmTarget::Single { cache } => {
                let guard = cache.read();
                let mut plan = Vec::new();
                for (layer, &block) in moe_blocks.iter().enumerate().skip(1) {
                    let ahead = layer + 1;
                    plan.extend(
                        plan_prefetch_layer(pairs, block, layer, k_used, ahead, max_lead, &guard)
                            .into_iter()
                            .filter(|f| f.lead_layers >= ahead),
                    );
                }
                DeeperPlan::Single(plan)
            }
            WarmTarget::Cluster { router } => {
                let mut plan = Vec::new();
                for (layer, &block) in moe_blocks.iter().enumerate().skip(1) {
                    let ahead = layer + 1;
                    plan.extend(
                        router
                            .plan_layer(pairs, block, layer, k_used, ahead, max_lead)
                            .into_iter()
                            .filter(|f| f.lead_layers >= ahead),
                    );
                }
                DeeperPlan::Cluster(plan)
            }
        }
    }

    /// One staging round of the depth-window warmer: while compute is
    /// about to enter layer `round`, probe layers `round .. round +
    /// depth` and collect every missing fetch whose tier lead covers
    /// its distance (`layers_ahead = probe - round + 1`; the `round`
    /// layer itself is always included — lead ≥ 1).
    pub(crate) fn plan_window(
        &self,
        pairs: &[(&HashTable, &[f32])],
        moe_blocks: &[usize],
        k_used: usize,
        round: usize,
        depth: usize,
    ) -> DeeperPlan {
        let end = moe_blocks.len().min(round + depth.max(1));
        match self {
            WarmTarget::Single { cache } => {
                let guard = cache.read();
                let mut plan = Vec::new();
                for layer in round..end {
                    let ahead = layer - round + 1;
                    plan.extend(
                        plan_prefetch_layer(
                            pairs, moe_blocks[layer], layer, k_used, ahead, depth, &guard,
                        )
                        .into_iter()
                        .filter(|f| f.lead_layers >= ahead),
                    );
                }
                DeeperPlan::Single(plan)
            }
            WarmTarget::Cluster { router } => {
                let mut plan = Vec::new();
                for layer in round..end {
                    let ahead = layer - round + 1;
                    plan.extend(
                        router
                            .plan_layer(pairs, moe_blocks[layer], layer, k_used, ahead, depth)
                            .into_iter()
                            .filter(|f| f.lead_layers >= ahead),
                    );
                }
                DeeperPlan::Cluster(plan)
            }
        }
    }

    /// Execute a deferred plan on the prefetch timeline (EDF admission
    /// into the shared window happens inside the fetch executors).
    pub(crate) fn fetch_deeper(&self, bundle: &ModelBundle, plan: &DeeperPlan) -> Result<()> {
        match (self, plan) {
            (WarmTarget::Single { cache }, DeeperPlan::Single(p)) => {
                fetch_planned(bundle, cache, p)
            }
            (WarmTarget::Cluster { router }, DeeperPlan::Cluster(p)) => {
                router.fetch_planned(bundle, p)
            }
            // a plan always comes from the same target that executes it
            _ => Ok(()),
        }
    }
}

/// Run one forward (built by `body`) with a layer-ahead warmer on a
/// scoped side thread: the warmer stages MoE layer j+1's union while
/// `body` computes layer j, and the layer gate keeps compute from
/// outrunning warm-up — so blocking-miss accounting stays deterministic
/// and every fetch is overlapped.  Shared by `Pipeline` (batch-1 and
/// batched serving) and the TCP server's batch worker.
///
/// Failure discipline: a panic inside `body` still releases the gate
/// (drop guard), so the warmer exits and the scope join cannot hang;
/// a warmer *error* is logged and otherwise ignored — the gate already
/// released compute, which then fetched its experts blocking, so the
/// forward output is complete and correct.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_gated_forward<T>(
    bundle: &ModelBundle,
    target: &WarmTarget,
    pairs: &[(&HashTable, &[f32])],
    moe_blocks: &[usize],
    k_used: usize,
    prefetch_depth: usize,
    trace_ids: &[u64],
    body: impl FnOnce(ForwardHooks<'_>) -> Result<T>,
) -> Result<T> {
    let gate = LayerGate::new();
    std::thread::scope(|s| -> Result<T> {
        let warmer = {
            let gate = &gate;
            s.spawn(move || {
                layer_ahead_warmer(bundle, target, gate, pairs, moe_blocks, k_used, prefetch_depth)
            })
        };
        let result = {
            // release the warmer on every exit path, unwinding included
            struct FinishCompute<'a>(&'a LayerGate);
            impl Drop for FinishCompute<'_> {
                fn drop(&mut self) {
                    self.0.finish_compute();
                }
            }
            let _finish = FinishCompute(&gate);
            body(ForwardHooks { layer_gate: Some(&gate), trace_ids: Some(trace_ids) })
        };
        if let Err(e) = warmer.join().expect("layer-ahead warmer panicked") {
            log::warn!("layer-ahead warmer failed (forward fell back to blocking fetches): {e:#}");
        }
        result
    })
}

/// Execute a fetch plan (non-blocking fetches on the prefetch
/// timeline); resident entries cost one read-path hit.  The plan is
/// first admitted earliest-deadline-first into the cache's shared
/// bandwidth window ([`crate::experts::admit_edf`]): low-confidence
/// speculative fetches whose deadline the backlog already passed are
/// deferred to a later just-in-time round instead of burning window.
fn fetch_planned(
    bundle: &ModelBundle,
    cache: &SharedExpertCache,
    plan: &[PlannedFetch],
) -> Result<()> {
    if plan.is_empty() {
        return Ok(());
    }
    let window = cache.bandwidth_window();
    let (costs, sim) = {
        let guard = cache.read();
        let cm = guard.cost_model();
        (cm.tier_costs(), cm.sim_expert_bytes)
    };
    let rate = window.rate();
    let adm = crate::experts::admit_edf(plan.to_vec(), window.backlog_secs(), |f| {
        costs.promote_secs(f.tier, sim) * rate
    });
    window.note_deferred(adm.deferred as u64);
    let t_stage = trace::begin();
    for fetch in &adm.admit {
        let key = fetch.key;
        let real = bundle.weights.expert_bytes(key.block, key.expert)?;
        // non-blocking: prefetch misses do not stall the inference thread
        let _ = cache.ensure_deadline(key, real, fetch.deadline_secs, || {
            crate::runtime::stage_expert_parts(
                &bundle.engine,
                &bundle.weights,
                key.block,
                key.expert,
            )
        })?;
    }
    if trace::enabled() {
        trace::complete(
            "prefetch_stage",
            "prefetch",
            trace::host_pid(),
            t_stage,
            vec![
                ("experts", ArgValue::U(adm.admit.len() as u64)),
                ("deferred", ArgValue::U(adm.deferred as u64)),
                ("lead_layers", ArgValue::U(adm.max_lead_layers as u64)),
                ("deadline_slack_ms", ArgValue::F(adm.min_slack_secs.unwrap_or(0.0) * 1e3)),
            ],
        );
    }
    Ok(())
}

/// Warm one MoE layer's predicted expert union (non-blocking fetches on
/// the prefetch timeline), hottest experts first.
#[allow(clippy::too_many_arguments)]
pub(crate) fn warm_layer(
    bundle: &ModelBundle,
    cache: &SharedExpertCache,
    pairs: &[(&HashTable, &[f32])],
    block: usize,
    layer: usize,
    k_used: usize,
    layers_ahead: usize,
    max_lead: usize,
) -> Result<()> {
    let plan = {
        let guard = cache.read();
        plan_prefetch_layer(pairs, block, layer, k_used, layers_ahead, max_lead, &guard)
    };
    fetch_planned(bundle, cache, &plan)
}

/// Batch-former prefetch: warm the first MoE layer's batch-union before
/// the batch is handed to inference, and return the deeper layers' plan
/// to fetch after the hand-off (request-ahead overlap, lead-filtered —
/// see [`WarmTarget::plan_deeper`]).
fn stage_batch_prefetch(
    bundle: &ModelBundle,
    target: &WarmTarget,
    batch: &[(Request, HashTable)],
    moe_blocks: &[usize],
    k_used: usize,
    depth: usize,
) -> Result<DeeperPlan> {
    let masks: Vec<Vec<f32>> = batch.iter().map(|(req, _)| req.mask()).collect();
    let pairs: Vec<(&HashTable, &[f32])> = batch
        .iter()
        .zip(masks.iter())
        .map(|((_, table), mask)| (table, mask.as_slice()))
        .collect();
    target.warm_layer(bundle, &pairs, moe_blocks[0], 0, k_used, 1, depth)?;
    Ok(target.plan_deeper(&pairs, moe_blocks, k_used, depth))
}

/// The depth-window warmer body (PR 5's layer-ahead warmer generalized
/// to a staging depth): when compute is about to enter layer `round`,
/// probe layers `round .. round + depth`, stage every missing fetch
/// whose tier-derived lead covers its distance, and EDF-admit the
/// merged plan into the shared bandwidth window.  Each compute-layer
/// advance drains one modeled layer window from the link, so deep
/// SSD promotions issued 2–3 rounds early really do accumulate hideable
/// window.  `depth == 1` reproduces the one-layer-ahead baseline
/// exactly.  Any exit path (success, error, compute finished early)
/// releases the gate so the inference thread can never deadlock on a
/// dead warmer.
#[allow(clippy::too_many_arguments)]
pub(crate) fn layer_ahead_warmer(
    bundle: &ModelBundle,
    target: &WarmTarget,
    gate: &LayerGate,
    pairs: &[(&HashTable, &[f32])],
    moe_blocks: &[usize],
    k_used: usize,
    depth: usize,
) -> Result<()> {
    struct Release<'a>(&'a LayerGate);
    impl Drop for Release<'_> {
        fn drop(&mut self) {
            self.0.finish_warm();
        }
    }
    let _release = Release(gate);
    let depth = depth.max(1);
    let window = target.bandwidth_window();
    for round in 0..moe_blocks.len() {
        if round > 0 {
            if !gate.wait_compute_at_least(round - 1) {
                break; // forward pass already over — nothing left to warm
            }
            // compute just finished layer round-1: that layer's modeled
            // staging window drained from the shared link
            window.drain(target.layer_window_secs(pairs, round - 1, k_used));
        }
        let plan = target.plan_window(pairs, moe_blocks, k_used, round, depth);
        target.fetch_deeper(bundle, &plan)?;
        gate.mark_warmed(round);
    }
    Ok(())
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    #[test]
    fn default_config_sane() {
        let c = PipelineConfig::default();
        assert_eq!(c.k_used, 1);
        assert_eq!(c.policy, "fifo");
        assert!(c.prefetch);
        assert_eq!(c.pool_threads, 0, "0 = auto-size");
    }
}
